//! Table 2 reproduction (DESIGN.md E6): MobileNet accelerator comparison —
//! published rows from the cited papers plus our regenerated LUTMUL row
//! (full MobileNetV2 synthesized on the U280 by the folding optimizer).
//!
//! Run: `cargo run --release --example table2`

fn main() {
    lutmul::reports::table2();
}
