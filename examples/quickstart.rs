//! Quickstart: the LUTMUL idea in five minutes, no artifacts needed.
//!
//! 1. Embed weights into LUT6_2 primitives (Figure 5) and multiply by
//!    *reading the LUTs*.
//! 2. Count resources with Eq. (3) vs a general multiplier.
//! 3. See why that beats the DSP roofline at equal resources (Figure 1).
//!
//! Run: `cargo run --release --example quickstart`

use lutmul::fabric::cost::{luts_per_general_mult, luts_per_mult};
use lutmul::fabric::device::U280;
use lutmul::fabric::lutmul::ConstMultiplier;
use lutmul::roofline;

fn main() {
    println!("== 1. Embed weights 1 and -3 into LUT6_2 primitives (Figure 5)");
    let m = ConstMultiplier::new(1, -3, 4);
    println!("   INIT vectors ({} physical LUT6 for 2 weights):", m.lut_count());
    for s in m.init_strings() {
        println!("     {s}");
    }
    println!("   multiplication by LUT readout (weight -3):");
    for a in [0u32, 1, 7, 15] {
        println!("     -3 x {a:>2} = {:>4}", m.eval(true, a));
    }
    assert_eq!(m.eval(true, 15), -45);

    println!("\n== 2. Resource cost per 4-bit multiplication (Eq. 3)");
    println!("   LUTMUL embedded:   {:>5.1} LUT6", luts_per_mult(4));
    println!("   general multiplier:{:>5.1} LUT6", luts_per_general_mult(4));
    println!(
        "   -> {:.1}x fewer LUTs, so {:.0}x more parallel multipliers",
        luts_per_general_mult(4) / luts_per_mult(4),
        luts_per_general_mult(4) / luts_per_mult(4)
    );

    println!("\n== 3. Why this exceeds the DSP roofline (1/64 of U280, 333 MHz)");
    let slice = U280.fraction(64);
    let f = U280.max_freq_mhz * 1e6;
    let lut_peak = roofline::lutmul_peak(&slice, 4, f);
    let dsp_peak = roofline::dsp_peak(&slice, 4, f);
    println!("   DSP-based peak (p=4 packing): {:>8.1} GOPS", dsp_peak / 1e9);
    println!("   LUTMUL peak:                  {:>8.1} GOPS", lut_peak / 1e9);
    println!(
        "   LUTs outnumber DSPs {:.0}x on the {}; LUTMUL converts that into {:.1}x peak",
        U280.luts as f64 / U280.dsps as f64,
        U280.name,
        lut_peak / dsp_peak
    );

    println!("\nNext steps:");
    println!("  make artifacts                             # train + AOT-lower the model");
    println!("  cargo run --release --example mobilenet_serve   # end-to-end serving");
    println!("  cargo run --release --example table2            # reproduce Table 2");
}
