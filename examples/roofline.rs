//! Table 1 + Figure 1 reproduction (DESIGN.md E1/E2).
//!
//! Run: `cargo run --release --example roofline [-- table1|fig1]`

fn main() {
    let what = std::env::args().nth(1).unwrap_or_else(|| "both".into());
    if what == "table1" || what == "both" {
        lutmul::reports::table1();
        println!();
    }
    if what == "fig1" || what == "both" {
        lutmul::reports::fig1();
    }
}
