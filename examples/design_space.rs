//! Design-space exploration (ablation for DESIGN.md E7): sweep the
//! resource budget and quantization bit-width for full MobileNetV2 on the
//! U280 and report what the folding optimizer finds — the paper's
//! scalability story ("the resources for each layer can be adjusted
//! according to computation requirements").
//!
//! Run: `cargo run --release --example design_space` (no artifacts needed)

use lutmul::fabric::device::U280;
use lutmul::graph::arch::mobilenet_v2_full;
use lutmul::synth::design::LayerMode;
use lutmul::synth::fold::{optimize_folding, Budget};
use lutmul::synth::synthesize;

fn main() {
    let arch = mobilenet_v2_full();
    println!(
        "MobileNetV2 @224: {} layers, {:.2} GOPs/image, {:.2}M weights\n",
        arch.layers.len(),
        arch.ops_per_image() as f64 / 1e9,
        arch.total_weights() as f64 / 1e6
    );

    println!("== budget sweep (U280 fractions, W4A4) ==");
    println!(
        "{:>9}{:>12}{:>10}{:>10}{:>10}{:>10}{:>11}{:>9}",
        "budget", "cycles/img", "FPS", "GOPS", "kLUT", "BRAM36", "DSP", "GOPS/W"
    );
    for denom in [1u64, 2, 4, 8, 16, 32, 64] {
        let budget =
            if denom == 1 { Budget::whole(&U280) } else { Budget::fraction(&U280, denom) };
        let (folds, cycles) = optimize_folding(&arch, &budget);
        let d = synthesize(&arch, &U280, &folds);
        println!(
            "{:>9}{:>12}{:>10.0}{:>10.1}{:>10.0}{:>10}{:>11}{:>9.2}",
            format!("1/{denom}"),
            cycles,
            d.fps(),
            d.gops(),
            d.luts as f64 / 1e3,
            d.bram36,
            d.dsps,
            d.gops_per_watt()
        );
    }

    println!("\n== bit-width sweep (whole U280) ==");
    println!(
        "{:>6}{:>12}{:>10}{:>10}{:>10}{:>16}",
        "bits", "cycles/img", "FPS", "GOPS", "kLUT", "LUTs/mult (Eq3)"
    );
    for bits in [2u32, 3, 4, 5, 6, 8] {
        let mut a = arch.clone();
        for l in a.layers.iter_mut() {
            if l.w_bits < 8 {
                l.w_bits = bits;
                l.a_bits = bits;
            }
        }
        let (folds, cycles) = optimize_folding(&a, &Budget::whole(&U280));
        let d = synthesize(&a, &U280, &folds);
        println!(
            "{:>6}{:>12}{:>10.0}{:>10.1}{:>10.0}{:>14.1}",
            bits,
            cycles,
            d.fps(),
            d.gops(),
            d.luts as f64 / 1e3,
            lutmul::fabric::cost::luts_per_mult(bits)
        );
    }

    println!("\n== per-layer plan at full budget (first 12 + folded tail summary) ==");
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    let d = synthesize(&arch, &U280, &folds);
    println!("{:>14}{:>9}{:>7}{:>10}{:>8}{:>5}", "layer", "mode", "fold", "LUTs", "BRAM", "SLR");
    for s in d.stages.iter().take(12) {
        println!(
            "{:>14}{:>9}{:>7}{:>10.0}{:>8.1}{:>5}",
            s.name,
            format!("{:?}", s.mode),
            s.fold,
            s.luts,
            s.bram36,
            s.slr
        );
    }
    let tail: Vec<_> = d.stages.iter().skip(12).collect();
    let tail_bram: f64 = tail.iter().map(|s| s.bram36).sum();
    let tail_luts: f64 = tail.iter().map(|s| s.luts).sum();
    let n_bram_mode = tail.iter().filter(|s| s.mode == LayerMode::BramMac).count();
    println!(
        "  ... {} more stages: {:.0} LUTs, {:.0} BRAM36, {} in BramMac mode (folded tail)",
        tail.len(),
        tail_luts,
        tail_bram,
        n_bram_mode
    );
    println!(
        "\ntotal: {} LUT | {} BRAM36 | {} DSP | {:.0} FPS | {:.1} GOPS | {:.1} W",
        d.luts,
        d.bram36,
        d.dsps,
        d.fps(),
        d.gops(),
        d.power_w
    );
}
