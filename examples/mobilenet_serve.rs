//! End-to-end driver (EXPERIMENTS.md E8): load the trained, streamlined
//! MobileNetV2 artifacts, prove the whole stack composes, and serve
//! batched inference requests.
//!
//!  stage 1  golden check — the PJRT runtime executes the AOT HLO (with
//!           the Pallas LUTMUL kernels inside) and must agree bit-exactly
//!           with the Rust reference executor and the dataflow simulator
//!           (skipped, with the executor/simulator cross-check kept, when
//!           built without the `xla` feature);
//!  stage 2  accelerator timing — run the full test set through the
//!           cycle-level dataflow pipeline, report simulated FPS/GOPS at
//!           333 MHz and classification accuracy;
//!  stage 3  batch-major throughput — images/s vs batch size through
//!           `Executor::run_batch`, the serving fast path (E9);
//!  stage 4  serving — push a batched request load through the async
//!           coordinator (router -> batcher -> worker pool) and report
//!           latency percentiles, batch statistics and throughput.
//!
//! Needs `make artifacts`. Run:
//!   cargo run --release --example mobilenet_serve [-- <requests>]

use std::sync::Arc;

use lutmul::coordinator::{argmax, Backend, Coordinator, ServeConfig};
use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::graph::executor::{Datapath, Executor, Tensor};
use lutmul::graph::network::Network;
use lutmul::runtime::{Artifacts, Runtime};

fn main() -> anyhow::Result<()> {
    let requests: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2048);
    let artifacts = Artifacts::new("artifacts");
    let net = Network::load(artifacts.network_json())?;
    let (images, labels) =
        artifacts.load_test_set(net.meta.image_size, net.meta.image_size, net.meta.in_ch)?;
    let size = net.meta.image_size;
    println!(
        "network: {} ops, W{}A{}, deployed acc (export) {:.2}% | {} test images",
        net.ops.len(),
        net.meta.w_bits,
        net.meta.a_bits,
        100.0 * net.meta.acc_int,
        images.len()
    );

    // ---- stage 1: three-way golden check ------------------------------
    println!("\n[1/4] golden check (PJRT HLO vs executor vs dataflow sim)");
    let ex = Executor::new(&net, Datapath::Arithmetic);
    let mut pipe = Pipeline::build(&net, &FoldConfig::fully_parallel(net.convs().count()), 16);
    let n_check = 8;
    let sim = pipe.run(&images[..n_check])?;
    let tensors: Vec<Tensor> = images[..n_check]
        .iter()
        .map(|img| Tensor::from_hwc(size, size, net.meta.in_ch, img.clone()))
        .collect();
    let exec_logits = ex.run_batch(&tensors);
    for i in 0..n_check {
        anyhow::ensure!(exec_logits[i] == sim.logits[i], "simulator diverged on image {i}");
    }
    match Runtime::load(artifacts.model_hlo(1), 1, size, size, net.meta.in_ch, net.meta.num_classes)
    {
        Ok(rt) => {
            for i in 0..n_check {
                let golden = rt.run(&images[i])?;
                anyhow::ensure!(golden[0] == exec_logits[i], "executor diverged on image {i}");
            }
            println!("      {n_check}/{n_check} images bit-exact across all three backends");
        }
        // without the `xla` feature the runtime is a stub: skip the HLO
        // leg but keep the executor/simulator cross-check
        #[cfg(not(feature = "xla"))]
        Err(e) => {
            println!("      PJRT skipped ({e});");
            println!("      executor vs simulator: {n_check}/{n_check} bit-exact");
        }
        // with real PJRT bindings a load failure is a broken artifact —
        // fail loudly rather than report a hollow pass
        #[cfg(feature = "xla")]
        Err(e) => return Err(e),
    }

    // ---- stage 2: accelerator timing on the full test set -------------
    println!("\n[2/4] dataflow accelerator simulation (full test set)");
    let mut pipe = Pipeline::build(&net, &FoldConfig::fully_parallel(net.convs().count()), 16);
    let t0 = std::time::Instant::now();
    let rep = pipe.run(&images)?;
    let host = t0.elapsed();
    let correct = rep
        .logits
        .iter()
        .zip(&labels)
        .filter(|(l, &y)| argmax(l) == y as usize)
        .count();
    let ops = net.ops_per_image(); // GOPS denominator from the served net
    let fps = rep.steady_state_fps(333.0);
    println!(
        "      {} images | accuracy {:.2}% | {} total cycles | steady-state {} cycles/img | marginal batched image {} cycles",
        images.len(),
        100.0 * correct as f64 / images.len() as f64,
        rep.cycles,
        rep.steady_state_cycles_per_image,
        rep.incremental_cycles_per_image()
    );
    println!(
        "      accelerator @333MHz: {:.0} FPS, {:.1} GOPS | host sim wall time {:.2?} ({:.0} img/s)",
        fps,
        fps * ops as f64 / 1e9,
        host,
        images.len() as f64 / host.as_secs_f64()
    );
    let busiest = rep.stages.iter().max_by_key(|s| s.fires).unwrap();
    println!("      busiest stage: {} ({} fires)", busiest.name, busiest.fires);

    // ---- stage 3: batch-major executor throughput ---------------------
    println!("\n[3/4] batch-major throughput (Executor::run_batch, Reference)");
    let bench_imgs: Vec<Tensor> = images
        .iter()
        .cycle()
        .take(32)
        .map(|img| Tensor::from_hwc(size, size, net.meta.in_ch, img.clone()))
        .collect();
    let mut base_ips = 0.0;
    for b in [1usize, 4, 8, 16, 32] {
        let batch = &bench_imgs[..b];
        let iters = (64 / b).max(4);
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(ex.run_batch(batch));
        }
        let ips = (b * iters) as f64 / t0.elapsed().as_secs_f64();
        if b == 1 {
            base_ips = ips;
        }
        println!("      batch {b:>2}: {ips:>8.0} img/s ({:.2}x vs batch 1)", ips / base_ips);
    }

    // ---- stage 4: batched serving ------------------------------------
    println!("\n[4/4] serving {requests} requests (router -> batcher -> 2 workers)");
    let coord = Coordinator::start(
        Arc::new(net),
        ServeConfig {
            backend: Backend::Reference,
            workers: 2,
            max_batch: 16,
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    let mut correct = 0usize;
    for i in 0..requests {
        match coord.submit(images[i % images.len()].clone()) {
            Ok(t) => pending.push((i, t)),
            Err(_) => rejected += 1,
        }
        // drain in windows to model a closed-loop client pool
        if pending.len() >= 256 {
            for (j, t) in pending.drain(..) {
                let r = t.wait()?;
                if r.class == labels[j % labels.len()] as usize {
                    correct += 1;
                }
            }
        }
    }
    for (j, t) in pending.drain(..) {
        let r = t.wait()?;
        if r.class == labels[j % labels.len()] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    println!(
        "      {} served ({rejected} rejected) in {:.2?} | accuracy {:.2}%",
        m.completed,
        wall,
        100.0 * correct as f64 / (requests - rejected) as f64
    );
    println!("      {m}");
    coord.shutdown();
    println!("\nOK — all layers compose (L1 Pallas kernels inside the AOT HLO, L2 model, L3 runtime).");
    Ok(())
}
