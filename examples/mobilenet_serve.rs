//! End-to-end driver (EXPERIMENTS.md E8): load the trained, streamlined
//! MobileNetV2 artifacts through the engine (DESIGN.md S19), prove the
//! whole stack composes behind the uniform `InferenceBackend` contract,
//! and serve batched inference requests.
//!
//!  stage 1  golden check — the PJRT runtime executes the AOT HLO (with
//!           the Pallas LUTMUL kernels inside) and must agree bit-exactly
//!           with the Rust reference executor and the dataflow simulator
//!           (skipped, with the executor/simulator cross-check kept, when
//!           built without the `xla` feature); all three are
//!           `InferenceBackend`s over the engine's one compiled plan;
//!  stage 2  accelerator timing — run the full test set through the
//!           cycle-level dataflow pipeline, report simulated FPS/GOPS at
//!           333 MHz and classification accuracy;
//!  stage 3  batch-major throughput — images/s vs batch size through
//!           the engine's executor backend, the serving fast path (E9);
//!  stage 4  serving — push a batched request load through the async
//!           coordinator (router -> batcher -> worker pool) and report
//!           latency percentiles, batch statistics and throughput.
//!
//! Needs `make artifacts`. Run:
//!   cargo run --release --example mobilenet_serve [-- <requests>]

use lutmul::coordinator::{argmax, Coordinator, ServeConfig};
use lutmul::engine::{Arch, BackendKind, Engine};
use lutmul::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    let requests: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2048);
    let artifacts = Artifacts::new("artifacts");
    // one construction path for the whole stack: trained network, plan
    // compile, executor backend (no synthetic fallback — this driver is
    // about the trained artifacts)
    let mut engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(&artifacts)
        .backend(BackendKind::Reference)
        .build()?;
    let (images, labels) = engine.labeled_test_set()?;
    let net = engine.net().clone();
    println!(
        "network: {} ops, W{}A{}, deployed acc (export) {:.2}% | {} test images",
        net.ops.len(),
        net.meta.w_bits,
        net.meta.a_bits,
        100.0 * net.meta.acc_int,
        images.len()
    );

    // ---- stage 1: three-way golden check ------------------------------
    println!("\n[1/4] golden check (PJRT HLO vs executor vs dataflow sim)");
    let n_check = 8;
    let exec_logits = engine.infer_batch(&images[..n_check])?.logits;
    let mut pipe = engine.make_backend(BackendKind::Pipeline)?;
    let sim = pipe.infer_batch(&images[..n_check])?;
    for i in 0..n_check {
        anyhow::ensure!(exec_logits[i] == sim.logits[i], "simulator diverged on image {i}");
    }
    match engine.make_backend(BackendKind::Pjrt { batch: 1 }) {
        Ok(mut rt) => {
            for i in 0..n_check {
                let golden = rt.infer_batch(std::slice::from_ref(&images[i]))?;
                anyhow::ensure!(
                    golden.logits[0] == exec_logits[i],
                    "executor diverged on image {i}"
                );
            }
            println!("      {n_check}/{n_check} images bit-exact across all three backends");
        }
        // with real PJRT bindings a load failure is a broken artifact —
        // fail loudly rather than report a hollow pass
        Err(e) if cfg!(feature = "xla") => return Err(e),
        // without the `xla` feature the runtime is a stub: skip the HLO
        // leg but keep the executor/simulator cross-check
        Err(e) => {
            println!("      PJRT skipped ({e});");
            println!("      executor vs simulator: {n_check}/{n_check} bit-exact");
        }
    }

    // ---- stage 2: accelerator timing on the full test set -------------
    println!("\n[2/4] dataflow accelerator simulation (full test set)");
    let t0 = std::time::Instant::now();
    let rep = pipe.infer_batch(&images)?;
    let host = t0.elapsed();
    let correct = rep
        .logits
        .iter()
        .zip(&labels)
        .filter(|(l, &y)| argmax(l) == y as usize)
        .count();
    let ops = net.ops_per_image(); // GOPS denominator from the served net
    let steady = pipe
        .steady_cycles()
        .unwrap_or(rep.cycles / images.len().max(1) as u64);
    let fps = 333.0e6 / steady.max(1) as f64;
    println!(
        "      {} images | accuracy {:.2}% | {} total cycles | steady-state {steady} cycles/img",
        images.len(),
        100.0 * correct as f64 / images.len() as f64,
        rep.cycles,
    );
    println!(
        "      accelerator @333MHz: {:.0} FPS, {:.1} GOPS | host sim wall time {:.2?} ({:.0} img/s)",
        fps,
        fps * ops as f64 / 1e9,
        host,
        images.len() as f64 / host.as_secs_f64()
    );

    // ---- stage 3: batch-major executor throughput ---------------------
    println!("\n[3/4] batch-major throughput (engine executor backend)");
    let bench_imgs: Vec<Vec<i32>> = images.iter().cycle().take(32).cloned().collect();
    let mut base_ips = 0.0;
    for b in [1usize, 4, 8, 16, 32] {
        let batch = &bench_imgs[..b];
        let iters = (64 / b).max(4);
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(engine.infer_batch(batch)?.logits.len());
        }
        let ips = (b * iters) as f64 / t0.elapsed().as_secs_f64();
        if b == 1 {
            base_ips = ips;
        }
        println!("      batch {b:>2}: {ips:>8.0} img/s ({:.2}x vs batch 1)", ips / base_ips);
    }

    // ---- stage 4: batched serving ------------------------------------
    println!("\n[4/4] serving {requests} requests (router -> batcher -> 2 workers)");
    let coord = Coordinator::start(
        &engine,
        ServeConfig { workers: 2, max_batch: 16, ..Default::default() },
    )?;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    let mut correct = 0usize;
    for i in 0..requests {
        match coord.submit(images[i % images.len()].clone()) {
            Ok(t) => pending.push((i, t)),
            Err(_) => rejected += 1,
        }
        // drain in windows to model a closed-loop client pool
        if pending.len() >= 256 {
            for (j, t) in pending.drain(..) {
                let r = t.wait()?;
                if r.class == labels[j % labels.len()] as usize {
                    correct += 1;
                }
            }
        }
    }
    for (j, t) in pending.drain(..) {
        let r = t.wait()?;
        if r.class == labels[j % labels.len()] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    println!(
        "      {} served ({rejected} rejected) in {:.2?} | accuracy {:.2}%",
        m.completed,
        wall,
        100.0 * correct as f64 / (requests - rejected) as f64
    );
    println!("      {m}");
    coord.shutdown();
    println!("\nOK — all layers compose (L1 Pallas kernels inside the AOT HLO, L2 model, L3 engine + serving).");
    Ok(())
}
