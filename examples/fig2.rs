//! Figure 2 reproduction (DESIGN.md E3): accuracy loss and LUTs per
//! multiplication for 1..8-bit quantization. The accuracy column comes
//! from the QAT sweep artifact (`make artifacts-fig2`); the LUT column is
//! Eq. (3) and needs nothing.
//!
//! Run: `cargo run --release --example fig2`

fn main() {
    lutmul::reports::fig2(std::path::Path::new("artifacts/fig2_accuracy.json"));
}
