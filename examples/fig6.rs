//! Figure 6 reproduction (DESIGN.md E5): LUT resource breakdown of
//! MobileNetV2's second convolution layer (1x1, 32->32) under LUTMUL,
//! vs the paper's published HLS/implementation numbers.
//!
//! Run: `cargo run --release --example fig6`

fn main() {
    lutmul::reports::fig6();
}
