"""Quantization primitives: Eq. (4)/(5), STE, streamlining thresholds."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as q


class TestSteRound:
    def test_forward_is_round(self):
        x = jnp.array([-1.5, -0.4, 0.5, 1.4, 2.5])
        # round-to-even at halves (jnp.round semantics)
        assert np.array(q.ste_round(x)).tolist() == [-2.0, -0.0, 0.0, 1.0, 2.0]

    def test_gradient_is_identity(self):
        g = jax.grad(lambda x: q.ste_round(x * 3.0))(1.234)
        assert float(g) == pytest.approx(3.0)


class TestRanges:
    @pytest.mark.parametrize("bits,lo,hi", [(1, -1, 0), (4, -8, 7), (8, -128, 127)])
    def test_weight_range(self, bits, lo, hi):
        assert q.weight_qrange(bits) == (lo, hi)

    @pytest.mark.parametrize("bits,hi", [(1, 1), (4, 15), (8, 255)])
    def test_act_range(self, bits, hi):
        assert q.act_qrange(bits) == (0, hi)


class TestWeightQuant:
    @settings(max_examples=20, deadline=None)
    @given(bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 1000))
    def test_codes_in_range(self, bits, seed):
        rng = np.random.default_rng(seed)
        w = jnp.array(rng.normal(0, 1, (6, 10)), jnp.float32)
        codes, s = q.weight_codes(w, bits, channel_axis=0)
        lo, hi = q.weight_qrange(bits)
        assert int(codes.min()) >= lo and int(codes.max()) <= hi
        assert (np.array(s) > 0).all()

    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        w = jnp.array(rng.normal(0, 1, (4, 32)), jnp.float32)
        wq = q.quantize_weight(w, 4, channel_axis=0)
        s = q.weight_scale(w, 4, channel_axis=0)
        # symmetric quant: |w - wq| <= s/2 except at the clamped negative edge
        assert (jnp.abs(w - wq) <= np.array(s) * 0.5 + 1e-6).all()

    def test_per_channel_independence(self):
        w = jnp.array([[0.1, -0.1], [100.0, -100.0]], jnp.float32)
        codes, s = q.weight_codes(w, 4, channel_axis=0)
        assert float(s.reshape(-1)[1]) == pytest.approx(100.0 / 7)
        assert float(s.reshape(-1)[0]) == pytest.approx(0.1 / 7)


class TestActQuant:
    def test_clamps_negative_to_zero(self):
        x = jnp.array([-5.0, -0.01, 0.0, 1.0])
        out = q.quantize_act(x, 0.1, 4)
        assert (np.array(out)[:3] == 0).all()

    def test_saturates_at_qmax(self):
        out = q.quantize_act(jnp.array([1000.0]), 0.1, 4)
        assert float(out[0]) == pytest.approx(1.5)  # 15 * 0.1

    def test_codes_match_fake_quant(self):
        rng = np.random.default_rng(1)
        x = jnp.array(rng.normal(0.5, 0.7, (100,)), jnp.float32)
        s = 0.13
        codes = q.act_codes(x, s, 4)
        fake = q.quantize_act(x, s, 4)
        assert np.allclose(np.array(codes) * s, np.array(fake), atol=1e-6)


class TestStreamlineThresholds:
    """The load-bearing transform: integer thresholds must reproduce the
    float pipeline BN -> scale -> round/clamp for every integer acc."""

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        out_bits=st.sampled_from([1, 2, 4]),
        negative_gain=st.booleans(),
    )
    def test_matches_float_reference(self, seed, out_bits, negative_gain):
        rng = np.random.default_rng(seed)
        c = 4
        s_w = jnp.array(rng.uniform(0.01, 0.2, c), jnp.float32)
        s_in = float(rng.uniform(0.01, 0.3))
        s_out = float(rng.uniform(0.05, 0.5))
        gamma = rng.uniform(0.2, 2.0, c) * (-1 if negative_gain else 1)
        bn = q.BatchNormParams(
            gamma=jnp.array(gamma, jnp.float32),
            beta=jnp.array(rng.normal(0, 1, c), jnp.float32),
            mean=jnp.array(rng.normal(0, 5, c), jnp.float32),
            var=jnp.array(rng.uniform(0.5, 10, c), jnp.float32),
        )
        thr, signs, consts = q.streamline_thresholds(s_w, s_in, bn, s_out, out_bits)
        levels = 2**out_bits - 1
        assert thr.shape == (c, levels)

        acc = jnp.arange(-300, 300, dtype=jnp.int32)[:, None].repeat(c, 1)
        # integer path
        from compile.kernels import ref as kref

        got = kref.multithreshold_ref(acc, thr, signs, consts)
        # float path: clamp(round(BN(s_w*s_in*acc)/s_out))
        x = np.array(s_w)[None, :] * s_in * np.array(acc, np.float64)
        y = np.array(bn.apply(jnp.array(x, jnp.float32)), np.float64)
        want = np.clip(np.floor(y / s_out + 0.5), 0, levels).astype(np.int64)
        got = np.array(got, np.int64)
        # Allow ties (y/s_out exactly half-integer) to differ; elsewhere exact.
        frac = np.abs(y / s_out - (np.floor(y / s_out) + 0.5))
        mask = frac > 1e-4
        assert (got[mask] == want[mask]).all()

    def test_zero_gain_constant_channel(self):
        c = 2
        bn = q.BatchNormParams(
            gamma=jnp.array([0.0, 1.0]),
            beta=jnp.array([0.7, 0.0]),
            mean=jnp.zeros(c),
            var=jnp.ones(c),
        )
        thr, signs, consts = q.streamline_thresholds(
            jnp.array([0.1, 0.1]), 0.1, bn, 0.1, 4
        )
        assert int(signs[0]) == 0 and int(signs[1]) == 1
        assert int(consts[0]) == 7  # round(0.7 / 0.1)


class TestCalibrate:
    def test_scale_covers_percentile(self):
        rng = np.random.default_rng(0)
        x = jnp.array(rng.uniform(0, 1, 10_000), jnp.float32)
        s = q.calibrate_scale(x, 4, percentile=100.0)
        assert s * 15 >= float(x.max()) - 1e-5

    def test_ignores_negative_tail(self):
        x = jnp.array([-100.0, -50.0, 0.5, 1.0])
        s = q.calibrate_scale(x, 4, percentile=100.0)
        assert s * 15 == pytest.approx(1.0, rel=1e-4)
