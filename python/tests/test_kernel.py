"""L1 correctness: Pallas LUTMUL kernels vs the pure-jnp oracle.

The kernels are integer-exact, so every check is `==` (bit-for-bit), not
allclose. Hypothesis sweeps shapes, bit-widths and block sizes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lutmul as lk
from compile.kernels import ref as kref


def _rand_case(rng, m, cout, cin, w_bits, a_bits):
    lo, hi = -(2 ** (w_bits - 1)), 2 ** (w_bits - 1)
    w = rng.integers(lo, hi, size=(cout, cin)).astype(np.int32)
    a = rng.integers(0, 2**a_bits, size=(m, cin)).astype(np.int32)
    return w, a


class TestBuildTable:
    def test_values(self):
        w = jnp.array([[1, -3], [7, -8]], jnp.int32)
        t = kref.build_table(w, 4)
        assert t.shape == (2, 2, 16)
        assert int(t[0, 1, 5]) == -15
        assert int(t[1, 0, 15]) == 105
        assert int(t[1, 1, 15]) == -120  # int4 min x uint4 max fits int8

    def test_zero_activation_column(self):
        w = jnp.array([[5, -5]], jnp.int32)
        t = kref.build_table(w, 4)
        assert (np.array(t[:, :, 0]) == 0).all()

    @pytest.mark.parametrize("a_bits", [1, 2, 4, 8])
    def test_table_width(self, a_bits):
        w = jnp.ones((3, 4), jnp.int32)
        assert kref.build_table(w, a_bits).shape == (3, 4, 2**a_bits)


class TestMatmulOracle:
    def test_vs_numpy_brute_force(self):
        rng = np.random.default_rng(1)
        w, a = _rand_case(rng, 23, 7, 13, 4, 4)
        t = kref.build_table(jnp.array(w), 4)
        out = np.array(kref.lutmul_matmul_ref(jnp.array(a), t))
        assert (out == a.astype(np.int64) @ w.T.astype(np.int64)).all()

    def test_dw_vs_numpy(self):
        rng = np.random.default_rng(2)
        c, k, m = 5, 9, 17
        w = rng.integers(-8, 8, size=(c, k)).astype(np.int32)
        a = rng.integers(0, 16, size=(m, c, k)).astype(np.int32)
        t = kref.build_table(jnp.array(w), 4)
        out = np.array(kref.lutmul_depthwise_ref(jnp.array(a), t))
        expect = (a.astype(np.int64) * w[None]).sum(axis=2)
        assert (out == expect).all()


class TestPallasVsOracle:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 70),
        cout=st.integers(1, 24),
        cin=st.integers(1, 40),
        w_bits=st.sampled_from([2, 3, 4, 8]),
        a_bits=st.sampled_from([1, 2, 4]),
        block_m=st.sampled_from([8, 16, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matmul(self, m, cout, cin, w_bits, a_bits, block_m, seed):
        rng = np.random.default_rng(seed)
        w, a = _rand_case(rng, m, cout, cin, w_bits, a_bits)
        t = kref.build_table(jnp.array(w), a_bits)
        ref = kref.lutmul_matmul_ref(jnp.array(a), t)
        out = lk.lutmul_matmul(jnp.array(a), t, block_m=block_m)
        assert out.dtype == jnp.int32
        assert (np.array(ref) == np.array(out)).all()

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 50),
        c=st.integers(1, 16),
        k=st.sampled_from([1, 4, 9]),
        a_bits=st.sampled_from([2, 4]),
        block_m=st.sampled_from([8, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_depthwise(self, m, c, k, a_bits, block_m, seed):
        rng = np.random.default_rng(seed)
        w = rng.integers(-8, 8, size=(c, k)).astype(np.int32)
        a = rng.integers(0, 2**a_bits, size=(m, c, k)).astype(np.int32)
        t = kref.build_table(jnp.array(w), a_bits)
        ref = kref.lutmul_depthwise_ref(jnp.array(a), t)
        out = lk.lutmul_depthwise(jnp.array(a), t, block_m=block_m)
        assert (np.array(ref) == np.array(out)).all()

    def test_8bit_activations(self):
        """Stem-layer configuration: uint8 activations, int8 weights."""
        rng = np.random.default_rng(3)
        w = rng.integers(-128, 128, size=(16, 27)).astype(np.int32)
        a = rng.integers(0, 256, size=(64, 27)).astype(np.int32)
        t = kref.build_table(jnp.array(w), 8)
        ref = kref.lutmul_matmul_ref(jnp.array(a), t)
        out = lk.lutmul_matmul(jnp.array(a), t, block_m=32)
        assert (np.array(ref) == np.array(out)).all()

    def test_m_exactly_block(self):
        rng = np.random.default_rng(4)
        w, a = _rand_case(rng, 16, 4, 8, 4, 4)
        t = kref.build_table(jnp.array(w), 4)
        out = lk.lutmul_matmul(jnp.array(a), t, block_m=16)
        assert (np.array(out) == np.array(kref.lutmul_matmul_ref(jnp.array(a), t))).all()

    def test_extreme_weights(self):
        """int4 boundary weights (-8, 7) with max activations."""
        w = jnp.array([[-8, 7, -8, 7]], jnp.int32)
        a = jnp.full((3, 4), 15, jnp.int32)
        t = kref.build_table(w, 4)
        out = lk.lutmul_matmul(a, t, block_m=8)
        assert (np.array(out) == (-8 + 7 - 8 + 7) * 15).all()


class TestMultiThreshold:
    def test_positive_sign_counts_crossings(self):
        acc = jnp.array([[-5], [0], [3], [100]], jnp.int32)
        thr = jnp.array([[0, 2, 50]], jnp.int32)  # C=1, L=3
        signs = jnp.array([1], jnp.int32)
        consts = jnp.array([0], jnp.int32)
        out = kref.multithreshold_ref(acc, thr, signs, consts)
        assert out.reshape(-1).tolist() == [0, 1, 2, 3]

    def test_negative_sign(self):
        acc = jnp.array([[-5], [0], [3], [100]], jnp.int32)
        thr = jnp.array([[-1, 1, 50]], jnp.int32)
        signs = jnp.array([-1], jnp.int32)
        consts = jnp.array([0], jnp.int32)
        out = kref.multithreshold_ref(acc, thr, signs, consts)
        # counts of acc <= t: -5 crosses all 3; 0 crosses {1,50}; 3 crosses {50}
        assert out.reshape(-1).tolist() == [3, 2, 1, 0]

    def test_const_channel(self):
        acc = jnp.zeros((5, 1), jnp.int32)
        thr = jnp.zeros((1, 15), jnp.int32)
        out = kref.multithreshold_ref(
            acc, thr, jnp.array([0], jnp.int32), jnp.array([7], jnp.int32)
        )
        assert (np.array(out) == 7).all()


class TestVmemFootprint:
    def test_monotonic_in_block(self):
        a = lk.vmem_footprint_bytes(32, 288, 16, block_m=64)
        b = lk.vmem_footprint_bytes(32, 288, 16, block_m=128)
        assert b > a

    def test_fits_vmem_for_all_model_layers(self):
        """Every layer of the exported model must fit the 16 MiB VMEM budget."""
        from compile import model as M

        prog = M.build_program()
        for op in prog:
            if op["op"] != "conv":
                continue
            cin = op["k"] * op["k"] * (1 if op["kind"] == "dw" else op["cin"])
            cout = op["cout"]
            a = 256 if op["in_scale_key"] == "in" else 16
            assert lk.vmem_footprint_bytes(cout, cin, a, 128) < 16 * 2**20, op["name"]
