"""L2 model: program construction, float/int interpreter consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quantize as q


@pytest.fixture(scope="module")
def setup():
    """Random-init model, calibrated on random data (no training needed for
    consistency checks)."""
    program = M.build_program(w_bits=4, a_bits=4)
    rng = jax.random.PRNGKey(42)
    params = M.init_params(rng, program)
    bn_state = M.init_bn_state(program)
    xs = jax.random.uniform(jax.random.PRNGKey(1), (32, M.IMAGE_SIZE, M.IMAGE_SIZE, 3))
    scales = M.calibrate(params, bn_state, program, xs)
    net = M.streamline(params, bn_state, scales, program)
    return program, params, bn_state, scales, net, xs


class TestProgram:
    def test_layer_count(self):
        prog = M.build_program()
        convs = [op for op in prog if op["op"] == "conv"]
        # stem + 4 blocks x 3 + head = 14 convs
        assert len(convs) == 14
        assert convs[0]["w_bits"] == 8  # first layer 8-bit (paper section 4.1)
        assert all(c["w_bits"] == 4 for c in convs[1:])

    def test_dense_is_8bit(self):
        prog = M.build_program()
        dense = [op for op in prog if op["op"] == "dense"]
        assert len(dense) == 1 and dense[0]["w_bits"] == 8

    def test_residual_blocks_share_scale(self):
        prog = M.build_program()
        # each res_add's scale_key equals the block input's scale key
        for i, op in enumerate(prog):
            if op["op"] == "res_add":
                proj = prog[i - 1]
                assert proj["op"] == "conv"
                assert proj["out_scale_key"] == op["scale_key"]

    def test_bitwidth_parameterization(self):
        prog = M.build_program(w_bits=2, a_bits=3)
        convs = [op for op in prog if op["op"] == "conv"]
        assert convs[1]["w_bits"] == 2 and convs[1]["out_bits"] == 3


class TestForwardFloat:
    def test_fp32_shapes(self, setup):
        program, params, bn_state, scales, net, xs = setup
        logits, _ = M.forward_float(
            params, bn_state, None, program, xs, quantized=False
        )
        assert logits.shape == (32, M.NUM_CLASSES)
        assert jnp.isfinite(logits).all()

    def test_quantized_shapes(self, setup):
        program, params, bn_state, scales, net, xs = setup
        logits, _ = M.forward_float(params, bn_state, scales, program, xs)
        assert logits.shape == (32, M.NUM_CLASSES)

    def test_train_updates_bn_state(self, setup):
        program, params, bn_state, scales, net, xs = setup
        _, new_state = M.forward_float(
            params, bn_state, scales, program, xs, train=True
        )
        changed = any(
            not np.allclose(np.array(new_state[k]["mean"]), np.array(bn_state[k]["mean"]))
            for k in bn_state
        )
        assert changed

    def test_eval_does_not_update_bn_state(self, setup):
        program, params, bn_state, scales, net, xs = setup
        _, new_state = M.forward_float(
            params, bn_state, scales, program, xs, train=False
        )
        for k in bn_state:
            assert np.array_equal(np.array(new_state[k]["mean"]), np.array(bn_state[k]["mean"]))


class TestIm2col:
    @pytest.mark.parametrize("k,stride,pad", [(3, 1, 1), (3, 2, 1), (1, 1, 0)])
    def test_matches_float_conv(self, k, stride, pad):
        """Integer im2col + matmul must equal lax conv on the same values."""
        rng = np.random.default_rng(0)
        x = rng.integers(0, 16, (2, 8, 8, 5)).astype(np.int32)
        w = rng.integers(-8, 8, (k, k, 5, 7)).astype(np.int32)
        patches = M.im2col(jnp.array(x), k, stride, pad)  # [N,Ho,Wo,KK,C]
        n, ho, wo, kk, c = patches.shape
        acts = np.array(patches).reshape(n * ho * wo, kk * c)
        w_mat = w.reshape(k * k * 5, 7)  # (tap, channel) minor order
        got = (acts @ w_mat).reshape(n, ho, wo, 7)

        dn = jax.lax.conv_dimension_numbers(
            (2, 8, 8, 5), w.shape, ("NHWC", "HWIO", "NHWC")
        )
        want = jax.lax.conv_general_dilated(
            jnp.array(x, jnp.float32),
            jnp.array(w, jnp.float32),
            (stride, stride),
            ((pad, pad), (pad, pad)),
            dimension_numbers=dn,
        )
        assert (got == np.array(want).astype(np.int64)).all()

    def test_depthwise_layout(self):
        """(tap, channel) -> transpose to [M, C, K] must match manual dw conv."""
        rng = np.random.default_rng(1)
        x = rng.integers(0, 16, (1, 6, 6, 3)).astype(np.int32)
        w = rng.integers(-8, 8, (3, 3, 1, 3)).astype(np.int32)  # HWIO dw
        patches = M.im2col(jnp.array(x), 3, 1, 1)
        n, ho, wo, kk, c = patches.shape
        acts = np.array(patches.transpose(0, 1, 2, 4, 3)).reshape(n * ho * wo, c, kk)
        w_mat = w.reshape(9, 3).T  # [C, K]
        got = (acts * w_mat[None]).sum(axis=2).reshape(ho, wo, c)

        dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
        want = jax.lax.conv_general_dilated(
            jnp.array(x, jnp.float32),
            jnp.array(w, jnp.float32),
            (1, 1),
            ((1, 1), (1, 1)),
            dimension_numbers=dn,
            feature_group_count=3,
        )[0]
        assert (got == np.array(want).astype(np.int64)).all()


class TestStreamline:
    def test_network_structure(self, setup):
        program, params, bn_state, scales, net, xs = setup
        kinds = [op["op"] for op in net.ops]
        assert kinds[0] == "input"
        assert kinds[-1] == "dense"
        assert "res_push" in kinds and "res_add" in kinds
        convs = [op for op in net.ops if op["op"] == "conv"]
        assert len(convs) == 14

    def test_weight_code_ranges(self, setup):
        program, params, bn_state, scales, net, xs = setup
        for op in net.ops:
            if op["op"] != "conv":
                continue
            lo, hi = q.weight_qrange(op["w_bits"])
            assert op["w_codes"].min() >= lo and op["w_codes"].max() <= hi

    def test_threshold_shapes(self, setup):
        program, params, bn_state, scales, net, xs = setup
        for op in net.ops:
            if op["op"] != "conv":
                continue
            levels = 2 ** op["out_bits"] - 1
            assert op["thresholds"].shape == (op["cout"], levels)
            assert op["signs"].shape == (op["cout"],)


class TestIntVsFloatConsistency:
    def test_logits_match(self, setup):
        """Deployed integer network tracks the float QAT forward.

        Exact agreement is only guaranteed between integer paths; the float
        path can round differently at quantizer/threshold boundaries (f32
        conv accumulation vs exact integer accumulation), and one flipped
        code perturbs downstream logits slightly.  Require argmax agreement
        and small logit deviation rather than bit-exactness.
        """
        program, params, bn_state, scales, net, xs = setup
        codes = M.encode_input(xs)
        li = np.array(M.forward_int(net, codes, use_pallas=False))
        lf, _ = M.forward_float(params, bn_state, scales, program, xs, quantized=True)
        lf = np.array(lf)
        assert np.abs(li - lf).max() < 0.5
        agree = (np.argmax(li, 1) == np.argmax(lf, 1)).mean()
        assert agree >= 0.9

    def test_pallas_path_bit_exact(self, setup):
        program, params, bn_state, scales, net, xs = setup
        codes = M.encode_input(xs[:4])
        a = M.forward_int(net, codes, use_pallas=True)
        b = M.forward_int(net, codes, use_pallas=False)
        assert (np.array(a) == np.array(b)).all()

    def test_batch_invariance(self, setup):
        """Per-image results must not depend on batch composition."""
        program, params, bn_state, scales, net, xs = setup
        codes = M.encode_input(xs[:4])
        full = M.forward_int(net, codes, use_pallas=False)
        single = jnp.concatenate(
            [M.forward_int(net, codes[i : i + 1], use_pallas=False) for i in range(4)]
        )
        assert (np.array(full) == np.array(single)).all()


class TestEncodeInput:
    def test_range_and_dtype(self):
        x = jnp.array([[-0.1, 0.0, 0.5, 1.0, 2.0]])
        codes = M.encode_input(x)
        assert codes.dtype == jnp.int32
        assert codes.tolist() == [[0, 0, 128, 255, 255]]
