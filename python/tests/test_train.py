"""Training infrastructure: Adam, loss, eval loops, one smoke run."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import datasets
from compile import model as M
from compile import train as T


class TestAdam:
    def test_descends_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = T.adam_init(params)
        for _ in range(300):
            grads = {"w": 2.0 * params["w"]}  # d/dw of w^2
            params, state = T.adam_update(params, grads, state, lr=0.05)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_bias_correction_first_step(self):
        params = {"w": jnp.array([0.0])}
        state = T.adam_init(params)
        new, state2 = T.adam_update(params, {"w": jnp.array([1.0])}, state, lr=0.1)
        # first step of Adam moves by ~lr regardless of gradient scale
        assert float(new["w"][0]) == pytest.approx(-0.1, rel=1e-3)
        assert state2["t"] == 1

    def test_state_shapes_match_params(self):
        params = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros(7)}}
        state = T.adam_init(params)
        assert state["m"]["a"].shape == (3, 4)
        assert state["v"]["b"]["c"].shape == (7,)


class TestLoss:
    def test_cross_entropy_perfect_prediction(self):
        logits = jnp.array([[100.0, 0.0, 0.0]])
        labels = jnp.array([0])
        assert float(T.cross_entropy(logits, labels)) < 1e-3

    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((4, 10))
        labels = jnp.array([0, 1, 2, 3])
        assert float(T.cross_entropy(logits, labels)) == pytest.approx(np.log(10), rel=1e-5)

    def test_gradient_direction(self):
        labels = jnp.array([1])
        g = jax.grad(lambda l: T.cross_entropy(l, labels))(jnp.zeros((1, 3)))
        assert float(g[0, 1]) < 0  # pushing the true class up reduces loss
        assert float(g[0, 0]) > 0


class TestEvalLoops:
    @pytest.fixture(scope="class")
    def tiny(self):
        data = datasets.make_dataset(n_train=128, n_test=64, seed=11)
        program = M.build_program()
        params = M.init_params(jax.random.PRNGKey(0), program)
        bn = M.init_bn_state(program)
        scales = M.calibrate(params, bn, program, jnp.asarray(data[0][:64]))
        return data, program, params, bn, scales

    def test_evaluate_float_bounds(self, tiny):
        data, program, params, bn, scales = tiny
        acc = T.evaluate_float(params, bn, scales, program, data[2], data[3], True)
        assert 0.0 <= acc <= 1.0

    def test_evaluate_int_matches_manual(self, tiny):
        data, program, params, bn, scales = tiny
        net = M.streamline(params, bn, scales, program)
        acc = T.evaluate_int(net, data[2][:32], data[3][:32])
        logits = M.forward_int(net, M.encode_input(jnp.asarray(data[2][:32])), use_pallas=False)
        manual = float((jnp.argmax(logits, 1) == jnp.asarray(data[3][:32])).mean())
        assert acc == pytest.approx(manual)


@pytest.mark.slow
class TestSmokeTraining:
    def test_short_run_beats_chance(self):
        data = datasets.make_dataset(n_train=512, n_test=64, seed=5)
        r = T.train_model(4, 4, epochs_fp=6, epochs_qat=1, data=data, verbose=False)
        # ~48 optimizer steps on the synthetic task: well above 10% chance
        assert r["acc_fp32"] > 0.3
        assert 0.0 <= r["acc_int"] <= 1.0
        assert set(r) >= {"params", "bn_state", "scales", "net"}
