"""Synthetic dataset properties: determinism, balance, learnability signal."""

import numpy as np
import pytest

from compile import datasets


@pytest.fixture(scope="module")
def data():
    return datasets.make_dataset(n_train=256, n_test=128, seed=3)


class TestShapesAndRanges:
    def test_shapes(self, data):
        xtr, ytr, xte, yte = data
        assert xtr.shape == (256, 16, 16, 3)
        assert xte.shape == (128, 16, 16, 3)
        assert ytr.shape == (256,) and yte.shape == (128,)

    def test_value_range(self, data):
        xtr, *_ = data
        assert float(xtr.min()) >= 0.0 and float(xtr.max()) <= 1.0

    def test_labels_in_range(self, data):
        _, ytr, _, yte = data
        for y in (ytr, yte):
            assert y.min() >= 0 and y.max() < datasets.NUM_CLASSES


class TestDistribution:
    def test_class_balance(self, data):
        _, ytr, _, _ = data
        counts = np.bincount(ytr, minlength=10)
        assert counts.min() >= len(ytr) // 10 - 1

    def test_deterministic(self):
        a = datasets.make_dataset(n_train=64, n_test=32, seed=7)
        b = datasets.make_dataset(n_train=64, n_test=32, seed=7)
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])

    def test_seed_changes_data(self):
        a = datasets.make_dataset(n_train=64, n_test=32, seed=1)
        b = datasets.make_dataset(n_train=64, n_test=32, seed=2)
        assert not np.array_equal(a[0], b[0])

    def test_train_test_disjoint(self, data):
        xtr, _, xte, _ = data
        # no test image should be bit-identical to a train image
        tr = {xtr[i].tobytes() for i in range(len(xtr))}
        dupes = sum(1 for i in range(len(xte)) if xte[i].tobytes() in tr)
        assert dupes == 0


class TestLearnability:
    def test_classes_are_separable_by_template_correlation(self, data):
        """A nearest-class-mean classifier on raw pixels must beat chance
        by a wide margin — the dataset carries class signal."""
        xtr, ytr, xte, yte = data
        means = np.stack([xtr[ytr == c].mean(0).reshape(-1) for c in range(10)])
        feats = xte.reshape(len(xte), -1)
        pred = np.argmax(feats @ means.T - 0.5 * (means**2).sum(1), axis=1)
        acc = (pred == yte).mean()
        assert acc > 0.3, f"nearest-mean acc {acc} barely above chance"

    def test_noise_present(self, data):
        """Samples of one class differ (augmentation/noise), so the task
        is not pure memorization."""
        xtr, ytr, *_ = data
        idx = np.where(ytr == 0)[0][:2]
        assert not np.allclose(xtr[idx[0]], xtr[idx[1]])
