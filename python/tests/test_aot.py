"""AOT pipeline: HLO text emission and checkpoint round-trip."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def small_net():
    program = M.build_program(w_bits=4, a_bits=4)
    rng = jax.random.PRNGKey(7)
    params = M.init_params(rng, program)
    bn_state = M.init_bn_state(program)
    xs = jax.random.uniform(jax.random.PRNGKey(2), (16, M.IMAGE_SIZE, M.IMAGE_SIZE, 3))
    scales = M.calibrate(params, bn_state, program, xs)
    return M.streamline(params, bn_state, scales, program), params, bn_state, scales


class TestHloEmission:
    def test_lower_batch1(self, small_net):
        net, *_ = small_net
        text = aot.lower_int_model(net, 1)
        assert text.startswith("HloModule")
        # input parameter shape embedded in the module
        assert "s32[1,16,16,3]" in text
        # output tuple of f32 logits
        assert "f32[1,10]" in text

    def test_weights_are_constants(self, small_net):
        """The lowered module must be self-contained (weights baked in) so the
        Rust runtime needs only the activation input."""
        net, *_ = small_net
        text = aot.lower_int_model(net, 1)
        # entry layout lists exactly one input operand (the activation codes)
        header = text.splitlines()[0]
        assert "entry_computation_layout={(s32[1,16,16,3]" in header
        assert header.count("s32[1,16,16,3]") == 1


class TestNetworkJson:
    def test_roundtrip(self, small_net, tmp_path):
        net, *_ = small_net
        path = tmp_path / "network.json"
        aot.export_network_json(net, str(path), extra_meta={"k": 1})
        loaded = json.loads(path.read_text())
        assert loaded["meta"]["k"] == 1
        assert loaded["meta"]["image_size"] == M.IMAGE_SIZE
        convs = [op for op in loaded["ops"] if op["op"] == "conv"]
        assert len(convs) == 14
        # arrays serialised as nested lists
        assert isinstance(convs[0]["w_codes"][0], list)


class TestCheckpoint:
    def test_roundtrip(self, small_net, tmp_path):
        _, params, bn_state, scales = small_net
        path = tmp_path / "ckpt.npz"
        aot.save_checkpoint(str(path), params, bn_state, scales)
        p2, b2, s2 = aot.load_checkpoint(str(path))
        assert s2 == scales
        for name in params:
            for k in params[name]:
                assert np.array_equal(np.array(params[name][k]), np.array(p2[name][k]))
