"""Quantization-aware training (paper section 3.6) + the Figure 2 sweep.

Three-phase recipe (standard QAT practice, matching the paper's flow of
"train in our quantization-aware training framework"):

  A. fp32 pre-training (also yields the fp32 baseline point of Figure 2);
  B. activation-scale calibration on the trained float network;
  C. QAT fine-tuning with STE fake-quantization at the target bit-width.

After training, the network is streamlined to the deployed integer form and
the *deployed* accuracy (the one a bitstream would achieve) is reported.

No optax on this image, so Adam is implemented inline.
"""

from __future__ import annotations

import functools
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from . import model as M

# ---------------------------------------------------------------------------
# Optimizer (Adam)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def _make_train_step(program, scales, quantized: bool):
    def loss_fn(params, bn_state, xs, ys):
        logits, new_state = M.forward_float(
            params, bn_state, scales, program, xs, train=True, quantized=quantized
        )
        return cross_entropy(logits, ys), new_state

    @jax.jit
    def step(params, bn_state, opt_state, lr, xs, ys):
        (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, bn_state, xs, ys
        )
        params, opt_state = adam_update(params, grads, opt_state, lr)
        return params, new_state, opt_state, loss

    return step


def evaluate_float(params, bn_state, scales, program, xs, ys, quantized, batch=256):
    @jax.jit
    def fwd(xb):
        logits, _ = M.forward_float(
            params, bn_state, scales, program, xb, train=False, quantized=quantized
        )
        return logits

    correct = 0
    for i in range(0, len(xs), batch):
        logits = fwd(xs[i : i + batch])
        correct += int((jnp.argmax(logits, 1) == ys[i : i + batch]).sum())
    return correct / len(xs)


def evaluate_int(net: M.IntNetwork, xs, ys, use_pallas=False, batch=256):
    """Deployed integer-network accuracy (the Figure 2 y-axis)."""

    @jax.jit
    def fwd(codes):
        return M.forward_int(net, codes, use_pallas=use_pallas)

    correct = 0
    for i in range(0, len(xs), batch):
        codes = M.encode_input(jnp.asarray(xs[i : i + batch]))
        logits = fwd(codes)
        correct += int((jnp.argmax(logits, 1) == ys[i : i + batch]).sum())
    return correct / len(xs)


# ---------------------------------------------------------------------------
# Training driver
# ---------------------------------------------------------------------------


def _epochs(step_fn, params, bn_state, opt_state, xs, ys, epochs, batch, lr0, seed):
    rng = np.random.default_rng(seed)
    n = len(xs)
    steps_per_epoch = n // batch
    total = max(epochs * steps_per_epoch, 1)
    i = 0
    last = None
    for _ in range(epochs):
        perm = rng.permutation(n)
        for s in range(steps_per_epoch):
            idx = perm[s * batch : (s + 1) * batch]
            # cosine decay
            lr = lr0 * 0.5 * (1 + np.cos(np.pi * i / total))
            params, bn_state, opt_state, last = step_fn(
                params, bn_state, opt_state, lr, jnp.asarray(xs[idx]), jnp.asarray(ys[idx])
            )
            i += 1
    return params, bn_state, opt_state, last


def train_model(
    w_bits: int = 4,
    a_bits: int = 4,
    *,
    epochs_fp: int = 15,
    epochs_qat: int = 12,
    batch: int = 64,
    lr_fp: float = 3e-3,
    lr_qat: float = 1e-3,
    seed: int = 0,
    data=None,
    verbose: bool = True,
) -> dict[str, Any]:
    """Full A/B/C recipe at one bit-width. Returns params, states and metrics."""
    t0 = time.time()
    if data is None:
        data = datasets.make_dataset(seed=seed)
    x_train, y_train, x_test, y_test = data
    program = M.build_program(w_bits=w_bits, a_bits=a_bits)
    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, program)
    bn_state = M.init_bn_state(program)

    # Phase A: fp32 pre-training
    step_fp = _make_train_step(program, None, quantized=False)
    opt = adam_init(params)
    params, bn_state, opt, _ = _epochs(
        step_fp, params, bn_state, opt, x_train, y_train, epochs_fp, batch, lr_fp, seed
    )
    acc_fp32 = evaluate_float(params, bn_state, None, program, x_test, y_test, False)

    # Phase B: calibration
    scales = M.calibrate(params, bn_state, program, jnp.asarray(x_train[:256]))

    # Phase C: QAT fine-tune
    step_q = _make_train_step(program, scales, quantized=True)
    opt = adam_init(params)
    params, bn_state, opt, _ = _epochs(
        step_q, params, bn_state, opt, x_train, y_train, epochs_qat, batch, lr_qat, seed + 1
    )
    acc_qat = evaluate_float(params, bn_state, scales, program, x_test, y_test, True)

    # Streamline + deployed accuracy
    net = M.streamline(params, bn_state, scales, program)
    acc_int = evaluate_int(net, x_test, y_test, use_pallas=False)

    if verbose:
        print(
            f"W{w_bits}A{a_bits}: fp32={acc_fp32:.4f} qat={acc_qat:.4f} "
            f"deployed={acc_int:.4f}  ({time.time() - t0:.1f}s)"
        )
    return {
        "params": params,
        "bn_state": bn_state,
        "scales": scales,
        "program": program,
        "net": net,
        "acc_fp32": acc_fp32,
        "acc_qat": acc_qat,
        "acc_int": acc_int,
        "data": data,
    }


def run_fig2_sweep(
    bit_widths=(1, 2, 3, 4, 5, 6, 8),
    *,
    epochs_fp: int = 15,
    epochs_qat: int = 12,
    seed: int = 0,
) -> dict[str, Any]:
    """Figure 2 data: deployed accuracy + LUTs/multiplication per bit-width.

    LUT count per n-bit multiplication follows Eq. (3) of the paper:
    ``2n * 2^n / 64`` (with a floor of 1 physical LUT6 at n <= 2; the
    paper's Figure 2 plots the same floor — output bits of small LUTs are
    the limiting factor).
    """
    data = datasets.make_dataset(seed=seed)
    results = {"bits": [], "acc_int": [], "acc_qat": [], "acc_fp32": None, "luts_per_mul": []}
    for b in bit_widths:
        r = train_model(
            b, b, epochs_fp=epochs_fp, epochs_qat=epochs_qat, seed=seed, data=data
        )
        if results["acc_fp32"] is None:
            results["acc_fp32"] = r["acc_fp32"]
        results["bits"].append(b)
        results["acc_int"].append(r["acc_int"])
        results["acc_qat"].append(r["acc_qat"])
        results["luts_per_mul"].append(max(2 * b * (2**b) / 64.0, 1.0))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true", help="run the Figure 2 sweep")
    ap.add_argument("--out", default=None)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=4)
    ap.add_argument("--epochs-fp", type=int, default=15)
    ap.add_argument("--epochs-qat", type=int, default=12)
    args = ap.parse_args()
    if args.sweep:
        res = run_fig2_sweep(epochs_fp=args.epochs_fp, epochs_qat=args.epochs_qat)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        print(json.dumps(res, indent=2))
    else:
        train_model(
            args.w_bits,
            args.a_bits,
            epochs_fp=args.epochs_fp,
            epochs_qat=args.epochs_qat,
        )
