"""L2 — Quantized MobileNetV2 (paper sections 3.2-3.6).

One architecture *program* (a list of op dicts) drives three interpreters:

  * ``forward_float``  — QAT training/eval forward: float convs with
    fake-quantized (STE) weights and activations, batch-norm, residual
    adds. Used for training and for the fp32 baseline (``quantized=False``).
  * ``streamline``     — converts trained float params into the deployed
    integer network (weight codes + multi-threshold units), the analog of
    the paper's ONNX -> streamlining -> HLS step.
  * ``forward_int``    — deployed integer forward over activation codes,
    using the Pallas LUTMUL kernels (or the jnp oracle).  This is the
    golden model the Rust dataflow simulator must match bit-exactly, and
    the function AOT-lowered to HLO for the Rust PJRT runtime.

The network is a scaled-down MobileNetV2: stem conv, four inverted-residual
blocks (expand 1x1 -> depthwise 3x3 -> project 1x1, residual where
stride=1 and shapes match), head 1x1 conv, global pooling, linear
classifier.  First and last layers are 8-bit, the rest W{w}A{a} per the
paper (default W4A4, channel-wise weight quantization).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as q
from .kernels import lutmul as lk
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Architecture program
# ---------------------------------------------------------------------------

IMAGE_SIZE = 16
IN_CH = 3
NUM_CLASSES = 10

# (expand_ratio, out_ch, stride, residual)
_IR_BLOCKS = [
    (2, 24, 2, False),
    (2, 24, 1, True),
    (2, 32, 2, False),
    (2, 32, 1, True),
]
_STEM_CH = 16
_HEAD_CH = 64


def build_program(
    w_bits: int = 4,
    a_bits: int = 4,
    image_size: int = IMAGE_SIZE,
    num_classes: int = NUM_CLASSES,
) -> list[dict[str, Any]]:
    """Build the op program for MobileNetV2-small at the given bit-widths.

    First (stem) and last (classifier) layers are 8-bit weights; input is
    8-bit; everything else is W{w_bits}A{a_bits} (paper section 4.1).
    """
    prog: list[dict[str, Any]] = []
    prog.append({"op": "input", "bits": 8, "scale_key": "in"})

    def conv(name, kind, cin, cout, k, stride, wb, out_bits, in_key, out_key):
        prog.append(
            {
                "op": "conv",
                "name": name,
                "kind": kind,
                "cin": cin,
                "cout": cout,
                "k": k,
                "stride": stride,
                "pad": (k - 1) // 2,
                "w_bits": wb,
                "out_bits": out_bits,
                "in_scale_key": in_key,
                "out_scale_key": out_key,
            }
        )

    conv("stem", "std", IN_CH, _STEM_CH, 3, 1, 8, a_bits, "in", "stem_out")
    cin, in_key = _STEM_CH, "stem_out"
    for bi, (exp, cout, stride, res) in enumerate(_IR_BLOCKS):
        mid = cin * exp
        n = f"ir{bi}"
        if res:
            # Residual blocks share the activation scale across the block
            # input, the project output, and the sum, so the residual join
            # is an exact saturating integer add (DESIGN.md).
            out_key = in_key
            prog.append({"op": "res_push"})
        else:
            out_key = f"{n}_out"
        conv(f"{n}_exp", "pw", cin, mid, 1, 1, w_bits, a_bits, in_key, f"{n}_mid1")
        conv(f"{n}_dw", "dw", mid, mid, 3, stride, w_bits, a_bits, f"{n}_mid1", f"{n}_mid2")
        conv(f"{n}_proj", "pw", mid, cout, 1, 1, w_bits, a_bits, f"{n}_mid2", out_key)
        if res:
            prog.append({"op": "res_add", "scale_key": out_key, "bits": a_bits})
        cin, in_key = cout, out_key
    conv("head", "pw", cin, _HEAD_CH, 1, 1, w_bits, a_bits, in_key, "head_out")
    prog.append({"op": "pool_sum"})
    prog.append(
        {
            "op": "dense",
            "name": "fc",
            "cin": _HEAD_CH,
            "cout": num_classes,
            "w_bits": 8,
            "in_scale_key": "head_out",
        }
    )
    return prog


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(rng: jax.Array, program: list[dict]) -> dict:
    """He-init conv/dense weights + identity batch-norm per conv."""
    params: dict[str, Any] = {}
    for op in program:
        if op["op"] == "conv":
            k, cin, cout, kind = op["k"], op["cin"], op["cout"], op["kind"]
            rng, sub = jax.random.split(rng)
            if kind == "dw":
                shape = (k, k, 1, cout)  # feature_group_count = cout
                fan_in = k * k
            else:
                shape = (k, k, cin, cout)
                fan_in = k * k * cin
            w = jax.random.normal(sub, shape, jnp.float32) * np.sqrt(2.0 / fan_in)
            params[op["name"]] = {
                "w": w,
                "gamma": jnp.ones((cout,), jnp.float32),
                "beta": jnp.zeros((cout,), jnp.float32),
            }
        elif op["op"] == "dense":
            rng, sub = jax.random.split(rng)
            w = jax.random.normal(
                sub, (op["cin"], op["cout"]), jnp.float32
            ) * np.sqrt(1.0 / op["cin"])
            params[op["name"]] = {"w": w, "b": jnp.zeros((op["cout"],), jnp.float32)}
    return params


def init_bn_state(program: list[dict]) -> dict:
    state = {}
    for op in program:
        if op["op"] == "conv":
            state[op["name"]] = {
                "mean": jnp.zeros((op["cout"],), jnp.float32),
                "var": jnp.ones((op["cout"],), jnp.float32),
            }
    return state


# ---------------------------------------------------------------------------
# Float (training) interpreter
# ---------------------------------------------------------------------------

_BN_EPS = 1e-5
_BN_MOMENTUM = 0.9


def _conv_float(x, w, kind, stride, pad):
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    groups = w.shape[3] if kind == "dw" else 1
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=dn,
        feature_group_count=groups,
    )


def forward_float(
    params: dict,
    bn_state: dict,
    scales: dict | None,
    program: list[dict],
    x: jnp.ndarray,
    *,
    train: bool = False,
    quantized: bool = True,
    record: dict | None = None,
):
    """Float-domain forward pass.

    Args:
      scales: activation-scale dict (``scale_key`` -> float); may be None
        only when ``quantized=False`` (fp32 baseline / calibration pass).
      train: use batch statistics and return an updated ``bn_state``.
      quantized: apply STE fake-quantization to weights and activations.
      record: if given, activations are appended per scale_key
        (calibration pass).

    Returns:
      (logits, new_bn_state)
    """
    new_state = dict(bn_state)
    res_stack: list[jnp.ndarray] = []

    def maybe_record(key, t):
        if record is not None:
            record.setdefault(key, []).append(t)

    for op in program:
        kind = op["op"]
        if kind == "input":
            maybe_record(op["scale_key"], x)
            if quantized:
                x = q.quantize_act(x, scales[op["scale_key"]], op["bits"])
        elif kind == "conv":
            p = params[op["name"]]
            w = q.quantize_weight(p["w"], op["w_bits"], channel_axis=3) if quantized else p["w"]
            x = _conv_float(x, w, op["kind"], op["stride"], op["pad"])
            if train:
                mean = x.mean(axis=(0, 1, 2))
                var = x.var(axis=(0, 1, 2))
                new_state[op["name"]] = {
                    "mean": _BN_MOMENTUM * bn_state[op["name"]]["mean"]
                    + (1 - _BN_MOMENTUM) * mean,
                    "var": _BN_MOMENTUM * bn_state[op["name"]]["var"]
                    + (1 - _BN_MOMENTUM) * var,
                }
            else:
                mean = bn_state[op["name"]]["mean"]
                var = bn_state[op["name"]]["var"]
            x = (x - mean) / jnp.sqrt(var + _BN_EPS) * p["gamma"] + p["beta"]
            maybe_record(op["out_scale_key"], x)
            if quantized:
                x = q.quantize_act(x, scales[op["out_scale_key"]], op["out_bits"])
            else:
                x = jax.nn.relu(x)  # fp32 baseline: quantizer's clamp-at-0 analog
        elif kind == "res_push":
            res_stack.append(x)
        elif kind == "res_add":
            x = x + res_stack.pop()
            maybe_record(op["scale_key"], x)
            if quantized:
                # Saturating re-quantization at the shared scale: the exact
                # float-domain image of the integer clamp(a1+a2, 0, 2^b-1).
                x = q.quantize_act(x, scales[op["scale_key"]], op["bits"])
        elif kind == "pool_sum":
            x = x.sum(axis=(1, 2))
        elif kind == "dense":
            p = params[op["name"]]
            w = q.quantize_weight(p["w"], op["w_bits"], channel_axis=1) if quantized else p["w"]
            n_px = _head_pixels()
            x = (x / n_px) @ w + p["b"]
        else:
            raise ValueError(f"unknown op {kind}")
    return x, new_state


def _head_pixels() -> int:
    """Spatial positions at the head (two stride-2 stages from IMAGE_SIZE)."""
    side = IMAGE_SIZE // 4
    return side * side


def calibrate(params, bn_state, program, xs) -> dict:
    """Fix activation scales from a float forward pass (percentile max)."""
    record: dict[str, list] = {}
    forward_float(
        params, bn_state, None, program, xs, train=False, quantized=False, record=record
    )
    scales = {}
    for op in program:
        key = op.get("scale_key") or op.get("out_scale_key")
        bits = op.get("bits") or op.get("out_bits")
        if key is None or key not in record or key in scales:
            continue
        stacked = jnp.concatenate([t.reshape(-1) for t in record[key]])
        if key == "in":
            scales[key] = 1.0 / 255.0  # input images are exact uint8 codes
        else:
            scales[key] = q.calibrate_scale(stacked, bits)
    return scales


# ---------------------------------------------------------------------------
# Streamlining: float params -> deployed integer network
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IntNetwork:
    """Deployed integer network: the exact program the Rust simulator runs."""

    meta: dict
    ops: list[dict]  # integer ops with numpy arrays attached


def streamline(params, bn_state, scales, program) -> IntNetwork:
    """Absorb weight/activation scales and BN into weight codes +
    multi-threshold units (paper section 3.2 / Umuroglu & Jahre 2017)."""
    ops: list[dict] = []
    for op in program:
        if op["op"] == "input":
            ops.append({"op": "input", "bits": op["bits"], "scale": float(scales["in"])})
        elif op["op"] == "conv":
            p = params[op["name"]]
            # weight codes, per-output-channel scale (channel axis 3 = OUT)
            codes, s_w = q.weight_codes(p["w"], op["w_bits"], channel_axis=3)
            k, kind = op["k"], op["kind"]
            if kind == "dw":
                w_mat = np.array(codes).reshape(k * k, op["cout"]).T  # [C, K]
            else:
                w_mat = (
                    np.array(codes).reshape(k * k * op["cin"], op["cout"]).T
                )  # [COUT, K*K*CIN], (tap, channel) minor order
            bn = q.BatchNormParams(
                gamma=p["gamma"],
                beta=p["beta"],
                mean=bn_state[op["name"]]["mean"],
                var=bn_state[op["name"]]["var"],
                eps=_BN_EPS,
            )
            thr, signs, consts = q.streamline_thresholds(
                s_w.reshape(-1),
                float(scales[op["in_scale_key"]]),
                bn,
                float(scales[op["out_scale_key"]]),
                op["out_bits"],
            )
            ops.append(
                {
                    "op": "conv",
                    "name": op["name"],
                    "kind": kind,
                    "cin": op["cin"],
                    "cout": op["cout"],
                    "k": k,
                    "stride": op["stride"],
                    "pad": op["pad"],
                    "w_bits": op["w_bits"],
                    "in_bits": _in_bits(program, op),
                    "out_bits": op["out_bits"],
                    "w_codes": w_mat.astype(np.int32),
                    "thresholds": np.array(thr, np.int32),
                    "signs": np.array(signs, np.int32),
                    "consts": np.array(consts, np.int32),
                    "out_scale": float(scales[op["out_scale_key"]]),
                }
            )
        elif op["op"] == "res_push":
            ops.append({"op": "res_push"})
        elif op["op"] == "res_add":
            ops.append({"op": "res_add", "bits": op["bits"]})
        elif op["op"] == "pool_sum":
            ops.append({"op": "pool_sum"})
        elif op["op"] == "dense":
            p = params[op["name"]]
            codes, s_w = q.weight_codes(p["w"], op["w_bits"], channel_axis=1)
            scale = (
                np.array(s_w).reshape(-1)
                * float(scales[op["in_scale_key"]])
                / _head_pixels()
            )
            ops.append(
                {
                    "op": "dense",
                    "name": op["name"],
                    "cin": op["cin"],
                    "cout": op["cout"],
                    "w_bits": op["w_bits"],
                    "w_codes": np.array(codes, np.int32),  # [CIN, COUT]
                    "scale": scale.astype(np.float32),
                    "bias": np.array(p["b"], np.float32),
                }
            )
    meta = {
        "image_size": IMAGE_SIZE,
        "in_ch": IN_CH,
        "num_classes": NUM_CLASSES,
        "in_scale": float(scales["in"]),
    }
    return IntNetwork(meta=meta, ops=ops)


def _in_bits(program, conv_op) -> int:
    key = conv_op["in_scale_key"]
    for op in program:
        if op.get("scale_key") == key and op["op"] == "input":
            return op["bits"]
        if op.get("out_scale_key") == key and op["op"] == "conv":
            return op["out_bits"]
        if op.get("scale_key") == key and op["op"] == "res_add":
            return op["bits"]
    return 4


# ---------------------------------------------------------------------------
# Integer (deployed) interpreter — the golden model
# ---------------------------------------------------------------------------


def im2col(x: jnp.ndarray, k: int, stride: int, pad: int) -> jnp.ndarray:
    """[N, H, W, C] codes -> [N, Ho, Wo, K*K, C] patches, (tap, channel) order.

    Zero padding is exact for unsigned activation codes (code 0 == value 0).
    """
    n, _, _, c = x.shape
    x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    h, w = x.shape[1], x.shape[2]
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    cols = []
    for i in range(k):
        for j in range(k):
            cols.append(
                jax.lax.slice(
                    x,
                    (0, i, j, 0),
                    (n, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    return jnp.stack(cols, axis=3)  # [N, Ho, Wo, K*K, C]


def forward_int(
    net: IntNetwork, codes: jnp.ndarray, *, use_pallas: bool = True, block_m: int = 128
) -> jnp.ndarray:
    """Deployed integer forward over uint8 input codes [N, H, W, C].

    Bit-exact specification of the accelerator: the Rust dataflow simulator
    must reproduce these activations/logits exactly.
    """
    x = codes.astype(jnp.int32)
    res_stack: list[jnp.ndarray] = []
    logits = None
    for op in net.ops:
        kind = op["op"]
        if kind == "input":
            pass  # input is already integer codes
        elif kind == "conv":
            n = x.shape[0]
            k, stride, pad = op["k"], op["stride"], op["pad"]
            patches = im2col(x, k, stride, pad)  # [N,Ho,Wo,KK,C]
            _, ho, wo, kk, c = patches.shape
            w_codes = jnp.asarray(op["w_codes"])
            if op["kind"] == "dw":
                acts = patches.transpose(0, 1, 2, 4, 3).reshape(n * ho * wo, c, kk)
                table = kref.build_table(w_codes, op["in_bits"])  # [C, K, A]
                acc = (
                    lk.lutmul_depthwise(acts, table, block_m=block_m)
                    if use_pallas
                    else kref.lutmul_depthwise_ref(acts, table)
                )
                cout = c
            else:
                acts = patches.reshape(n * ho * wo, kk * c)
                table = kref.build_table(w_codes, op["in_bits"])  # [COUT, KK*C, A]
                acc = (
                    lk.lutmul_matmul(acts, table, block_m=block_m)
                    if use_pallas
                    else kref.lutmul_matmul_ref(acts, table)
                )
                cout = op["cout"]
            out = kref.multithreshold_ref(
                acc,
                jnp.asarray(op["thresholds"]),
                jnp.asarray(op["signs"]),
                jnp.asarray(op["consts"]),
            )
            x = out.reshape(n, ho, wo, cout)
        elif kind == "res_push":
            res_stack.append(x)
        elif kind == "res_add":
            lim = 2 ** op["bits"] - 1
            x = jnp.clip(x + res_stack.pop(), 0, lim)
        elif kind == "pool_sum":
            x = x.sum(axis=(1, 2))
        elif kind == "dense":
            acc = x.astype(jnp.int32) @ jnp.asarray(op["w_codes"])
            logits = acc.astype(jnp.float32) * jnp.asarray(op["scale"]) + jnp.asarray(
                op["bias"]
            )
        else:
            raise ValueError(kind)
    assert logits is not None
    return logits


def encode_input(x: jnp.ndarray) -> jnp.ndarray:
    """Float [0,1] images -> uint8 activation codes (scale 1/255)."""
    return jnp.clip(jnp.round(x * 255.0), 0, 255).astype(jnp.int32)
