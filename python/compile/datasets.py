"""Synthetic structured image-classification dataset.

Stand-in for ImageNet (repro band 0/5: no dataset access, and 420-epoch
MobileNetV2 QAT is out of scope on this testbed).  The dataset is designed
so the *shape* of the paper's Figure 2 reproduces: classes are separated by
fine-grained texture (oriented colour gratings with per-sample translation,
amplitude jitter, and additive noise), so 1-2-bit quantization collapses
accuracy while 4-bit is close to fp32.
"""

from __future__ import annotations

import numpy as np

IMAGE_SIZE = 16
NUM_CLASSES = 10
CHANNELS = 3


def make_dataset(
    n_train: int = 2048,
    n_test: int = 512,
    image_size: int = IMAGE_SIZE,
    n_classes: int = NUM_CLASSES,
    seed: int = 0,
):
    """Returns (x_train, y_train, x_test, y_test); images float32 in [0, 1]."""
    rng = np.random.default_rng(seed)
    gratings_per_class = 2

    # Class-defining gratings: frequency, orientation, per-channel phase.
    freq = rng.uniform(0.6, 2.2, (n_classes, CHANNELS, gratings_per_class))
    theta = rng.uniform(0.0, np.pi, (n_classes, CHANNELS, gratings_per_class))
    base_phase = rng.uniform(0.0, 2 * np.pi, (n_classes, CHANNELS, gratings_per_class))
    amp = rng.uniform(0.5, 1.0, (n_classes, CHANNELS, gratings_per_class))

    yy, xx = np.meshgrid(
        np.arange(image_size, dtype=np.float32),
        np.arange(image_size, dtype=np.float32),
        indexing="ij",
    )

    def sample(cls: int, r: np.random.Generator) -> np.ndarray:
        img = np.zeros((image_size, image_size, CHANNELS), np.float32)
        # Random translation realised as a phase shift of each grating.
        dx, dy = r.uniform(-3, 3, 2)
        jitter = r.uniform(0.75, 1.25)
        for c in range(CHANNELS):
            acc = np.zeros((image_size, image_size), np.float32)
            for g in range(gratings_per_class):
                f = freq[cls, c, g] * 2 * np.pi / image_size
                kx = f * np.cos(theta[cls, c, g])
                ky = f * np.sin(theta[cls, c, g])
                ph = base_phase[cls, c, g] + kx * dx + ky * dy
                acc += amp[cls, c, g] * np.sin(kx * xx + ky * yy + ph)
            img[:, :, c] = acc * jitter
        img += r.normal(0.0, 0.25, img.shape).astype(np.float32)
        # Normalise to [0, 1].
        img = (img - img.min()) / max(img.max() - img.min(), 1e-6)
        return img

    def build(n: int, seed2: int):
        r = np.random.default_rng(seed2)
        xs = np.empty((n, image_size, image_size, CHANNELS), np.float32)
        ys = np.empty((n,), np.int32)
        for i in range(n):
            cls = i % n_classes
            xs[i] = sample(cls, r)
            ys[i] = cls
        perm = r.permutation(n)
        return xs[perm], ys[perm]

    x_train, y_train = build(n_train, seed + 1)
    x_test, y_test = build(n_test, seed + 2)
    return x_train, y_train, x_test, y_test
