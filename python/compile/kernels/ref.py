"""Pure-jnp oracles for the LUTMUL kernels.

These are the correctness references for the Pallas kernels in
``lutmul.py``: a direct table *gather* implementation of Algorithm 1 of the
paper (``mul[co][ci] = lut[co][ci][input[ci]]`` followed by an accumulate
over ``ci``).  All arithmetic is exact integer arithmetic, so the Pallas
kernels are required to match these bit-for-bit (``==``, not allclose).
"""

from __future__ import annotations

import jax.numpy as jnp


def build_table(w_codes: jnp.ndarray, a_bits: int) -> jnp.ndarray:
    """Precompute the weight x activation product table (the "LUT INIT").

    Args:
      w_codes: integer weight codes, shape ``[COUT, CIN]`` (signed, two's
        complement range for the weight bit-width).
      a_bits: activation bit-width; activations are unsigned codes in
        ``[0, 2**a_bits)`` (the paper uses uint4 activations).

    Returns:
      ``table[co, ci, a] = w_codes[co, ci] * a`` with shape
      ``[COUT, CIN, 2**a_bits]``, dtype int32.
    """
    acts = jnp.arange(2**a_bits, dtype=jnp.int32)
    return w_codes.astype(jnp.int32)[:, :, None] * acts[None, None, :]


def lutmul_matmul_ref(acts: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Reference LUT-based matrix multiply (Algorithm 1).

    Args:
      acts: activation codes, shape ``[M, CIN]``, values in ``[0, A)``.
      table: product table, shape ``[COUT, CIN, A]``.

    Returns:
      accumulator ``out[m, co] = sum_ci table[co, ci, acts[m, ci]]``,
      shape ``[M, COUT]``, dtype int32.
    """
    m, cin = acts.shape
    cout, cin2, _ = table.shape
    assert cin == cin2, (acts.shape, table.shape)
    # Gather per (m, co, ci): table[co, ci, acts[m, ci]].
    idx = acts.astype(jnp.int32)[:, None, :]            # [M, 1, CIN]
    gathered = jnp.take_along_axis(
        table.astype(jnp.int32)[None],                   # [1, COUT, CIN, A]
        jnp.broadcast_to(idx[:, :, :, None], (m, cout, cin, 1)),
        axis=3,
    )[..., 0]                                            # [M, COUT, CIN]
    return gathered.sum(axis=2).astype(jnp.int32)


def lutmul_depthwise_ref(acts: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Reference depthwise LUT multiply.

    Args:
      acts: activation codes, shape ``[M, C, K]`` (K = kernel taps).
      table: product table, shape ``[C, K, A]``.

    Returns:
      ``out[m, c] = sum_k table[c, k, acts[m, c, k]]``, shape ``[M, C]``.
    """
    m, c, k = acts.shape
    c2, k2, _ = table.shape
    assert (c, k) == (c2, k2), (acts.shape, table.shape)
    gathered = jnp.take_along_axis(
        table.astype(jnp.int32)[None],                   # [1, C, K, A]
        acts.astype(jnp.int32)[:, :, :, None],           # [M, C, K, 1]
        axis=3,
    )[..., 0]                                            # [M, C, K]
    return gathered.sum(axis=2).astype(jnp.int32)


def multithreshold_ref(
    acc: jnp.ndarray,
    thresholds: jnp.ndarray,
    signs: jnp.ndarray,
    consts: jnp.ndarray,
) -> jnp.ndarray:
    """Reference multi-threshold activation unit (FINN-style streamlining).

    Args:
      acc: integer accumulators, shape ``[M, C]``.
      thresholds: per-channel ascending thresholds, shape ``[C, L]``
        (L = 2**out_bits - 1).
      signs: per-channel comparison direction, shape ``[C]``; +1 compares
        ``acc >= T`` (positive BN gain), -1 compares ``acc <= T`` (negative
        gain), 0 means the channel is constant.
      consts: per-channel constant codes used when ``signs == 0``.

    Returns:
      output codes in ``[0, L]``, shape ``[M, C]``, dtype int32.
    """
    acc = acc.astype(jnp.int32)[:, :, None]              # [M, C, 1]
    t = thresholds.astype(jnp.int32)[None]               # [1, C, L]
    ge = (acc >= t).sum(axis=2).astype(jnp.int32)
    le = (acc <= t).sum(axis=2).astype(jnp.int32)
    s = signs.astype(jnp.int32)[None]
    return jnp.where(s > 0, ge, jnp.where(s < 0, le, consts[None].astype(jnp.int32)))
