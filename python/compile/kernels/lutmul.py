"""Pallas LUTMUL kernels — the paper's compute hot-spot (Algorithm 1).

The paper embeds quantized weights into FPGA LUT6 primitives so that a
multiplication is a table lookup indexed by the activation code.  The TPU
adaptation (see DESIGN.md section "Hardware adaptation") keeps the core
insight — *weights-stationary product tables indexed by activation codes* —
but restructures the lookup for the TPU memory/compute hierarchy:

  * the product table ``T[co, ci, a] = w[co, ci] * a`` is precomputed at
    compile time (the analog of LUT INIT generation, Figure 5) and kept
    resident in VMEM across all grid steps (weights-stationary, the analog
    of ROM-embedded weights);
  * the per-element lookup is expressed as a **one-hot contraction**:
    ``out[m, co] = sum_{ci, a} onehot(acts)[m, ci, a] * T[co, ci, a]``.
    On real TPU hardware this maps onto the MXU systolic array (a matmul
    with a widened ``CIN * A`` contraction) instead of a scalar gather,
    which the TPU memory system would serialize; under ``interpret=True``
    (mandatory on CPU PJRT) it executes as plain HLO.
  * the grid streams output-pixel tiles (``block_m`` rows of the im2col
    matrix) through VMEM — the analog of the paper's FIFO-streamed
    activations with II=1.

Correctness: bit-exact integer equality against ``ref.py`` (pytest +
hypothesis sweep shapes/dtypes/bit-widths).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_DEFAULT_BLOCK_M = 128


def _lutmul_matmul_kernel(acts_ref, table_ref, out_ref, *, a_size: int):
    """One grid step: [block_m, CIN] codes x [COUT, CIN, A] table -> [block_m, COUT]."""
    acts = acts_ref[...].astype(jnp.int32)               # [bm, CIN]
    table = table_ref[...].astype(jnp.int32)             # [COUT, CIN, A]
    bm, cin = acts.shape
    cout = table.shape[0]
    codes = jnp.arange(a_size, dtype=jnp.int32)
    # One-hot over the activation code axis: the "address decode" of the LUT.
    onehot = (acts[:, :, None] == codes[None, None, :]).astype(jnp.int32)
    # Contract over (CIN, A) — a single [bm, CIN*A] x [CIN*A, COUT] matmul,
    # which is the MXU-friendly form of the LUT readout + adder tree.
    lhs = onehot.reshape(bm, cin * a_size)
    rhs = table.reshape(cout, cin * a_size)
    out_ref[...] = jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _lutmul_depthwise_kernel(acts_ref, table_ref, out_ref, *, a_size: int):
    """One grid step: [block_m, C, K] codes x [C, K, A] table -> [block_m, C]."""
    acts = acts_ref[...].astype(jnp.int32)                # [bm, C, K]
    table = table_ref[...].astype(jnp.int32)              # [C, K, A]
    codes = jnp.arange(a_size, dtype=jnp.int32)
    onehot = (acts[..., None] == codes[None, None, None, :]).astype(jnp.int32)
    # out[m, c] = sum_{k, a} onehot[m, c, k, a] * table[c, k, a]
    out_ref[...] = (onehot * table[None]).sum(axis=(2, 3)).astype(jnp.int32)


def _pad_rows(x: jnp.ndarray, block_m: int) -> tuple[jnp.ndarray, int]:
    m = x.shape[0]
    padded = pl.cdiv(m, block_m) * block_m
    if padded != m:
        pad = [(0, padded - m)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, pad)
    return x, m


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def lutmul_matmul(
    acts: jnp.ndarray,
    table: jnp.ndarray,
    *,
    block_m: int = _DEFAULT_BLOCK_M,
    interpret: bool = True,
) -> jnp.ndarray:
    """LUT-based matrix multiply: ``out[m, co] = sum_ci table[co, ci, acts[m, ci]]``.

    Args:
      acts: activation codes ``[M, CIN]`` (unsigned, ``< table.shape[2]``).
      table: product table ``[COUT, CIN, A]`` (see ``ref.build_table``).
      block_m: rows of the im2col matrix per grid step (VMEM tile).
      interpret: must stay True on CPU PJRT (Mosaic custom-calls cannot run
        on the CPU plugin); the lowered HLO is identical maths either way.

    Returns:
      int32 accumulators ``[M, COUT]``.
    """
    cout, cin, a_size = table.shape
    acts_p, m = _pad_rows(acts.astype(jnp.int32), block_m)
    grid = (acts_p.shape[0] // block_m,)
    out = pl.pallas_call(
        functools.partial(_lutmul_matmul_kernel, a_size=a_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, cin), lambda i: (i, 0)),
            pl.BlockSpec((cout, cin, a_size), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((acts_p.shape[0], cout), jnp.int32),
        interpret=interpret,
    )(acts_p, table.astype(jnp.int32))
    return out[:m]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def lutmul_depthwise(
    acts: jnp.ndarray,
    table: jnp.ndarray,
    *,
    block_m: int = _DEFAULT_BLOCK_M,
    interpret: bool = True,
) -> jnp.ndarray:
    """Depthwise LUT multiply: ``out[m, c] = sum_k table[c, k, acts[m, c, k]]``.

    Args:
      acts: activation codes ``[M, C, K]``.
      table: product table ``[C, K, A]``.

    Returns:
      int32 accumulators ``[M, C]``.
    """
    c, k, a_size = table.shape
    acts_p, m = _pad_rows(acts.astype(jnp.int32), block_m)
    grid = (acts_p.shape[0] // block_m,)
    out = pl.pallas_call(
        functools.partial(_lutmul_depthwise_kernel, a_size=a_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, c, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((c, k, a_size), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((acts_p.shape[0], c), jnp.int32),
        interpret=interpret,
    )(acts_p, table.astype(jnp.int32))
    return out[:m]


def vmem_footprint_bytes(
    cout: int, cin: int, a_size: int, block_m: int = _DEFAULT_BLOCK_M
) -> int:
    """Estimated VMEM bytes for one grid step (table + act tile + onehot + out).

    Used by the performance notes in EXPERIMENTS.md to check that a layer's
    resident table plus streaming tile fits the ~16 MiB VMEM budget.
    """
    table = cout * cin * a_size * 4
    acts = block_m * cin * 4
    onehot = block_m * cin * a_size * 4
    out = block_m * cout * 4
    return table + acts + onehot + out
