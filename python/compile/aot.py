"""AOT pipeline: train -> streamline -> artifacts (HLO text + network.json).

Emits HLO *text* (NOT ``lowered.compiler_ir("hlo")`` protos or
``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the Rust side's xla_extension 0.5.1 rejects; the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all under ``artifacts/``):

  model.hlo.txt        deployed integer network, batch=1 (golden model the
                       Rust runtime executes on the request path for
                       verification)
  model_b8.hlo.txt     same, batch=8 (batched verification / throughput)
  network.json         integer network description: per-layer weight codes,
                       multi-threshold units, shapes — the input to the
                       Rust graph compiler / dataflow simulator
  test_images.bin      uint8 activation codes [N, 16, 16, 3] (raw bytes)
  test_labels.bin      uint8 labels [N]
  fig2_accuracy.json   Figure 2 sweep results (only with --fig2)
  params.npz           cached trained parameters (skip retraining on re-run)

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big literals as ``constant({...})``, which the Rust side's old
    text parser silently mis-fills — the weight tensors embedded in the
    integer network would be garbage.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_int_model(net: M.IntNetwork, batch: int) -> str:
    """Lower the deployed integer forward (Pallas kernels inside) to HLO text."""
    size, ch = net.meta["image_size"], net.meta["in_ch"]
    spec = jax.ShapeDtypeStruct((batch, size, size, ch), jnp.int32)

    def fn(codes):
        return (M.forward_int(net, codes, use_pallas=True),)

    return to_hlo_text(jax.jit(fn).lower(spec))


class _NpEncoder(json.JSONEncoder):
    def default(self, o):
        if isinstance(o, np.ndarray):
            return o.tolist()
        if isinstance(o, (np.integer,)):
            return int(o)
        if isinstance(o, (np.floating,)):
            return float(o)
        return super().default(o)


def export_network_json(net: M.IntNetwork, path: str, extra_meta: dict | None = None):
    meta = dict(net.meta)
    if extra_meta:
        meta.update(extra_meta)
    with open(path, "w") as f:
        json.dump({"meta": meta, "ops": net.ops}, f, cls=_NpEncoder)


def _flatten_params(tree, prefix=""):
    flat = {}
    for k, v in tree.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            flat.update(_flatten_params(v, key + "/"))
        else:
            flat[key] = np.asarray(v)
    return flat


def _unflatten_params(flat):
    tree: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return tree


def save_checkpoint(path, params, bn_state, scales):
    flat = _flatten_params({"params": params, "bn": bn_state})
    flat["__scales__"] = np.array(json.dumps(scales))
    np.savez(path, **flat)


def load_checkpoint(path):
    z = np.load(path, allow_pickle=False)
    scales = json.loads(str(z["__scales__"]))
    flat = {k: z[k] for k in z.files if k != "__scales__"}
    tree = _unflatten_params(flat)
    return tree["params"], tree["bn"], scales


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=4)
    ap.add_argument("--epochs-fp", type=int, default=15)
    ap.add_argument("--epochs-qat", type=int, default=12)
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--fig2", action="store_true", help="also run the Figure 2 sweep")
    ap.add_argument("--fig2-epochs", type=int, default=6)
    ap.add_argument("--retrain", action="store_true", help="ignore cached params")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    art_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(art_dir, exist_ok=True)
    ckpt = os.path.join(art_dir, "params.npz")

    from . import datasets

    data = datasets.make_dataset(seed=args.seed)
    program = M.build_program(w_bits=args.w_bits, a_bits=args.a_bits)

    if os.path.exists(ckpt) and not args.retrain:
        print(f"loading cached params from {ckpt}")
        params, bn_state, scales = load_checkpoint(ckpt)
        net = M.streamline(params, bn_state, scales, program)
        acc_int = T.evaluate_int(net, data[2], data[3], use_pallas=False)
        acc_fp32 = acc_qat = -1.0
    else:
        r = T.train_model(
            args.w_bits,
            args.a_bits,
            epochs_fp=args.epochs_fp,
            epochs_qat=args.epochs_qat,
            seed=args.seed,
            data=data,
        )
        params, bn_state, scales = r["params"], r["bn_state"], r["scales"]
        net = r["net"]
        acc_int, acc_fp32, acc_qat = r["acc_int"], r["acc_fp32"], r["acc_qat"]
        save_checkpoint(ckpt, params, bn_state, scales)

    # HLO artifacts
    for b in args.batches:
        path = args.out if b == 1 else args.out.replace(".hlo.txt", f"_b{b}.hlo.txt")
        text = lower_int_model(net, b)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars, batch={b})")

    # Test set (raw bytes, read by the Rust examples/benches)
    x_test, y_test = data[2], data[3]
    codes = np.asarray(M.encode_input(jnp.asarray(x_test)), np.uint8)
    codes.tofile(os.path.join(art_dir, "test_images.bin"))
    np.asarray(y_test, np.uint8).tofile(os.path.join(art_dir, "test_labels.bin"))

    # Golden logits for the first 32 test images (bit-exactness check target)
    golden = np.asarray(
        M.forward_int(net, M.encode_input(jnp.asarray(x_test[:32])), use_pallas=False)
    )

    export_network_json(
        net,
        os.path.join(art_dir, "network.json"),
        extra_meta={
            "w_bits": args.w_bits,
            "a_bits": args.a_bits,
            "acc_int": acc_int,
            "acc_fp32": acc_fp32,
            "acc_qat": acc_qat,
            "n_test": int(len(y_test)),
            "golden_logits": golden,
        },
    )
    print(f"wrote network.json (deployed acc={acc_int:.4f})")

    if args.fig2:
        res = T.run_fig2_sweep(
            epochs_fp=args.fig2_epochs, epochs_qat=args.fig2_epochs, seed=args.seed
        )
        with open(os.path.join(art_dir, "fig2_accuracy.json"), "w") as f:
            json.dump(res, f, indent=2)
        print("wrote fig2_accuracy.json")


if __name__ == "__main__":
    main()
