"""Quantization-aware training primitives (paper section 3.6).

Implements Eq. (4)/(5) of the paper: affine quantize/dequantize with
straight-through-estimator (STE) gradients, per-channel symmetric weight
quantization (two's complement, e.g. int4 in [-8, 7]) and unsigned
activation quantization (e.g. uint4 in [0, 15]); activation scales are
fixed from a calibration pass (max-percentile), matching the deployment
semantics of the streamlined integer network.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round-to-nearest-even with identity (straight-through) gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def weight_qrange(bits: int) -> tuple[int, int]:
    """Two's complement signed range, e.g. bits=4 -> (-8, 7)."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def act_qrange(bits: int) -> tuple[int, int]:
    """Unsigned range, e.g. bits=4 -> (0, 15)."""
    return 0, 2**bits - 1


def weight_scale(w: jnp.ndarray, bits: int, channel_axis: int = 0) -> jnp.ndarray:
    """Per-channel symmetric scale: max|w| over non-channel axes / qmax."""
    _, qmax = weight_qrange(bits)
    axes = tuple(i for i in range(w.ndim) if i != channel_axis)
    amax = jnp.max(jnp.abs(w), axis=axes, keepdims=True)
    return jnp.maximum(amax / qmax, 1e-8)


def quantize_weight(w: jnp.ndarray, bits: int, channel_axis: int = 0) -> jnp.ndarray:
    """Fake-quantize weights (STE): returns dequantized values for training."""
    qmin, qmax = weight_qrange(bits)
    s = weight_scale(w, bits, channel_axis)
    q = jnp.clip(ste_round(w / s), qmin, qmax)
    return q * s


def weight_codes(w: jnp.ndarray, bits: int, channel_axis: int = 0):
    """Integer weight codes + per-channel scale for export (deployment)."""
    qmin, qmax = weight_qrange(bits)
    s = weight_scale(w, bits, channel_axis)
    codes = jnp.clip(jnp.round(w / s), qmin, qmax).astype(jnp.int32)
    return codes, s


def quantize_act(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quantize activations (STE) against a fixed calibration scale.

    The clamp at 0 doubles as the non-linearity (the streamlined
    multi-threshold unit absorbs the ReLU), so layers using this need no
    separate activation function.
    """
    qmin, qmax = act_qrange(bits)
    q = jnp.clip(ste_round(x / scale), qmin, qmax)
    return q * scale


def act_codes(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Integer activation codes (deployment semantics of quantize_act)."""
    qmin, qmax = act_qrange(bits)
    return jnp.clip(jnp.round(x / scale), qmin, qmax).astype(jnp.int32)


def calibrate_scale(x: jnp.ndarray, bits: int, percentile: float = 99.9) -> float:
    """Calibration: pick the activation scale so `percentile` of positive
    mass is representable. Uses the positive tail only (outputs are
    unsigned; negatives are clipped by the quantizer/ReLU)."""
    _, qmax = act_qrange(bits)
    pos = jnp.maximum(x, 0.0)
    hi = jnp.percentile(pos, percentile)
    return float(jnp.maximum(hi / qmax, 1e-6))


@dataclasses.dataclass(frozen=True)
class BatchNormParams:
    """Inference-time batchnorm: y = gamma * (x - mean) / sqrt(var+eps) + beta."""

    gamma: jnp.ndarray
    beta: jnp.ndarray
    mean: jnp.ndarray
    var: jnp.ndarray
    eps: float = 1e-5

    def apply(self, x: jnp.ndarray) -> jnp.ndarray:
        inv = self.gamma / jnp.sqrt(self.var + self.eps)
        return x * inv + (self.beta - self.mean * inv)


def streamline_thresholds(
    w_scale: jnp.ndarray,
    in_scale: float,
    bn: BatchNormParams,
    out_scale: float,
    out_bits: int,
):
    """FINN-style streamlining (paper section 3.2): absorb the per-channel
    weight scale, input scale, and batch-norm into an integer
    multi-threshold unit.

    For a layer computing
        code_out = clamp(round(BN(s_w * s_in * acc) / s_out), 0, 2^b - 1)
    with integer accumulator ``acc``, the output code crosses level ``t``
    exactly when BN(...) >= (t - 0.5) * s_out, which (for positive BN gain)
    is ``acc >= T[t]`` with an integer threshold.  Returns
    ``(thresholds [C, 2^b - 1] int32, signs [C] int32, consts [C] int32)``
    matching ``ref.multithreshold_ref`` / the Rust MultiThreshold unit.
    """
    levels = 2**out_bits - 1
    sd = jnp.sqrt(bn.var + bn.eps)
    g = bn.gamma
    sw = w_scale.reshape(-1)  # per-output-channel
    c = sw.shape[0]
    t_idx = jnp.arange(1, levels + 1, dtype=jnp.float32)  # crossing points

    # y-domain crossing values: (t - 0.5) * s_out
    y_cross = (t_idx - 0.5) * out_scale                     # [L]
    # invert BN: x = mean + sd * (y - beta) / gamma
    x_cross = bn.mean[:, None] + sd[:, None] * (
        (y_cross[None, :] - bn.beta[:, None]) / jnp.where(g == 0, 1.0, g)[:, None]
    )                                                        # [C, L]
    acc_cross = x_cross / (sw[:, None] * in_scale)           # [C, L] float

    pos = jnp.ceil(acc_cross)                                # acc >= ceil(.)
    neg = jnp.floor(acc_cross)                               # acc <= floor(.)
    # Clamp to int32 to keep the export well-defined for extreme BN params.
    lo, hi = -(2**31) + 1, 2**31 - 2
    pos = jnp.clip(pos, lo, hi).astype(jnp.int32)
    neg = jnp.clip(neg, lo, hi).astype(jnp.int32)
    # For negative gain the crossings come out descending; the unit counts
    # acc <= T so sort ascending to keep the [C, L] layout canonical.
    neg = jnp.sort(neg, axis=1)

    signs = jnp.where(g > 0, 1, jnp.where(g < 0, -1, 0)).astype(jnp.int32)
    consts = jnp.clip(
        jnp.round(bn.beta / out_scale), 0, levels
    ).astype(jnp.int32)  # gamma == 0 -> constant output channel
    thresholds = jnp.where(signs[:, None] > 0, pos, neg)
    return thresholds, signs, consts
