//! Minimal offline stand-in for the `anyhow` crate (DESIGN.md S13).
//!
//! The offline vendored crate set used by this repo cannot assume registry
//! access, so the error-handling surface the crate actually uses is
//! reimplemented here: [`Error`], [`Result`], the `anyhow!` / `bail!` /
//! `ensure!` macros, and the [`Context`] extension trait for `Result` and
//! `Option`. Errors are a single flattened message (no backtraces, no
//! downcasting) — enough for a CLI/serving binary that only ever formats
//! its errors.

use std::fmt;

/// `Result<T, anyhow::Error>` alias, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened, message-only error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    /// Prepend context, `anyhow`-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error` — that
// is what keeps this blanket conversion coherent (same trick as the real
// crate), so `?` works on any std error type.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt", ...)` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt", ...)` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt", ...)` — `bail!` unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        assert_eq!(format!("{e:?}"), "x = 3");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(v: i32) -> Result<i32> {
            ensure!(v >= 0, "negative: {v}");
            if v > 10 {
                bail!("too big");
            }
            Ok(v)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(11).unwrap_err().to_string(), "too big");
    }

    #[test]
    fn context_on_result_and_option() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: gone");
        let e = None::<i32>.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }
}
