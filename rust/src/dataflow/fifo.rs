//! Bounded FIFO channel between dataflow stages (paper section 3.3:
//! "employs a First In, First Out (FIFO) buffer between layers to store
//! activations").
//!
//! Tracks occupancy high-water marks so the synthesis analog can size the
//! physical FIFOs (BRAM vs LUTRAM) from simulation.

use std::collections::VecDeque;

/// A bounded FIFO with occupancy statistics.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    q: VecDeque<T>,
    capacity: usize,
    high_water: usize,
    total_pushes: u64,
    /// Cycles a producer stalled because this FIFO was full.
    pub backpressure_events: u64,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "FIFO capacity must be positive");
        Self {
            q: VecDeque::with_capacity(capacity.min(4096)),
            capacity,
            high_water: 0,
            total_pushes: 0,
            backpressure_events: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Push a token; returns false (and records backpressure) when full.
    pub fn try_push(&mut self, v: T) -> bool {
        if self.is_full() {
            self.backpressure_events += 1;
            return false;
        }
        self.q.push_back(v);
        self.total_pushes += 1;
        self.high_water = self.high_water.max(self.q.len());
        true
    }

    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }

    /// Maximum occupancy observed (physical depth requirement).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn total_pushes(&self) -> u64 {
        self.total_pushes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_order() {
        let mut f = Fifo::new(4);
        assert!(f.try_push(1));
        assert!(f.try_push(2));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn rejects_when_full_and_counts_backpressure() {
        let mut f = Fifo::new(2);
        assert!(f.try_push(1));
        assert!(f.try_push(2));
        assert!(!f.try_push(3));
        assert_eq!(f.backpressure_events, 1);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn high_water_tracks_max() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.try_push(i);
        }
        f.pop();
        f.pop();
        assert_eq!(f.high_water(), 5);
        assert_eq!(f.len(), 3);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _: Fifo<i32> = Fifo::new(0);
    }
}
