//! Bounded FIFO channel between dataflow stages (paper section 3.3:
//! "employs a First In, First Out (FIFO) buffer between layers to store
//! activations").
//!
//! Tracks occupancy high-water marks so the synthesis analog can size the
//! physical FIFOs (BRAM vs LUTRAM) from simulation.

use std::collections::VecDeque;

/// A bounded FIFO with occupancy statistics.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    q: VecDeque<T>,
    capacity: usize,
    high_water: usize,
    total_pushes: u64,
    /// Cycles a producer stalled because this FIFO was full.
    pub backpressure_events: u64,
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "FIFO capacity must be positive");
        Self {
            q: VecDeque::with_capacity(capacity.min(4096)),
            capacity,
            high_water: 0,
            total_pushes: 0,
            backpressure_events: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Push a token; returns false (and records backpressure) when full.
    pub fn try_push(&mut self, v: T) -> bool {
        if self.is_full() {
            self.backpressure_events += 1;
            return false;
        }
        self.q.push_back(v);
        self.total_pushes += 1;
        self.high_water = self.high_water.max(self.q.len());
        true
    }

    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }

    /// Maximum occupancy observed (physical depth requirement).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn total_pushes(&self) -> u64 {
        self.total_pushes
    }
}

/// A bounded link between shard pipelines (DESIGN.md S18): a FIFO whose
/// send side is paced by wire bandwidth (`cycles_per_token` between
/// injections) and whose tokens only become visible to the receiver
/// after the hop latency. Occupancy and stall statistics mirror
/// [`Fifo`] so the chain can report link pressure next to FIFO
/// pressure.
#[derive(Debug, Clone)]
pub struct LinkChannel<T> {
    /// `(deliverable_cycle, token)` in send order.
    q: VecDeque<(u64, T)>,
    capacity: usize,
    /// Wire occupancy per token (bandwidth model), >= 1.
    pub cycles_per_token: u64,
    /// One-way hop latency in cycles.
    pub latency_cycles: u64,
    /// First cycle at which the wire can accept the next token.
    next_free: u64,
    high_water: usize,
    total_tokens: u64,
    /// Cycles the wire spent transmitting.
    pub busy_cycles: u64,
    /// Send attempts rejected because the wire was busy or the buffer
    /// full (producer-side backpressure).
    pub stalled_cycles: u64,
}

impl<T> LinkChannel<T> {
    pub fn new(capacity: usize, cycles_per_token: u64, latency_cycles: u64) -> Self {
        assert!(capacity > 0, "link buffer capacity must be positive");
        Self {
            q: VecDeque::with_capacity(capacity.min(4096)),
            capacity,
            cycles_per_token: cycles_per_token.max(1),
            latency_cycles,
            next_free: 0,
            high_water: 0,
            total_tokens: 0,
            busy_cycles: 0,
            stalled_cycles: 0,
        }
    }

    /// Start transmitting a token at `cycle`. Gives the token back when
    /// the wire is still busy with the previous token or the in-flight
    /// buffer is full (the caller retries next cycle).
    pub fn try_send(&mut self, cycle: u64, v: T) -> Result<(), T> {
        if self.q.len() >= self.capacity || cycle < self.next_free {
            self.stalled_cycles += 1;
            return Err(v);
        }
        self.next_free = cycle + self.cycles_per_token;
        self.busy_cycles += self.cycles_per_token;
        self.q.push_back((cycle + self.cycles_per_token + self.latency_cycles, v));
        self.total_tokens += 1;
        self.high_water = self.high_water.max(self.q.len());
        Ok(())
    }

    /// Pop the oldest token that has fully arrived by `cycle`.
    pub fn try_recv(&mut self, cycle: u64) -> Option<T> {
        if self.q.front().is_some_and(|(t, _)| *t <= cycle) {
            self.q.pop_front().map(|(_, v)| v)
        } else {
            None
        }
    }

    /// Zero the wire clock so a persistent link's next drive starts from
    /// cycle 0 instead of stalling until the previous run's `next_free`
    /// is reached. The caller guarantees the link is drained (a
    /// completed chain run leaves no tokens in flight); statistics keep
    /// accumulating.
    pub fn reset_clock(&mut self) {
        debug_assert!(self.q.is_empty(), "resetting a link with tokens in flight");
        self.next_free = 0;
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Maximum in-flight occupancy observed (link buffer sizing).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_order() {
        let mut f = Fifo::new(4);
        assert!(f.try_push(1));
        assert!(f.try_push(2));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn rejects_when_full_and_counts_backpressure() {
        let mut f = Fifo::new(2);
        assert!(f.try_push(1));
        assert!(f.try_push(2));
        assert!(!f.try_push(3));
        assert_eq!(f.backpressure_events, 1);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn high_water_tracks_max() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.try_push(i);
        }
        f.pop();
        f.pop();
        assert_eq!(f.high_water(), 5);
        assert_eq!(f.len(), 3);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _: Fifo<i32> = Fifo::new(0);
    }

    #[test]
    fn link_charges_latency_before_delivery() {
        let mut l: LinkChannel<i32> = LinkChannel::new(4, 1, 10);
        assert!(l.try_send(5, 7).is_ok());
        // in flight until cycle 5 + 1 (wire) + 10 (hop)
        assert_eq!(l.try_recv(15), None);
        assert_eq!(l.try_recv(16), Some(7));
        assert_eq!(l.try_recv(17), None, "drained");
        assert_eq!(l.total_tokens(), 1);
    }

    #[test]
    fn link_paces_sends_by_bandwidth() {
        let mut l: LinkChannel<i32> = LinkChannel::new(8, 4, 0);
        assert!(l.try_send(0, 1).is_ok());
        // wire busy until cycle 4: sends at 1..3 bounce back
        assert_eq!(l.try_send(1, 2), Err(2));
        assert_eq!(l.try_send(3, 2), Err(2));
        assert!(l.try_send(4, 2).is_ok());
        assert_eq!(l.stalled_cycles, 2);
        assert_eq!(l.busy_cycles, 8);
        // delivery order preserved
        assert_eq!(l.try_recv(100), Some(1));
        assert_eq!(l.try_recv(100), Some(2));
    }

    #[test]
    fn link_bounds_in_flight_tokens() {
        let mut l: LinkChannel<i32> = LinkChannel::new(2, 1, 1000);
        assert!(l.try_send(0, 1).is_ok());
        assert!(l.try_send(1, 2).is_ok());
        // buffer full until something arrives and is received
        assert_eq!(l.try_send(2, 3), Err(3));
        assert_eq!(l.high_water(), 2);
        assert!(l.try_recv(2000).is_some());
        assert!(l.try_send(2000, 3).is_ok());
    }
}
