//! Cycle-level dataflow pipeline simulator (paper section 3.3).
//!
//! Builds one hardware stage per compiled plan op (DESIGN.md S17) —
//! convolution stages own a
//! [`ConvGenerator`](super::convgen::ConvGenerator) plus the layer's
//! [`ConvPlan`](crate::graph::plan::ConvPlan) (the same record the
//! reference executor runs); residual bypasses become tee/join stages
//! with their own FIFOs — and simulates
//! the whole pipeline at pixel granularity: every stage fires when its
//! inputs are ready and downstream FIFO space exists, taking `II = fold`
//! cycles per output. This reproduces both the *functional* behaviour
//! (bit-exact vs the JAX golden model) and the *timing* behaviour
//! (throughput = clock / cycles-per-image of the slowest stage, FIFO
//! high-water marks, backpressure).

use std::collections::VecDeque;

use crate::quant::saturating_res_add;

use super::convgen::{ConvGenConfig, ConvGenerator};
use super::fifo::Fifo;
use crate::graph::kernels;
use crate::graph::network::Network;
use crate::graph::plan::{ConvPlan, Datapath, DensePlan, NetworkPlan, PlanOp};

type Token = Vec<i32>;

/// Per-layer folding: a stage computes `cout / fold` output channels per
/// cycle, so one output pixel takes `fold` cycles (paper section 3.2:
/// "HLS layers are folded according to performance and resource
/// requirements").
#[derive(Debug, Clone)]
pub struct FoldConfig {
    /// fold factor per conv stage, in network order. 1 = fully parallel.
    pub folds: Vec<usize>,
}

impl FoldConfig {
    pub fn fully_parallel(n_convs: usize) -> Self {
        Self { folds: vec![1; n_convs] }
    }

    pub fn uniform(n_convs: usize, fold: usize) -> Self {
        Self { folds: vec![fold.max(1); n_convs] }
    }
}

struct ConvStage {
    gen: ConvGenerator,
    /// The compiled layer plan — the same record the reference executor
    /// runs (`kernels::patch_out` is the stage body), so the simulator
    /// consumes plan weights/thresholds/geometry instead of re-deriving
    /// them from `Network`.
    plan: ConvPlan,
    fold: usize,
    pending: VecDeque<Token>,
    busy_until: u64,
}

struct PoolStage {
    pixels_per_image: usize,
    acc: Vec<i32>,
    seen: usize,
}

enum StageKind {
    Conv(Box<ConvStage>),
    /// Residual split: duplicate the token into main + bypass FIFOs.
    Tee,
    /// Residual join: saturating add of main + bypass tokens.
    ResAdd { bits: u32 },
    Pool(PoolStage),
    Dense(DensePlan),
}

struct Stage {
    kind: StageKind,
    inputs: Vec<usize>,  // fifo ids
    outputs: Vec<usize>, // fifo ids (empty for Dense -> logits sink)
    fires: u64,
    stalled_cycles: u64,
}

/// Simulation statistics for one stage.
#[derive(Debug, Clone)]
pub struct StageStat {
    pub name: String,
    pub fires: u64,
    pub stalled_cycles: u64,
    pub ii: usize,
}

/// FIFO sizing data from simulation.
#[derive(Debug, Clone)]
pub struct FifoStat {
    pub high_water: usize,
    pub capacity: usize,
    pub backpressure_events: u64,
}

/// Result of a pipeline run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total simulated cycles to fully drain all images.
    pub cycles: u64,
    pub images: usize,
    pub logits: Vec<Vec<f32>>,
    pub stages: Vec<StageStat>,
    pub fifos: Vec<FifoStat>,
    /// Steady-state cycles per image (analytic: slowest stage).
    pub steady_state_cycles_per_image: u64,
    /// Cycle at which each image's logits left the dense head, in
    /// submission order. Within a batch, images overlap in the pipeline,
    /// so successive completions are spaced by the steady-state interval,
    /// not by the full pipeline depth — this is what the batch-pipelined
    /// `Simulator` serving backend exposes per request.
    pub image_done_cycles: Vec<u64>,
}

impl SimReport {
    /// Frames per second at a given clock.
    pub fn fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 * self.images as f64 / self.cycles as f64
    }

    /// Steady-state FPS (pipeline full, the paper's Table 2 regime).
    pub fn steady_state_fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 / self.steady_state_cycles_per_image as f64
    }

    /// Measured cycles between the last two image completions — the
    /// marginal cost of one more image in a batch (approaches the
    /// steady-state interval once the pipeline is full), vs `cycles` for
    /// a cold single-image run.
    pub fn incremental_cycles_per_image(&self) -> u64 {
        match self.image_done_cycles.len() {
            0 | 1 => self.cycles,
            n => self.image_done_cycles[n - 1] - self.image_done_cycles[n - 2],
        }
    }
}

/// The dataflow accelerator: stages + FIFOs built from a network.
pub struct Pipeline {
    stages: Vec<Stage>,
    fifos: Vec<Fifo<Token>>,
    input_fifo: usize,
    in_pixels: usize,
    in_ch: usize,
    steady_cycles: u64,
}

impl Pipeline {
    /// Compile a streamlined network into a dataflow pipeline
    /// (convenience: lowers an arithmetic [`NetworkPlan`] first).
    ///
    /// `fifo_depth` sizes inter-stage FIFOs (pixels); `folds` sets each
    /// conv stage's initiation interval.
    pub fn build(net: &Network, folds: &FoldConfig, fifo_depth: usize) -> Self {
        Self::from_plan(&NetworkPlan::compile(net, Datapath::Arithmetic), folds, fifo_depth)
    }

    /// Build the pipeline from an already-compiled plan: stages consume
    /// the plan's geometry (conv shapes, tee/pool pixel counts, I/O
    /// geometry) and weights/thresholds directly instead of re-deriving
    /// them from `Network` (DESIGN.md S17).
    pub fn from_plan(plan: &NetworkPlan, folds: &FoldConfig, fifo_depth: usize) -> Self {
        let mut stages: Vec<Stage> = Vec::new();
        let mut fifos: Vec<Fifo<Token>> = vec![Fifo::new(fifo_depth)];
        let input_fifo = 0usize;
        let mut cur = input_fifo;
        let mut res_stack: Vec<usize> = Vec::new(); // bypass fifo ids
        let mut conv_idx = 0usize;
        let mut steady: u64 = 1;

        for op in &plan.ops {
            match op {
                PlanOp::Input => {}
                PlanOp::Conv(cp) => {
                    let g = cp.geom;
                    let cfg = ConvGenConfig {
                        in_h: g.in_h,
                        in_w: g.in_w,
                        cin: g.cin,
                        k: g.k,
                        stride: g.stride,
                        pad: g.pad,
                    };
                    let fold = folds.folds.get(conv_idx).copied().unwrap_or(1).max(1);
                    conv_idx += 1;
                    let out_fifo = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    steady = steady
                        .max(g.out_pixels() as u64 * fold as u64)
                        .max(g.in_pixels() as u64);
                    stages.push(Stage {
                        kind: StageKind::Conv(Box::new(ConvStage {
                            gen: ConvGenerator::new(cfg),
                            plan: cp.clone(),
                            fold,
                            pending: VecDeque::new(),
                            busy_until: 0,
                        })),
                        inputs: vec![cur],
                        outputs: vec![out_fifo],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    cur = out_fifo;
                }
                PlanOp::ResPush { pixels } => {
                    let main = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    // bypass FIFO sized for a whole block's worth of pixels
                    // plus in-flight slack (two images can overlap at the
                    // tee while the join drains the first)
                    let bypass = fifos.len();
                    fifos.push(Fifo::new(2 * pixels + fifo_depth));
                    stages.push(Stage {
                        kind: StageKind::Tee,
                        inputs: vec![cur],
                        outputs: vec![main, bypass],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    res_stack.push(bypass);
                    cur = main;
                }
                PlanOp::ResAdd { bits } => {
                    let bypass = res_stack.pop().expect("res_add without res_push");
                    let out = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    stages.push(Stage {
                        kind: StageKind::ResAdd { bits: *bits },
                        inputs: vec![cur, bypass],
                        outputs: vec![out],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    cur = out;
                }
                PlanOp::PoolSum { pixels } => {
                    let out = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    stages.push(Stage {
                        kind: StageKind::Pool(PoolStage {
                            pixels_per_image: *pixels,
                            acc: Vec::new(),
                            seen: 0,
                        }),
                        inputs: vec![cur],
                        outputs: vec![out],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    cur = out;
                }
                PlanOp::Dense(dp) => {
                    stages.push(Stage {
                        kind: StageKind::Dense(dp.clone()),
                        inputs: vec![cur],
                        outputs: vec![],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                }
            }
        }

        Self {
            stages,
            fifos,
            input_fifo,
            in_pixels: plan.io.image_size * plan.io.image_size,
            in_ch: plan.io.in_ch,
            steady_cycles: steady,
        }
    }

    /// Number of conv stages (for fold vector sizing).
    pub fn n_convs(&self) -> usize {
        self.stages
            .iter()
            .filter(|s| matches!(s.kind, StageKind::Conv(_)))
            .count()
    }

    /// Run `images` (each `[H*W*C]` codes, raster order) through the
    /// pipeline; returns logits per image plus timing statistics.
    ///
    /// Batches are *pipelined*: the pixel source feeds image i+1 into the
    /// first stage the cycle after image i's last pixel, so successive
    /// images overlap in the dataflow rather than draining between images
    /// (`SimReport::image_done_cycles` records the overlap).
    pub fn run(&mut self, images: &[Vec<i32>]) -> SimReport {
        let mut logits: Vec<Vec<f32>> = Vec::with_capacity(images.len());
        let mut done_cycles: Vec<u64> = Vec::with_capacity(images.len());
        // stream of input pixels across all images
        let in_ch = self.in_ch;
        let mut pixel_iter =
            images.iter().flat_map(move |img| img.chunks(in_ch)).map(|p| p.to_vec());
        let total_pixels = images.len() * self.in_pixels;
        let mut next_pixel: Option<Token> = pixel_iter.next();

        let mut cycle: u64 = 0;
        let max_cycles = (total_pixels as u64 + 10_000) * 64 + 1_000_000;
        while logits.len() < images.len() {
            cycle += 1;
            assert!(cycle < max_cycles, "pipeline deadlock at cycle {cycle}");

            // source: one pixel per cycle into the input FIFO
            if let Some(px) = next_pixel.as_ref() {
                if self.fifos[self.input_fifo].try_push(px.clone()) {
                    next_pixel = pixel_iter.next();
                }
                // on failure: keep the pixel for next cycle (backpressure)
            }

            // stages fire downstream-first so space frees within a cycle
            for si in (0..self.stages.len()).rev() {
                self.fire_stage(si, cycle, &mut logits, &mut done_cycles);
            }
        }

        SimReport {
            cycles: cycle,
            images: images.len(),
            logits,
            stages: self
                .stages
                .iter()
                .map(|s| StageStat {
                    name: match &s.kind {
                        StageKind::Conv(c) => c.plan.name.clone(),
                        StageKind::Tee => "tee".into(),
                        StageKind::ResAdd { .. } => "res_add".into(),
                        StageKind::Pool(_) => "pool".into(),
                        StageKind::Dense(d) => d.name.clone(),
                    },
                    fires: s.fires,
                    stalled_cycles: s.stalled_cycles,
                    ii: match &s.kind {
                        StageKind::Conv(c) => c.fold,
                        _ => 1,
                    },
                })
                .collect(),
            fifos: self
                .fifos
                .iter()
                .map(|f| FifoStat {
                    high_water: f.high_water(),
                    capacity: f.capacity(),
                    backpressure_events: f.backpressure_events,
                })
                .collect(),
            steady_state_cycles_per_image: self.steady_cycles,
            image_done_cycles: done_cycles,
        }
    }

    fn fire_stage(
        &mut self,
        si: usize,
        cycle: u64,
        logits: &mut Vec<Vec<f32>>,
        done_cycles: &mut Vec<u64>,
    ) {
        let (inputs, outputs) = {
            let s = &self.stages[si];
            (s.inputs.clone(), s.outputs.clone())
        };
        let mut fired = false;
        let mut stalled = false;
        // NB: `self.stages[si].kind` and `self.fifos[..]` are disjoint
        // fields, so both can be borrowed mutably at once.
        match &mut self.stages[si].kind {
            StageKind::Conv(cs) => {
                // 1) emit a computed patch if the multiplier array is free
                if !cs.pending.is_empty() && cycle >= cs.busy_until {
                    if !self.fifos[outputs[0]].is_full() {
                        let patch = cs.pending.pop_front().unwrap();
                        let out = kernels::patch_out(&cs.plan, &patch);
                        let ok = self.fifos[outputs[0]].try_push(out);
                        debug_assert!(ok);
                        cs.busy_until = cycle + cs.fold as u64;
                        fired = true;
                    } else {
                        stalled = true;
                    }
                }
                // 2) ingest one input pixel per cycle (line-buffer write)
                //    unless the patch queue is backed up
                if cs.pending.len() < 4 {
                    if let Some(px) = self.fifos[inputs[0]].pop() {
                        let patches = cs.gen.push_pixel(&px);
                        cs.pending.extend(patches);
                    }
                }
            }
            StageKind::Tee => {
                if !self.fifos[outputs[0]].is_full() && !self.fifos[outputs[1]].is_full() {
                    if let Some(px) = self.fifos[inputs[0]].pop() {
                        self.fifos[outputs[0]].try_push(px.clone());
                        self.fifos[outputs[1]].try_push(px);
                        fired = true;
                    }
                }
            }
            StageKind::ResAdd { bits } => {
                let bits = *bits;
                if !self.fifos[inputs[0]].is_empty()
                    && !self.fifos[inputs[1]].is_empty()
                    && !self.fifos[outputs[0]].is_full()
                {
                    let a = self.fifos[inputs[0]].pop().unwrap();
                    let b = self.fifos[inputs[1]].pop().unwrap();
                    let sum: Token = a
                        .iter()
                        .zip(b.iter())
                        .map(|(&x, &y)| saturating_res_add(x, y, bits))
                        .collect();
                    self.fifos[outputs[0]].try_push(sum);
                    fired = true;
                }
            }
            StageKind::Pool(ps) => {
                if !self.fifos[outputs[0]].is_full() {
                    if let Some(px) = self.fifos[inputs[0]].pop() {
                        if ps.acc.is_empty() {
                            ps.acc = vec![0; px.len()];
                        }
                        for (a, v) in ps.acc.iter_mut().zip(px.iter()) {
                            *a += v;
                        }
                        ps.seen += 1;
                        fired = true;
                        if ps.seen == ps.pixels_per_image {
                            let acc = std::mem::take(&mut ps.acc);
                            ps.seen = 0;
                            self.fifos[outputs[0]].try_push(acc);
                        }
                    }
                }
            }
            StageKind::Dense(ds) => {
                if let Some(pooled) = self.fifos[inputs[0]].pop() {
                    // same dense kernel as the reference executor (FMA to
                    // match XLA's fused lowering)
                    logits.push(kernels::dense(ds, &pooled));
                    done_cycles.push(cycle);
                    fired = true;
                }
            }
        }
        if fired {
            self.stages[si].fires += 1;
        }
        if stalled {
            self.stages[si].stalled_cycles += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::executor::{Executor, Tensor};
    use crate::graph::network::{ConvKind, Meta, Op};

    /// Build a small random network exercising every op type.
    fn random_net(seed: u64) -> Network {
        let mut s = seed;
        let mut rnd = move |m: i32| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as i32).rem_euclid(m)
        };
        let thr = |cout: usize, rnd: &mut dyn FnMut(i32) -> i32| -> (Vec<Vec<i32>>, Vec<i32>, Vec<i32>) {
            let mut t = Vec::new();
            let mut signs = Vec::new();
            for _ in 0..cout {
                let base = rnd(40) - 20;
                let step = 1 + rnd(5);
                t.push((0..15).map(|i| base + i * step).collect());
                signs.push(if rnd(4) == 0 { -1 } else { 1 });
            }
            (t, signs, vec![0; cout])
        };
        let conv = |name: &str,
                    kind: ConvKind,
                    cin: usize,
                    cout: usize,
                    k: usize,
                    stride: usize,
                    rnd: &mut dyn FnMut(i32) -> i32| {
            let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
            let w: Vec<Vec<i32>> =
                (0..cout).map(|_| (0..cols).map(|_| rnd(16) - 8).collect()).collect();
            let (t, signs, consts) = thr(cout, rnd);
            Op::Conv {
                name: name.into(),
                kind,
                cin,
                cout,
                k,
                stride,
                pad: (k - 1) / 2,
                w_bits: 4,
                in_bits: 4,
                out_bits: 4,
                w_codes: w,
                thresholds: t,
                signs,
                consts,
                out_scale: 0.1,
            }
        };
        let mut ops = vec![Op::Input { bits: 4, scale: 1.0 / 15.0 }];
        ops.push(conv("c0", ConvKind::Std, 3, 6, 3, 1, &mut rnd));
        ops.push(Op::ResPush {});
        ops.push(conv("c1", ConvKind::Pw, 6, 8, 1, 1, &mut rnd));
        ops.push(conv("c2", ConvKind::Dw, 8, 8, 3, 1, &mut rnd));
        ops.push(conv("c3", ConvKind::Pw, 8, 6, 1, 1, &mut rnd));
        ops.push(Op::ResAdd { bits: 4 });
        ops.push(conv("c4", ConvKind::Std, 6, 5, 3, 2, &mut rnd));
        ops.push(Op::PoolSum {});
        ops.push(Op::Dense {
            name: "fc".into(),
            cin: 5,
            cout: 3,
            w_bits: 8,
            w_codes: (0..5).map(|_| (0..3).map(|_| rnd(256) - 128).collect()).collect(),
            scale: vec![0.01; 3],
            bias: vec![0.5, -0.5, 0.0],
        });
        Network {
            meta: Meta {
                image_size: 8,
                in_ch: 3,
                num_classes: 3,
                in_scale: 1.0 / 15.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops,
        }
    }

    fn random_images(n: usize, size: usize, ch: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                (0..size * size * ch)
                    .map(|_| {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((s >> 40) as i32).rem_euclid(16)
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn pipeline_matches_reference_executor() {
        let net = random_net(7);
        let images = random_images(3, 8, 3, 11);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let folds = FoldConfig::fully_parallel(6);
        let mut pipe = Pipeline::build(&net, &folds, 8);
        let report = pipe.run(&images);
        assert_eq!(report.logits.len(), 3);
        for (img, got) in images.iter().zip(&report.logits) {
            let t = Tensor::from_hwc(8, 8, 3, img.clone());
            let want = ex.execute(&t);
            assert_eq!(got, &want);
        }
    }

    #[test]
    fn folding_preserves_function_but_slows_pipeline() {
        let net = random_net(21);
        let images = random_images(2, 8, 3, 5);
        let fast = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8).run(&images);
        let slow = Pipeline::build(&net, &FoldConfig::uniform(6, 4), 8).run(&images);
        assert_eq!(fast.logits, slow.logits, "folding must not change results");
        assert!(slow.cycles > fast.cycles, "fold 4 must be slower");
    }

    #[test]
    fn throughput_improves_with_pipelining() {
        // steady-state: cycles for 8 images << 8 x cycles for 1 image
        let net = random_net(3);
        let one = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8)
            .run(&random_images(1, 8, 3, 1));
        let eight = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8)
            .run(&random_images(8, 8, 3, 1));
        assert!(
            eight.cycles < one.cycles * 8,
            "pipelining: {} !< {}",
            eight.cycles,
            one.cycles * 8
        );
    }

    #[test]
    fn batch_overlaps_in_pipeline() {
        // completion times are recorded per image, strictly increasing,
        // and the marginal image costs far less than a cold run
        let net = random_net(17);
        let report =
            Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8).run(&random_images(6, 8, 3, 9));
        assert_eq!(report.image_done_cycles.len(), 6);
        assert!(report.image_done_cycles.windows(2).all(|w| w[0] < w[1]));
        let cold = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8)
            .run(&random_images(1, 8, 3, 9));
        assert!(
            report.incremental_cycles_per_image() < cold.cycles,
            "pipelined marginal image ({}) must beat a cold run ({})",
            report.incremental_cycles_per_image(),
            cold.cycles
        );
        assert_eq!(cold.incremental_cycles_per_image(), cold.cycles);
    }

    #[test]
    fn fifo_stats_populated() {
        let net = random_net(9);
        let mut pipe = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 4);
        let report = pipe.run(&random_images(2, 8, 3, 2));
        assert!(report.fifos.iter().any(|f| f.high_water > 0));
        assert!(report.stages.iter().all(|s| s.fires > 0));
    }

    #[test]
    fn steady_state_bound_sane() {
        let net = random_net(13);
        let report =
            Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8).run(&random_images(4, 8, 3, 3));
        // steady state cycles per image >= dominant stage pixel count
        assert!(report.steady_state_cycles_per_image >= 64);
        assert!(report.fps(333.0) > 0.0);
        assert!(report.steady_state_fps(333.0) >= report.fps(333.0) * 0.5);
    }
}
