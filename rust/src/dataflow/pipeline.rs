//! Cycle-level dataflow pipeline simulator (paper section 3.3).
//!
//! Builds one hardware stage per compiled plan op (DESIGN.md S17) —
//! convolution stages own a
//! [`ConvGenerator`](super::convgen::ConvGenerator) plus the layer's
//! [`ConvPlan`](crate::graph::plan::ConvPlan) (the same record the
//! reference executor runs); residual bypasses become tee/join stages
//! with their own FIFOs — and simulates
//! the whole pipeline at pixel granularity: every stage fires when its
//! inputs are ready and downstream FIFO space exists, taking `II = fold`
//! cycles per output. This reproduces both the *functional* behaviour
//! (bit-exact vs the JAX golden model) and the *timing* behaviour
//! (throughput = clock / cycles-per-image of the slowest stage, FIFO
//! high-water marks, backpressure).
//!
//! The pipeline and the shard chain serve behind the engine's uniform
//! backend contract (`engine::{PipelineBackend, ShardChainBackend}`,
//! DESIGN.md S19).

use std::collections::VecDeque;

use crate::quant::saturating_res_add;

use super::convgen::{ConvGenConfig, ConvGenerator};
use super::fifo::{Fifo, LinkChannel};
use super::multi::LinkModel;
use crate::graph::kernels;
use crate::graph::network::Network;
use crate::graph::plan::{ConvPlan, Datapath, DensePlan, NetworkPlan, PlanOp, PlanShard};

type Token = Vec<i32>;

/// Structured simulation failure: which stage diagnosed it, at which
/// cycle, and why — malformed stage graphs (mismatched join widths, a
/// shard wired to the wrong neighbour, a deadlocked pipeline) report
/// instead of panicking.
#[derive(Debug, Clone)]
pub struct SimError {
    pub stage: String,
    pub cycle: u64,
    pub detail: String,
}

impl SimError {
    fn at(stage: impl Into<String>, cycle: u64, detail: impl Into<String>) -> Self {
        Self { stage: stage.into(), cycle, detail: detail.into() }
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "dataflow sim error at cycle {} in stage '{}': {}",
            self.cycle, self.stage, self.detail
        )
    }
}

impl std::error::Error for SimError {}

/// Per-layer folding: a stage computes `cout / fold` output channels per
/// cycle, so one output pixel takes `fold` cycles (paper section 3.2:
/// "HLS layers are folded according to performance and resource
/// requirements").
#[derive(Debug, Clone)]
pub struct FoldConfig {
    /// fold factor per conv stage, in network order. 1 = fully parallel.
    pub folds: Vec<usize>,
}

impl FoldConfig {
    pub fn fully_parallel(n_convs: usize) -> Self {
        Self { folds: vec![1; n_convs] }
    }

    pub fn uniform(n_convs: usize, fold: usize) -> Self {
        Self { folds: vec![fold.max(1); n_convs] }
    }

    /// Rescale a fold vector for a structurally pruned plan (DESIGN.md
    /// S23). A conv stage folded by `f` owns `ceil(cout / f)` parallel
    /// compute units; with only `live` surviving output channels the
    /// same units finish a pixel in `ceil(live / units)` cycles, so a
    /// pruned layer's initiation interval — and with it the simulated
    /// steady-state — shrinks with its channel sparsity. Dense
    /// (unpruned) stages keep their fold unchanged.
    pub fn rescaled_for(&self, plan: &NetworkPlan) -> FoldConfig {
        let folds = plan
            .convs()
            .zip(self.folds.iter())
            .map(|(cp, &f)| {
                let f = f.max(1);
                match &cp.prune {
                    Some(info) => {
                        let units = cp.geom.cout.div_ceil(f);
                        info.live_rows.len().div_ceil(units).max(1)
                    }
                    None => f,
                }
            })
            .collect();
        FoldConfig { folds }
    }
}

struct ConvStage {
    gen: ConvGenerator,
    /// The compiled layer plan — the same record the reference executor
    /// runs (`kernels::patch_out_into` is the stage body), so the simulator
    /// consumes plan weights/thresholds/geometry instead of re-deriving
    /// them from `Network`.
    plan: ConvPlan,
    fold: usize,
    pending: VecDeque<Token>,
    busy_until: u64,
}

struct PoolStage {
    pixels_per_image: usize,
    acc: Vec<i32>,
    seen: usize,
}

enum StageKind {
    Conv(Box<ConvStage>),
    /// Residual split: duplicate the token into main + bypass FIFOs.
    Tee,
    /// Residual join: saturating add of main + bypass tokens.
    ResAdd { bits: u32 },
    Pool(PoolStage),
    Dense(DensePlan),
}

struct Stage {
    kind: StageKind,
    inputs: Vec<usize>,  // fifo ids
    outputs: Vec<usize>, // fifo ids (empty for Dense -> logits sink)
    fires: u64,
    stalled_cycles: u64,
}

/// Simulation statistics for one stage.
#[derive(Debug, Clone)]
pub struct StageStat {
    pub name: String,
    pub fires: u64,
    pub stalled_cycles: u64,
    pub ii: usize,
}

/// FIFO sizing data from simulation.
#[derive(Debug, Clone)]
pub struct FifoStat {
    pub high_water: usize,
    pub capacity: usize,
    pub backpressure_events: u64,
}

/// Result of a pipeline run.
///
/// `cycles`, `logits` and `image_done_cycles` describe *this* run;
/// `stages` and `fifos` are cumulative over the pipeline's lifetime
/// (a persistent serving pipeline keeps counting across batches), so
/// ratios like stalled/cycles are only meaningful on a fresh pipeline.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total simulated cycles to fully drain all images.
    pub cycles: u64,
    pub images: usize,
    pub logits: Vec<Vec<f32>>,
    pub stages: Vec<StageStat>,
    pub fifos: Vec<FifoStat>,
    /// Steady-state cycles per image (analytic: slowest stage).
    pub steady_state_cycles_per_image: u64,
    /// Cycle at which each image's logits left the dense head, in
    /// submission order. Within a batch, images overlap in the pipeline,
    /// so successive completions are spaced by the steady-state interval,
    /// not by the full pipeline depth — this is what the batch-pipelined
    /// `Simulator` serving backend exposes per request.
    pub image_done_cycles: Vec<u64>,
}

impl SimReport {
    /// Frames per second at a given clock.
    pub fn fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 * self.images as f64 / self.cycles as f64
    }

    /// Steady-state FPS (pipeline full, the paper's Table 2 regime).
    pub fn steady_state_fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 / self.steady_state_cycles_per_image as f64
    }

    /// Measured cycles between the last two image completions — the
    /// marginal cost of one more image in a batch (approaches the
    /// steady-state interval once the pipeline is full), vs `cycles` for
    /// a cold single-image run.
    pub fn incremental_cycles_per_image(&self) -> u64 {
        match self.image_done_cycles.len() {
            0 | 1 => self.cycles,
            n => self.image_done_cycles[n - 1] - self.image_done_cycles[n - 2],
        }
    }
}

/// The dataflow accelerator: stages + FIFOs built from a network (or
/// from one shard of a sliced network, DESIGN.md S18).
pub struct Pipeline {
    stages: Vec<Stage>,
    fifos: Vec<Fifo<Token>>,
    input_fifo: usize,
    /// Egress FIFO of a shard that does not end in the dense head; the
    /// whole-network pipeline (dense tail) has none.
    output_fifo: Option<usize>,
    in_pixels: usize,
    in_ch: usize,
    steady_cycles: u64,
}

impl Pipeline {
    /// Compile a streamlined network into a dataflow pipeline
    /// (convenience: lowers an arithmetic [`NetworkPlan`] first).
    ///
    /// `fifo_depth` sizes inter-stage FIFOs (pixels); `folds` sets each
    /// conv stage's initiation interval.
    pub fn build(net: &Network, folds: &FoldConfig, fifo_depth: usize) -> Self {
        Self::from_plan(&NetworkPlan::compile(net, Datapath::Arithmetic), folds, fifo_depth)
    }

    /// Build the pipeline from an already-compiled plan: stages consume
    /// the plan's geometry (conv shapes, tee/pool pixel counts, I/O
    /// geometry) and weights/thresholds directly instead of re-deriving
    /// them from `Network` (DESIGN.md S17).
    pub fn from_plan(plan: &NetworkPlan, folds: &FoldConfig, fifo_depth: usize) -> Self {
        let mut stages: Vec<Stage> = Vec::new();
        let mut fifos: Vec<Fifo<Token>> = vec![Fifo::new(fifo_depth)];
        let input_fifo = 0usize;
        let mut cur = input_fifo;
        let mut res_stack: Vec<usize> = Vec::new(); // bypass fifo ids
        let mut conv_idx = 0usize;
        let mut steady: u64 = 1;

        for op in &plan.ops {
            match op {
                PlanOp::Input => {}
                PlanOp::Conv(cp) => {
                    let g = cp.geom;
                    let cfg = ConvGenConfig {
                        in_h: g.in_h,
                        in_w: g.in_w,
                        cin: g.cin,
                        k: g.k,
                        stride: g.stride,
                        pad: g.pad,
                    };
                    let fold = folds.folds.get(conv_idx).copied().unwrap_or(1).max(1);
                    conv_idx += 1;
                    let out_fifo = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    steady = steady
                        .max(g.out_pixels() as u64 * fold as u64)
                        .max(g.in_pixels() as u64);
                    stages.push(Stage {
                        kind: StageKind::Conv(Box::new(ConvStage {
                            gen: ConvGenerator::new(cfg),
                            plan: cp.clone(),
                            fold,
                            pending: VecDeque::new(),
                            busy_until: 0,
                        })),
                        inputs: vec![cur],
                        outputs: vec![out_fifo],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    cur = out_fifo;
                }
                PlanOp::ResPush { pixels } => {
                    let main = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    // bypass FIFO sized for a whole block's worth of pixels
                    // plus in-flight slack (two images can overlap at the
                    // tee while the join drains the first)
                    let bypass = fifos.len();
                    fifos.push(Fifo::new(2 * pixels + fifo_depth));
                    stages.push(Stage {
                        kind: StageKind::Tee,
                        inputs: vec![cur],
                        outputs: vec![main, bypass],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    res_stack.push(bypass);
                    cur = main;
                }
                PlanOp::ResAdd { bits } => {
                    let bypass = res_stack.pop().expect("res_add without res_push");
                    let out = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    stages.push(Stage {
                        kind: StageKind::ResAdd { bits: *bits },
                        inputs: vec![cur, bypass],
                        outputs: vec![out],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    cur = out;
                }
                PlanOp::PoolSum { pixels } => {
                    let out = fifos.len();
                    fifos.push(Fifo::new(fifo_depth));
                    stages.push(Stage {
                        kind: StageKind::Pool(PoolStage {
                            pixels_per_image: *pixels,
                            acc: Vec::new(),
                            seen: 0,
                        }),
                        inputs: vec![cur],
                        outputs: vec![out],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                    cur = out;
                }
                PlanOp::Dense(dp) => {
                    stages.push(Stage {
                        kind: StageKind::Dense(dp.clone()),
                        inputs: vec![cur],
                        outputs: vec![],
                        fires: 0,
                        stalled_cycles: 0,
                    });
                }
            }
        }

        let tail_dense = matches!(plan.ops.last(), Some(PlanOp::Dense(_)));
        Self {
            stages,
            fifos,
            input_fifo,
            output_fifo: (!tail_dense).then_some(cur),
            in_pixels: plan.io.image_size * plan.io.image_size,
            in_ch: plan.io.in_ch,
            steady_cycles: steady,
        }
    }

    /// Build one device's pipeline from a plan shard (DESIGN.md S18).
    /// The shard's sub-plan builds exactly like a whole plan — same
    /// stages, FIFOs and fold semantics; a shard that does not end in
    /// the dense head gets an egress FIFO that a [`ShardChain`] link
    /// drains. `folds` covers this shard's conv stages only.
    pub fn from_shard(shard: &PlanShard, folds: &FoldConfig, fifo_depth: usize) -> Self {
        Self::from_plan(&shard.plan, folds, fifo_depth)
    }

    /// Number of conv stages (for fold vector sizing).
    pub fn n_convs(&self) -> usize {
        self.stages
            .iter()
            .filter(|s| matches!(s.kind, StageKind::Conv(_)))
            .count()
    }

    /// Analytic steady-state cycles per image of this pipeline alone
    /// (slowest stage, including the input-streaming floor).
    pub fn steady_cycles(&self) -> u64 {
        self.steady_cycles
    }

    /// Whether the ingress FIFO has no room this cycle.
    pub fn input_full(&self) -> bool {
        self.fifos[self.input_fifo].is_full()
    }

    /// Offer one input token (a pixel's channel vector); false when the
    /// ingress FIFO is full — the caller keeps the token and retries.
    pub fn try_push_input(&mut self, token: Vec<i32>) -> bool {
        self.fifos[self.input_fifo].try_push(token)
    }

    /// Drain one token from a shard's egress FIFO (`None` for a
    /// dense-tailed pipeline, which emits logits instead).
    pub fn pop_output(&mut self) -> Option<Vec<i32>> {
        let f = self.output_fifo?;
        self.fifos[f].pop()
    }

    /// Zero the stage clocks so a persistent pipeline's next `run` (or a
    /// chain's next drive) starts from cycle 0 instead of spinning idle
    /// cycles until the previous run's `busy_until` marks are reached.
    /// Statistics counters keep accumulating.
    fn reset_timing(&mut self) {
        for s in &mut self.stages {
            if let StageKind::Conv(cs) = &mut s.kind {
                cs.busy_until = 0;
            }
        }
    }

    /// Summed fire/stall/occupancy counters, allocation-free (the
    /// per-stage breakdown with names lives in
    /// [`stage_stats`](Self::stage_stats)).
    fn counters(&self) -> (u64, u64, usize) {
        let fires = self.stages.iter().map(|s| s.fires).sum();
        let stalled = self.stages.iter().map(|s| s.stalled_cycles).sum();
        let high_water = self.fifos.iter().map(Fifo::high_water).max().unwrap_or(0);
        (fires, stalled, high_water)
    }

    /// Advance every stage by one cycle, downstream-first (so FIFO space
    /// frees within the cycle). Completed logits and their completion
    /// cycles append to the provided sinks.
    fn tick(
        &mut self,
        cycle: u64,
        logits: &mut Vec<Vec<f32>>,
        done_cycles: &mut Vec<u64>,
    ) -> Result<(), SimError> {
        for si in (0..self.stages.len()).rev() {
            self.fire_stage(si, cycle, logits, done_cycles)?;
        }
        Ok(())
    }

    /// Per-stage firing/stall statistics (cumulative over the pipeline's
    /// lifetime).
    pub fn stage_stats(&self) -> Vec<StageStat> {
        self.stages
            .iter()
            .map(|s| StageStat {
                name: match &s.kind {
                    StageKind::Conv(c) => c.plan.name.clone(),
                    StageKind::Tee => "tee".into(),
                    StageKind::ResAdd { .. } => "res_add".into(),
                    StageKind::Pool(_) => "pool".into(),
                    StageKind::Dense(d) => d.name.clone(),
                },
                fires: s.fires,
                stalled_cycles: s.stalled_cycles,
                ii: match &s.kind {
                    StageKind::Conv(c) => c.fold,
                    _ => 1,
                },
            })
            .collect()
    }

    /// Per-FIFO occupancy statistics (cumulative).
    pub fn fifo_stats(&self) -> Vec<FifoStat> {
        self.fifos
            .iter()
            .map(|f| FifoStat {
                high_water: f.high_water(),
                capacity: f.capacity(),
                backpressure_events: f.backpressure_events,
            })
            .collect()
    }

    /// Run `images` (each `[H*W*C]` codes, raster order) through the
    /// pipeline; returns logits per image plus timing statistics.
    ///
    /// Batches are *pipelined*: the pixel source feeds image i+1 into the
    /// first stage the cycle after image i's last pixel, so successive
    /// images overlap in the dataflow rather than draining between images
    /// (`SimReport::image_done_cycles` records the overlap).
    ///
    /// Requires a dense-tailed plan; drive a headless shard through a
    /// [`ShardChain`] instead. Malformed stage graphs and deadlocks
    /// return a [`SimError`] naming the stage and cycle.
    pub fn run(&mut self, images: &[Vec<i32>]) -> Result<SimReport, SimError> {
        if let Some(f) = self.output_fifo {
            return Err(SimError::at(
                "<pipeline>",
                0,
                format!(
                    "plan has no dense head (stage output drains to FIFO {f}); \
                     drive this shard through a ShardChain"
                ),
            ));
        }
        self.reset_timing();
        let mut logits: Vec<Vec<f32>> = Vec::with_capacity(images.len());
        let mut done_cycles: Vec<u64> = Vec::with_capacity(images.len());
        // stream of input pixels across all images
        let in_ch = self.in_ch;
        let mut pixel_iter =
            images.iter().flat_map(move |img| img.chunks(in_ch)).map(|p| p.to_vec());
        let total_pixels = images.len() * self.in_pixels;
        let mut next_pixel: Option<Token> = pixel_iter.next();

        let mut cycle: u64 = 0;
        let max_cycles = (total_pixels as u64 + 10_000) * 64 + 1_000_000;
        while logits.len() < images.len() {
            cycle += 1;
            if cycle >= max_cycles {
                return Err(SimError::at(
                    "<source>",
                    cycle,
                    format!("pipeline deadlock: {}/{} images drained", logits.len(), images.len()),
                ));
            }

            // source: one pixel per cycle into the input FIFO
            if let Some(px) = next_pixel.as_ref() {
                if self.fifos[self.input_fifo].try_push(px.clone()) {
                    next_pixel = pixel_iter.next();
                }
                // on failure: keep the pixel for next cycle (backpressure)
            }

            // stages fire downstream-first so space frees within a cycle
            self.tick(cycle, &mut logits, &mut done_cycles)?;
        }

        Ok(SimReport {
            cycles: cycle,
            images: images.len(),
            logits,
            stages: self.stage_stats(),
            fifos: self.fifo_stats(),
            steady_state_cycles_per_image: self.steady_cycles,
            image_done_cycles: done_cycles,
        })
    }

    fn fire_stage(
        &mut self,
        si: usize,
        cycle: u64,
        logits: &mut Vec<Vec<f32>>,
        done_cycles: &mut Vec<u64>,
    ) -> Result<(), SimError> {
        let (inputs, outputs) = {
            let s = &self.stages[si];
            (s.inputs.clone(), s.outputs.clone())
        };
        let mut fired = false;
        let mut stalled = false;
        // NB: `self.stages[si].kind` and `self.fifos[..]` are disjoint
        // fields, so both can be borrowed mutably at once.
        match &mut self.stages[si].kind {
            StageKind::Conv(cs) => {
                // 1) emit a computed patch if the multiplier array is free
                if !cs.pending.is_empty() && cycle >= cs.busy_until {
                    if !self.fifos[outputs[0]].is_full() {
                        let Some(patch) = cs.pending.pop_front() else {
                            return Err(SimError::at(
                                &cs.plan.name,
                                cycle,
                                "conv fired with an empty patch queue",
                            ));
                        };
                        // activation-major kernel body; the token Vec is
                        // owned by the FIFO, so only it is allocated
                        let mut out = vec![0i32; cs.plan.geom.cout];
                        kernels::patch_out_into(&cs.plan, &patch, &mut out);
                        let ok = self.fifos[outputs[0]].try_push(out);
                        debug_assert!(ok);
                        cs.busy_until = cycle + cs.fold as u64;
                        fired = true;
                    } else {
                        stalled = true;
                    }
                }
                // 2) ingest one input pixel per cycle (line-buffer write)
                //    unless the patch queue is backed up
                if cs.pending.len() < 4 {
                    if let Some(px) = self.fifos[inputs[0]].pop() {
                        if px.len() != cs.plan.geom.cin {
                            return Err(SimError::at(
                                &cs.plan.name,
                                cycle,
                                format!(
                                    "input token has {} channels, stage expects {}",
                                    px.len(),
                                    cs.plan.geom.cin
                                ),
                            ));
                        }
                        let patches = cs.gen.push_pixel(&px);
                        cs.pending.extend(patches);
                    }
                }
            }
            StageKind::Tee => {
                if !self.fifos[outputs[0]].is_full() && !self.fifos[outputs[1]].is_full() {
                    if let Some(px) = self.fifos[inputs[0]].pop() {
                        self.fifos[outputs[0]].try_push(px.clone());
                        self.fifos[outputs[1]].try_push(px);
                        fired = true;
                    }
                }
            }
            StageKind::ResAdd { bits } => {
                let bits = *bits;
                if !self.fifos[inputs[0]].is_empty()
                    && !self.fifos[inputs[1]].is_empty()
                    && !self.fifos[outputs[0]].is_full()
                {
                    let (a, b) = match (self.fifos[inputs[0]].pop(), self.fifos[inputs[1]].pop()) {
                        (Some(a), Some(b)) => (a, b),
                        _ => {
                            return Err(SimError::at(
                                "res_add",
                                cycle,
                                "join fired with an empty input FIFO",
                            ))
                        }
                    };
                    if a.len() != b.len() {
                        return Err(SimError::at(
                            "res_add",
                            cycle,
                            format!(
                                "join width mismatch: main token {} ch vs bypass {} ch",
                                a.len(),
                                b.len()
                            ),
                        ));
                    }
                    let sum: Token = a
                        .iter()
                        .zip(b.iter())
                        .map(|(&x, &y)| saturating_res_add(x, y, bits))
                        .collect();
                    self.fifos[outputs[0]].try_push(sum);
                    fired = true;
                }
            }
            StageKind::Pool(ps) => {
                if !self.fifos[outputs[0]].is_full() {
                    if let Some(px) = self.fifos[inputs[0]].pop() {
                        if ps.acc.is_empty() {
                            ps.acc = vec![0; px.len()];
                        }
                        for (a, v) in ps.acc.iter_mut().zip(px.iter()) {
                            *a += v;
                        }
                        ps.seen += 1;
                        fired = true;
                        if ps.seen == ps.pixels_per_image {
                            let acc = std::mem::take(&mut ps.acc);
                            ps.seen = 0;
                            self.fifos[outputs[0]].try_push(acc);
                        }
                    }
                }
            }
            StageKind::Dense(ds) => {
                if let Some(pooled) = self.fifos[inputs[0]].pop() {
                    if pooled.len() != ds.cin {
                        return Err(SimError::at(
                            &ds.name,
                            cycle,
                            format!(
                                "dense head expects {} pooled channels, got {}",
                                ds.cin,
                                pooled.len()
                            ),
                        ));
                    }
                    // same dense kernel as the reference executor (FMA to
                    // match XLA's fused lowering)
                    logits.push(kernels::dense(ds, &pooled));
                    done_cycles.push(cycle);
                    fired = true;
                }
            }
        }
        if fired {
            self.stages[si].fires += 1;
        }
        if stalled {
            self.stages[si].stalled_cycles += 1;
        }
        Ok(())
    }
}

/// Per-link transport statistics from a chain run (cumulative over the
/// chain's lifetime, like stage stats).
#[derive(Debug, Clone)]
pub struct LinkStat {
    pub tokens: u64,
    pub busy_cycles: u64,
    pub stalled_cycles: u64,
    pub high_water: usize,
    pub capacity: usize,
    pub cycles_per_token: u64,
    pub latency_cycles: u64,
}

/// Summed occupancy/stall counters for one shard and its egress link
/// (zeroes for the tail shard, which has no downstream link) — the
/// allocation-free snapshot [`ShardChain::occupancy`] returns for the
/// serving metrics (which re-export it as `ShardOccupancy`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Stage firings on this shard since the chain was built.
    pub fires: u64,
    /// Cycles this shard's stages spent stalled on backpressure.
    pub stalled_cycles: u64,
    /// Highest FIFO occupancy observed on this shard.
    pub fifo_high_water: usize,
    /// Cycles the egress link spent transmitting.
    pub link_busy_cycles: u64,
    /// Egress send attempts rejected (wire busy / buffer full).
    pub link_stalled_cycles: u64,
}

impl ShardCounters {
    /// Element-wise accumulation (high-water takes the max; the other
    /// counters sum) — how the serving metrics merge the per-worker
    /// snapshots of the same shard index.
    pub fn absorb(&mut self, other: &ShardCounters) {
        self.fires += other.fires;
        self.stalled_cycles += other.stalled_cycles;
        self.fifo_high_water = self.fifo_high_water.max(other.fifo_high_water);
        self.link_busy_cycles += other.link_busy_cycles;
        self.link_stalled_cycles += other.link_stalled_cycles;
    }
}

/// One shard's view in a [`ChainReport`]: the stage and FIFO statistics
/// of its pipeline.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub stages: Vec<StageStat>,
    pub fifos: Vec<FifoStat>,
}

impl ShardReport {
    /// Total stage firings on this shard.
    pub fn fires(&self) -> u64 {
        self.stages.iter().map(|s| s.fires).sum()
    }

    /// Total cycles this shard's stages spent stalled on backpressure.
    pub fn stalled_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.stalled_cycles).sum()
    }

    /// Highest FIFO occupancy observed on this shard.
    pub fn fifo_high_water(&self) -> usize {
        self.fifos.iter().map(|f| f.high_water).max().unwrap_or(0)
    }
}

/// Result of a [`ShardChain`] run: the whole-chain analog of
/// [`SimReport`], with per-shard and per-link breakdowns. As with
/// `SimReport`, `cycles`/`logits`/`image_done_cycles` are per-run while
/// `shards` and `links` accumulate over the chain's lifetime.
#[derive(Debug, Clone)]
pub struct ChainReport {
    /// Total simulated cycles to fully drain all images.
    pub cycles: u64,
    pub images: usize,
    pub logits: Vec<Vec<f32>>,
    /// Cycle each image's logits left the tail shard, submission order.
    pub image_done_cycles: Vec<u64>,
    pub shards: Vec<ShardReport>,
    pub links: Vec<LinkStat>,
    /// Analytic steady-state cycles per image: slowest of {shard stage
    /// bounds, link injection rates}.
    pub steady_state_cycles_per_image: u64,
}

impl ChainReport {
    /// Frames per second at a given clock.
    pub fn fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 * self.images as f64 / self.cycles as f64
    }

    /// Steady-state FPS (chain full, the multi-device Table 2 regime).
    pub fn steady_state_fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 / self.steady_state_cycles_per_image as f64
    }

    /// Measured cycles between the last two image completions — the
    /// steady-state interval once the chain is full.
    pub fn incremental_cycles_per_image(&self) -> u64 {
        match self.image_done_cycles.len() {
            0 | 1 => self.cycles,
            n => self.image_done_cycles[n - 1] - self.image_done_cycles[n - 2],
        }
    }

    /// Measured steady-state FPS from the completion interval.
    pub fn measured_steady_fps(&self, freq_mhz: f64) -> f64 {
        freq_mhz * 1e6 / self.incremental_cycles_per_image().max(1) as f64
    }
}

/// N shard pipelines connected by bounded link channels whose occupancy
/// is charged cycles from a [`LinkModel`] (bandwidth pacing + hop
/// latency) — the *executable* form of a multi-device partition
/// (DESIGN.md S18). Functionally bit-exact with the single-device
/// [`Pipeline`] on the unsliced plan: the links only move tokens, they
/// never transform them.
pub struct ShardChain {
    shards: Vec<Pipeline>,
    links: Vec<LinkChannel<Token>>,
    /// Token popped from shard i's egress, awaiting link i admission.
    pending: Vec<Option<Token>>,
    in_pixels: usize,
    in_ch: usize,
    steady_cycles: u64,
}

impl ShardChain {
    /// Assemble a chain from contiguous shards of one plan. `folds`
    /// covers the conv stages of the *whole* parent plan in network
    /// order and is split across the shards here; `a_bits` is the
    /// activation code width the links charge bandwidth for.
    pub fn new(
        shards: &[PlanShard],
        folds: &FoldConfig,
        fifo_depth: usize,
        link: &LinkModel,
        freq_mhz: f64,
        a_bits: u32,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "shard chain needs at least one shard");
        let tail = shards.last().expect("non-empty");
        anyhow::ensure!(
            tail.is_tail(),
            "the final shard (ops {}..{}) must end in the dense head",
            tail.start,
            tail.end
        );
        for w in shards.windows(2) {
            anyhow::ensure!(
                w[0].end == w[1].start,
                "shards must tile one plan contiguously: ops {}..{} then {}..{}",
                w[0].start,
                w[0].end,
                w[1].start,
                w[1].end
            );
            anyhow::ensure!(
                w[0].out_pixels == w[1].in_pixels && w[0].out_ch == w[1].in_ch,
                "shard ops {}..{} emits {}px x {}ch but its successor expects {}px x {}ch",
                w[0].start,
                w[0].end,
                w[0].out_pixels,
                w[0].out_ch,
                w[1].in_pixels,
                w[1].in_ch
            );
        }
        let total_convs: usize = shards.iter().map(|s| s.plan.n_convs()).sum();
        anyhow::ensure!(
            folds.folds.len() >= total_convs,
            "fold vector has {} entries, chain has {total_convs} conv stages",
            folds.folds.len()
        );

        let mut pipes = Vec::with_capacity(shards.len());
        let mut links = Vec::with_capacity(shards.len().saturating_sub(1));
        let mut fold_off = 0usize;
        let mut steady: u64 = 1;
        for (i, s) in shards.iter().enumerate() {
            let k = s.plan.n_convs();
            let sub = FoldConfig { folds: folds.folds[fold_off..fold_off + k].to_vec() };
            fold_off += k;
            let p = Pipeline::from_shard(s, &sub, fifo_depth);
            steady = steady.max(p.steady_cycles());
            if i + 1 < shards.len() {
                let cpt = link.cycles_per_token(s.out_ch, a_bits, freq_mhz);
                let lat = link.latency_cycles(freq_mhz);
                // the link must inject out_pixels tokens per image
                steady = steady.max(cpt * s.out_pixels as u64);
                // in-flight capacity covers the bandwidth-delay product
                // (the wire itself stores latency/rate tokens — a pipe,
                // not a buffer) plus a receive-buffer's worth, so the hop
                // latency adds delay without capping the wire rate;
                // sustained receiver stalls still backpressure the sender
                let bdp = (lat / cpt.max(1) + 1) as usize;
                links.push(LinkChannel::new(fifo_depth.max(2) + bdp, cpt, lat));
            }
            pipes.push(p);
        }
        Ok(Self {
            shards: pipes,
            links,
            pending: vec![None; shards.len().saturating_sub(1)],
            in_pixels: shards[0].in_pixels,
            in_ch: shards[0].in_ch,
            steady_cycles: steady,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Analytic steady-state cycles per image of the whole chain.
    pub fn steady_cycles(&self) -> u64 {
        self.steady_cycles
    }

    /// Current per-shard statistics (cumulative; readable between runs
    /// for serving metrics).
    pub fn shard_stats(&self) -> Vec<ShardReport> {
        self.shards
            .iter()
            .map(|p| ShardReport { stages: p.stage_stats(), fifos: p.fifo_stats() })
            .collect()
    }

    /// Cumulative per-shard counters plus egress-link busy/stall cycles,
    /// allocation-free — what the sharded serving worker polls after
    /// every batch (the per-stage breakdown with names stays in
    /// [`shard_stats`](Self::shard_stats)).
    pub fn occupancy(&self) -> Vec<ShardCounters> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let (fires, stalled_cycles, fifo_high_water) = p.counters();
                let (link_busy_cycles, link_stalled_cycles) = self
                    .links
                    .get(i)
                    .map_or((0, 0), |l| (l.busy_cycles, l.stalled_cycles));
                ShardCounters {
                    fires,
                    stalled_cycles,
                    fifo_high_water,
                    link_busy_cycles,
                    link_stalled_cycles,
                }
            })
            .collect()
    }

    /// Current per-link statistics (cumulative).
    pub fn link_stats(&self) -> Vec<LinkStat> {
        self.links
            .iter()
            .map(|l| LinkStat {
                tokens: l.total_tokens(),
                busy_cycles: l.busy_cycles,
                stalled_cycles: l.stalled_cycles,
                high_water: l.high_water(),
                capacity: l.capacity(),
                cycles_per_token: l.cycles_per_token,
                latency_cycles: l.latency_cycles,
            })
            .collect()
    }

    /// Stream `images` through the chain: the pixel source feeds shard 0,
    /// every shard advances each global cycle, and tokens cross between
    /// shards only through the cycle-charged links. Returns the logits
    /// (identical to the single-device pipeline's) plus per-shard and
    /// per-link statistics.
    ///
    /// A chain whose `run` returned an error must be discarded: its
    /// FIFOs, line buffers and links still hold the failed batch's
    /// partial-image tokens (the sharded serving worker rebuilds its
    /// backend on failure for exactly this reason).
    pub fn run(&mut self, images: &[Vec<i32>]) -> Result<ChainReport, SimError> {
        for p in &mut self.shards {
            p.reset_timing();
        }
        // a completed run leaves the links drained, so only their wire
        // clocks carry over; without this reset every later batch of a
        // persistent chain would stall each link until the previous
        // run's final cycle is reached
        for l in &mut self.links {
            l.reset_clock();
        }
        let mut logits: Vec<Vec<f32>> = Vec::with_capacity(images.len());
        let mut done_cycles: Vec<u64> = Vec::with_capacity(images.len());
        let in_ch = self.in_ch;
        let mut pixel_iter =
            images.iter().flat_map(move |img| img.chunks(in_ch)).map(|p| p.to_vec());
        let total_pixels = images.len() * self.in_pixels;
        let mut next_pixel: Option<Token> = pixel_iter.next();

        // deadlock guard: the single-pipeline budget plus the serialized
        // wire time and latency of every hop
        let wire_budget: u64 = self
            .links
            .iter()
            .map(|l| l.latency_cycles + l.cycles_per_token * total_pixels as u64)
            .sum();
        let max_cycles = (total_pixels as u64 + 10_000) * 64 + 1_000_000 + wire_budget;

        let n = self.shards.len();
        let mut cycle: u64 = 0;
        while logits.len() < images.len() {
            cycle += 1;
            if cycle >= max_cycles {
                return Err(SimError::at(
                    "<chain>",
                    cycle,
                    format!(
                        "shard chain deadlock: {}/{} images drained",
                        logits.len(),
                        images.len()
                    ),
                ));
            }

            // source: one pixel per cycle into shard 0
            if let Some(px) = next_pixel.as_ref() {
                if self.shards[0].try_push_input(px.clone()) {
                    next_pixel = pixel_iter.next();
                }
            }

            // downstream-first across shards, mirroring the intra-shard
            // stage order, so link/FIFO space frees within a cycle
            for i in (0..n).rev() {
                // deliver one arrived token from the upstream link
                if i > 0 && !self.shards[i].input_full() {
                    if let Some(tok) = self.links[i - 1].try_recv(cycle) {
                        let ok = self.shards[i].try_push_input(tok);
                        debug_assert!(ok, "guarded by input_full");
                    }
                }
                self.shards[i].tick(cycle, &mut logits, &mut done_cycles)?;
                // start transmitting one egress token on the downstream link
                if i + 1 < n {
                    if self.pending[i].is_none() {
                        self.pending[i] = self.shards[i].pop_output();
                    }
                    if let Some(tok) = self.pending[i].take() {
                        if let Err(tok) = self.links[i].try_send(cycle, tok) {
                            self.pending[i] = Some(tok);
                        }
                    }
                }
            }
        }

        Ok(ChainReport {
            cycles: cycle,
            images: images.len(),
            logits,
            image_done_cycles: done_cycles,
            shards: self.shard_stats(),
            links: self.link_stats(),
            steady_state_cycles_per_image: self.steady_cycles,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::executor::{Executor, Tensor};
    use crate::graph::network::{ConvKind, Meta, Op};

    /// Build a small random network exercising every op type.
    fn random_net(seed: u64) -> Network {
        let mut s = seed;
        let mut rnd = move |m: i32| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as i32).rem_euclid(m)
        };
        let thr = |cout: usize, rnd: &mut dyn FnMut(i32) -> i32| -> (Vec<Vec<i32>>, Vec<i32>, Vec<i32>) {
            let mut t = Vec::new();
            let mut signs = Vec::new();
            for _ in 0..cout {
                let base = rnd(40) - 20;
                let step = 1 + rnd(5);
                t.push((0..15).map(|i| base + i * step).collect());
                signs.push(if rnd(4) == 0 { -1 } else { 1 });
            }
            (t, signs, vec![0; cout])
        };
        let conv = |name: &str,
                    kind: ConvKind,
                    cin: usize,
                    cout: usize,
                    k: usize,
                    stride: usize,
                    rnd: &mut dyn FnMut(i32) -> i32| {
            let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
            let w: Vec<Vec<i32>> =
                (0..cout).map(|_| (0..cols).map(|_| rnd(16) - 8).collect()).collect();
            let (t, signs, consts) = thr(cout, rnd);
            Op::Conv {
                name: name.into(),
                kind,
                cin,
                cout,
                k,
                stride,
                pad: (k - 1) / 2,
                w_bits: 4,
                in_bits: 4,
                out_bits: 4,
                w_codes: w,
                thresholds: t,
                signs,
                consts,
                out_scale: 0.1,
            }
        };
        let mut ops = vec![Op::Input { bits: 4, scale: 1.0 / 15.0 }];
        ops.push(conv("c0", ConvKind::Std, 3, 6, 3, 1, &mut rnd));
        ops.push(Op::ResPush {});
        ops.push(conv("c1", ConvKind::Pw, 6, 8, 1, 1, &mut rnd));
        ops.push(conv("c2", ConvKind::Dw, 8, 8, 3, 1, &mut rnd));
        ops.push(conv("c3", ConvKind::Pw, 8, 6, 1, 1, &mut rnd));
        ops.push(Op::ResAdd { bits: 4 });
        ops.push(conv("c4", ConvKind::Std, 6, 5, 3, 2, &mut rnd));
        ops.push(Op::PoolSum {});
        ops.push(Op::Dense {
            name: "fc".into(),
            cin: 5,
            cout: 3,
            w_bits: 8,
            w_codes: (0..5).map(|_| (0..3).map(|_| rnd(256) - 128).collect()).collect(),
            scale: vec![0.01; 3],
            bias: vec![0.5, -0.5, 0.0],
        });
        Network {
            meta: Meta {
                image_size: 8,
                in_ch: 3,
                num_classes: 3,
                in_scale: 1.0 / 15.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops,
        }
    }

    fn random_images(n: usize, size: usize, ch: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                (0..size * size * ch)
                    .map(|_| {
                        s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((s >> 40) as i32).rem_euclid(16)
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn pipeline_matches_reference_executor() {
        let net = random_net(7);
        let images = random_images(3, 8, 3, 11);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let folds = FoldConfig::fully_parallel(6);
        let mut pipe = Pipeline::build(&net, &folds, 8);
        let report = pipe.run(&images).unwrap();
        assert_eq!(report.logits.len(), 3);
        for (img, got) in images.iter().zip(&report.logits) {
            let t = Tensor::from_hwc(8, 8, 3, img.clone());
            let want = ex.execute(&t);
            assert_eq!(got, &want);
        }
    }

    #[test]
    fn folding_preserves_function_but_slows_pipeline() {
        let net = random_net(21);
        let images = random_images(2, 8, 3, 5);
        let fast = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8).run(&images).unwrap();
        let slow = Pipeline::build(&net, &FoldConfig::uniform(6, 4), 8).run(&images).unwrap();
        assert_eq!(fast.logits, slow.logits, "folding must not change results");
        assert!(slow.cycles > fast.cycles, "fold 4 must be slower");
    }

    #[test]
    fn throughput_improves_with_pipelining() {
        // steady-state: cycles for 8 images << 8 x cycles for 1 image
        let net = random_net(3);
        let one = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8)
            .run(&random_images(1, 8, 3, 1)).unwrap();
        let eight = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8)
            .run(&random_images(8, 8, 3, 1)).unwrap();
        assert!(
            eight.cycles < one.cycles * 8,
            "pipelining: {} !< {}",
            eight.cycles,
            one.cycles * 8
        );
    }

    #[test]
    fn batch_overlaps_in_pipeline() {
        // completion times are recorded per image, strictly increasing,
        // and the marginal image costs far less than a cold run
        let net = random_net(17);
        let report =
            Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8).run(&random_images(6, 8, 3, 9)).unwrap();
        assert_eq!(report.image_done_cycles.len(), 6);
        assert!(report.image_done_cycles.windows(2).all(|w| w[0] < w[1]));
        let cold = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8)
            .run(&random_images(1, 8, 3, 9)).unwrap();
        assert!(
            report.incremental_cycles_per_image() < cold.cycles,
            "pipelined marginal image ({}) must beat a cold run ({})",
            report.incremental_cycles_per_image(),
            cold.cycles
        );
        assert_eq!(cold.incremental_cycles_per_image(), cold.cycles);
    }

    #[test]
    fn fifo_stats_populated() {
        let net = random_net(9);
        let mut pipe = Pipeline::build(&net, &FoldConfig::fully_parallel(6), 4);
        let report = pipe.run(&random_images(2, 8, 3, 2)).unwrap();
        assert!(report.fifos.iter().any(|f| f.high_water > 0));
        assert!(report.stages.iter().all(|s| s.fires > 0));
    }

    #[test]
    fn steady_state_bound_sane() {
        let net = random_net(13);
        let report =
            Pipeline::build(&net, &FoldConfig::fully_parallel(6), 8).run(&random_images(4, 8, 3, 3)).unwrap();
        // steady state cycles per image >= dominant stage pixel count
        assert!(report.steady_state_cycles_per_image >= 64);
        assert!(report.fps(333.0) > 0.0);
        assert!(report.steady_state_fps(333.0) >= report.fps(333.0) * 0.5);
    }

    #[test]
    fn persistent_pipeline_does_not_accumulate_idle_cycles() {
        // a worker reuses one pipeline across batches; without the clock
        // reset the second run would spin until the first run's
        // busy_until marks are reached
        let net = random_net(29);
        let mut pipe = Pipeline::build(&net, &FoldConfig::uniform(6, 3), 8);
        let first = pipe.run(&random_images(2, 8, 3, 4)).unwrap();
        let second = pipe.run(&random_images(2, 8, 3, 4)).unwrap();
        assert_eq!(first.logits, second.logits, "same inputs, same results");
        assert!(
            second.cycles <= first.cycles + 16,
            "second batch must not pay the first batch's clock: {} vs {}",
            second.cycles,
            first.cycles
        );
    }

    #[test]
    fn malformed_dense_head_diagnoses_instead_of_panicking() {
        // shrink the dense head's weight matrix after compilation: the
        // pooled token no longer matches, which must surface as a
        // structured SimError naming the stage, not an index panic
        let net = random_net(31);
        let mut plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let n_ops = plan.ops.len();
        let PlanOp::Dense(dp) = &mut plan.ops[n_ops - 1] else {
            panic!("random_net ends in a dense head");
        };
        dp.wflat.truncate(2 * dp.cout);
        dp.cin = 2;
        let mut pipe = Pipeline::from_plan(&plan, &FoldConfig::fully_parallel(6), 8);
        let err = pipe.run(&random_images(1, 8, 3, 6)).unwrap_err();
        assert_eq!(err.stage, "fc");
        assert!(err.detail.contains("pooled channels"), "{err}");
        assert!(err.cycle > 0);
    }

    #[test]
    fn headless_shard_refuses_standalone_run() {
        let net = random_net(37);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let cut = *plan.cut_points().first().expect("random_net has a valid cut");
        let head = plan.slice(0..cut).unwrap();
        let folds = FoldConfig::fully_parallel(head.plan.n_convs());
        let mut pipe = Pipeline::from_shard(&head, &folds, 8);
        let err = pipe.run(&random_images(1, 8, 3, 2)).unwrap_err();
        assert!(err.detail.contains("ShardChain"), "{err}");
    }

    #[test]
    fn shard_chain_is_bit_exact_with_single_pipeline_across_residuals() {
        // random_net carries a residual bypass, so valid cuts must skip
        // the tee..join region; every 2-way split at a valid boundary
        // reproduces the single-device logits exactly
        let net = random_net(41);
        let images = random_images(4, 8, 3, 13);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let folds = FoldConfig::fully_parallel(plan.n_convs());
        let want = Pipeline::from_plan(&plan, &folds, 8).run(&images).unwrap();
        let cuts = plan.cut_points();
        assert!(!cuts.is_empty());
        for &c in &cuts {
            let shards = plan.shard(&[c]).unwrap();
            let mut chain =
                ShardChain::new(&shards, &folds, 8, &LinkModel::gbe100(), 333.0, 4).unwrap();
            let got = chain.run(&images).unwrap();
            assert_eq!(got.logits, want.logits, "cut at op {c}");
            assert_eq!(got.image_done_cycles.len(), 4);
            assert!(got.image_done_cycles.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(got.links.len(), 1);
            assert!(got.links[0].tokens > 0, "tokens crossed the link");
            // the hop latency is visible: the chain cannot be faster
            assert!(got.cycles >= want.cycles, "cut at op {c}: {} < {}", got.cycles, want.cycles);
        }
    }

    #[test]
    fn persistent_chain_does_not_accumulate_link_clock() {
        // a sharded serving worker reuses one chain across batches; the
        // links' wire clocks must reset like the stage clocks do, or
        // every later batch stalls until the previous run's next_free
        let net = random_net(47);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let folds = FoldConfig::fully_parallel(plan.n_convs());
        let cut = *plan.cut_points().first().unwrap();
        let shards = plan.shard(&[cut]).unwrap();
        let mut chain =
            ShardChain::new(&shards, &folds, 8, &LinkModel::gbe100(), 333.0, 4).unwrap();
        let images = random_images(2, 8, 3, 8);
        let first = chain.run(&images).unwrap();
        let second = chain.run(&images).unwrap();
        assert_eq!(first.logits, second.logits, "same inputs, same results");
        assert!(
            second.cycles <= first.cycles + 16,
            "second batch must not pay the first batch's link clock: {} vs {}",
            second.cycles,
            first.cycles
        );
    }

    #[test]
    fn pruned_plan_pipeline_matches_masked_dense_and_rescales_folds() {
        use crate::graph::prune::PruneSpec;
        let net = random_net(53);
        let images = random_images(3, 8, 3, 15);
        let spec = PruneSpec::channels(0.5);
        let masked = spec.masked_network(&net);
        let dense_plan = NetworkPlan::compile(&masked, Datapath::Arithmetic);
        let pruned_plan = NetworkPlan::compile_pruned(&net, Datapath::Arithmetic, &spec);
        let folds = FoldConfig::uniform(6, 4);
        let want = Pipeline::from_plan(&dense_plan, &folds, 8).run(&images).unwrap();
        let rescaled = folds.rescaled_for(&pruned_plan);
        let got = Pipeline::from_plan(&pruned_plan, &rescaled, 8).run(&images).unwrap();
        assert_eq!(got.logits, want.logits, "pruned pipeline vs masked dense");
        assert!(
            rescaled.folds.iter().zip(&folds.folds).any(|(r, f)| r < f),
            "50% channel pruning must shrink at least one fold: {:?}",
            rescaled.folds
        );
        assert!(got.steady_state_cycles_per_image <= want.steady_state_cycles_per_image);
        // a noop rescale against the dense plan is the identity
        assert_eq!(folds.rescaled_for(&dense_plan).folds, folds.folds);
    }

    #[test]
    fn mismatched_shard_wiring_is_rejected_at_build() {
        let net = random_net(43);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let cuts = plan.cut_points();
        let c = cuts[cuts.len() / 2];
        let head = plan.slice(0..c).unwrap();
        let folds = FoldConfig::fully_parallel(plan.n_convs());
        // chain missing its tail
        let err = ShardChain::new(
            std::slice::from_ref(&head),
            &folds,
            8,
            &LinkModel::gbe100(),
            333.0,
            4,
        )
        .unwrap_err();
        assert!(err.to_string().contains("dense head"), "{err}");
        // non-contiguous shards
        let tail = plan.slice(c..plan.ops.len()).unwrap();
        let err = ShardChain::new(
            &[tail.clone(), tail],
            &folds,
            8,
            &LinkModel::gbe100(),
            333.0,
            4,
        )
        .unwrap_err();
        assert!(err.to_string().contains("contiguous"), "{err}");
    }
}
