//! Convolution generator (paper section 3.4): the im2col streaming unit.
//!
//! Consumes input pixels (one `[CIN]` code vector per cycle) in raster
//! order, maintains line buffers, and emits im2col patches (`[K*K*CIN]`
//! in (tap, channel) minor order — matching `python/compile/model.py::
//! im2col`) as soon as their window is complete. Supports standard,
//! depthwise, and pointwise convolutions with arbitrary kernel/stride/pad
//! ("each kind of convolutional layer expects different input data
//! sequences, necessitating specific generator settings").


/// Static configuration of a convolution generator.
#[derive(Debug, Clone, Copy)]
pub struct ConvGenConfig {
    pub in_h: usize,
    pub in_w: usize,
    pub cin: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGenConfig {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Line-buffer bits: `(k-1)` full rows plus one partial row of pixels
    /// must be resident (the classic sliding-window buffer).
    pub fn line_buffer_bits(&self, act_bits: u32) -> u64 {
        (self.k as u64) * self.in_w as u64 * self.cin as u64 * act_bits as u64
    }

    /// The input pixel (raster index) whose arrival completes the window
    /// of output `(oy, ox)` — the last in-bounds tap.
    fn trigger_index(&self, oy: usize, ox: usize) -> usize {
        let last_y = (oy * self.stride + self.k - 1)
            .saturating_sub(self.pad)
            .min(self.in_h - 1);
        let last_x = (ox * self.stride + self.k - 1)
            .saturating_sub(self.pad)
            .min(self.in_w - 1);
        last_y * self.in_w + last_x
    }
}

/// The streaming im2col generator.
#[derive(Debug, Clone)]
pub struct ConvGenerator {
    cfg: ConvGenConfig,
    /// Sliding window of the most recent `k` rows (plus partial row).
    rows: Vec<Vec<i32>>, // rows[y % k][x * cin + c] circularly indexed
    pixels_seen: usize,
    /// Raster cursor over output positions awaiting their trigger pixel.
    next_out: usize,
    emitted_this_image: usize,
}

impl ConvGenerator {
    pub fn new(cfg: ConvGenConfig) -> Self {
        assert!(cfg.k >= 1 && cfg.stride >= 1);
        assert!(cfg.pad < cfg.k, "padding beyond kernel makes empty taps only");
        Self {
            rows: vec![vec![0; cfg.in_w * cfg.cin]; cfg.k],
            cfg,
            pixels_seen: 0,
            next_out: 0,
            emitted_this_image: 0,
        }
    }

    pub fn config(&self) -> &ConvGenConfig {
        &self.cfg
    }

    /// Total patches emitted per image.
    pub fn patches_per_image(&self) -> usize {
        self.cfg.out_h() * self.cfg.out_w()
    }

    /// Feed one input pixel (length `CIN`); returns every patch whose
    /// window this pixel completes (usually 0 or 1; more at right/bottom
    /// edges with padding).
    pub fn push_pixel(&mut self, pixel: &[i32]) -> Vec<Vec<i32>> {
        let cfg = self.cfg;
        assert_eq!(pixel.len(), cfg.cin, "pixel width mismatch");
        let idx = self.pixels_seen;
        let (y, x) = (idx / cfg.in_w, idx % cfg.in_w);
        let row = &mut self.rows[y % cfg.k];
        row[x * cfg.cin..(x + 1) * cfg.cin].copy_from_slice(pixel);
        self.pixels_seen += 1;

        let mut patches = Vec::new();
        let total_out = cfg.out_h() * cfg.out_w();
        while self.next_out < total_out {
            let (oy, ox) = (self.next_out / cfg.out_w(), self.next_out % cfg.out_w());
            // Emit once the trigger pixel has passed. Strict equality is
            // wrong at clamped bottom/right edges: several outputs share a
            // clamped trigger, and raster order can put a *smaller*
            // trigger after a larger one (e.g. output (H-1, 0) after
            // (H-2, W-1) when both clamp to input row H-1).
            if cfg.trigger_index(oy, ox) > idx {
                break;
            }
            patches.push(self.extract(oy, ox));
            self.next_out += 1;
            self.emitted_this_image += 1;
        }

        // end of image: reset for the next one
        if self.pixels_seen == cfg.in_h * cfg.in_w {
            debug_assert_eq!(self.emitted_this_image, total_out, "convgen under-emitted");
            self.pixels_seen = 0;
            self.next_out = 0;
            self.emitted_this_image = 0;
        }
        patches
    }

    /// Extract the patch for output `(oy, ox)` from the line buffers,
    /// zero-filling out-of-bounds taps (exact for unsigned codes).
    fn extract(&self, oy: usize, ox: usize) -> Vec<i32> {
        let cfg = self.cfg;
        let mut patch = vec![0i32; cfg.k * cfg.k * cfg.cin];
        for i in 0..cfg.k {
            let y = (oy * cfg.stride + i) as isize - cfg.pad as isize;
            if y < 0 || y >= cfg.in_h as isize {
                continue;
            }
            let row = &self.rows[(y as usize) % cfg.k];
            for j in 0..cfg.k {
                let x = (ox * cfg.stride + j) as isize - cfg.pad as isize;
                if x < 0 || x >= cfg.in_w as isize {
                    continue;
                }
                let tap = i * cfg.k + j;
                let src = &row[(x as usize) * cfg.cin..(x as usize + 1) * cfg.cin];
                patch[tap * cfg.cin..(tap + 1) * cfg.cin].copy_from_slice(src);
            }
        }
        patch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Feed a whole image and collect patches; compare against a direct
    /// im2col implementation.
    fn run_image(cfg: ConvGenConfig, img: &[i32]) -> Vec<Vec<i32>> {
        let mut gen = ConvGenerator::new(cfg);
        let mut out = Vec::new();
        for p in img.chunks(cfg.cin) {
            out.extend(gen.push_pixel(p));
        }
        out
    }

    fn direct_im2col(cfg: ConvGenConfig, img: &[i32]) -> Vec<Vec<i32>> {
        let get = |y: isize, x: isize, c: usize| -> i32 {
            if y < 0 || x < 0 || y >= cfg.in_h as isize || x >= cfg.in_w as isize {
                0
            } else {
                img[(y as usize * cfg.in_w + x as usize) * cfg.cin + c]
            }
        };
        let mut out = Vec::new();
        for oy in 0..cfg.out_h() {
            for ox in 0..cfg.out_w() {
                let mut patch = Vec::with_capacity(cfg.k * cfg.k * cfg.cin);
                for i in 0..cfg.k {
                    for j in 0..cfg.k {
                        for c in 0..cfg.cin {
                            patch.push(get(
                                (oy * cfg.stride + i) as isize - cfg.pad as isize,
                                (ox * cfg.stride + j) as isize - cfg.pad as isize,
                                c,
                            ));
                        }
                    }
                }
                out.push(patch);
            }
        }
        out
    }

    fn test_img(cfg: &ConvGenConfig) -> Vec<i32> {
        (0..cfg.in_h * cfg.in_w * cfg.cin).map(|i| (i % 16) as i32).collect()
    }

    #[test]
    fn std_3x3_stride1_pad1() {
        let cfg = ConvGenConfig { in_h: 6, in_w: 6, cin: 3, k: 3, stride: 1, pad: 1 };
        let img = test_img(&cfg);
        assert_eq!(run_image(cfg, &img), direct_im2col(cfg, &img));
    }

    #[test]
    fn std_3x3_stride2() {
        let cfg = ConvGenConfig { in_h: 8, in_w: 8, cin: 2, k: 3, stride: 2, pad: 1 };
        let img = test_img(&cfg);
        let got = run_image(cfg, &img);
        assert_eq!(got.len(), cfg.out_h() * cfg.out_w());
        assert_eq!(got, direct_im2col(cfg, &img));
    }

    #[test]
    fn pointwise_1x1() {
        let cfg = ConvGenConfig { in_h: 4, in_w: 4, cin: 5, k: 1, stride: 1, pad: 0 };
        let img = test_img(&cfg);
        let got = run_image(cfg, &img);
        // pointwise: each patch is exactly the pixel, emitted immediately
        assert_eq!(got.len(), 16);
        assert_eq!(got, direct_im2col(cfg, &img));
    }

    #[test]
    fn non_square_input() {
        let cfg = ConvGenConfig { in_h: 5, in_w: 7, cin: 2, k: 3, stride: 1, pad: 1 };
        let img = test_img(&cfg);
        assert_eq!(run_image(cfg, &img), direct_im2col(cfg, &img));
    }

    #[test]
    fn resets_between_images() {
        let cfg = ConvGenConfig { in_h: 4, in_w: 4, cin: 1, k: 3, stride: 1, pad: 1 };
        let img1: Vec<i32> = (0..16).collect();
        let img2: Vec<i32> = (0..16).rev().collect();
        let mut gen = ConvGenerator::new(cfg);
        let mut got1 = Vec::new();
        for p in img1.chunks(1) {
            got1.extend(gen.push_pixel(p));
        }
        let mut got2 = Vec::new();
        for p in img2.chunks(1) {
            got2.extend(gen.push_pixel(p));
        }
        assert_eq!(got1, direct_im2col(cfg, &img1));
        assert_eq!(got2, direct_im2col(cfg, &img2));
    }

    #[test]
    fn line_buffer_sizing() {
        let cfg = ConvGenConfig { in_h: 112, in_w: 112, cin: 32, k: 3, stride: 1, pad: 1 };
        // 3 rows x 112 px x 32 ch x 4 bits
        assert_eq!(cfg.line_buffer_bits(4), 3 * 112 * 32 * 4);
    }
}
