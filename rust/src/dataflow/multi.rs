//! Multi-FPGA scaling (paper section 3.3): "Dataflow architecture is
//! inherently suited for design spanning multiple SLRs and can be scaled
//! up, enabling additional FPGAs connected via network for deploying
//! larger networks [Diaconu et al., HPEC'23]."
//!
//! This module partitions a synthesized design across several devices
//! connected by network links and models the resulting pipeline:
//! functional behaviour is unchanged (the partition only moves the FIFO
//! between two stages onto a network hop), throughput is the slowest of
//! {per-device stage bound, link bandwidth bound}, and latency gains the
//! per-hop link latency.
//!
//! The analytic plan is also *executable* (DESIGN.md S18):
//! [`MultiFpgaPlan::to_shards`] lowers the partition onto a compiled
//! [`NetworkPlan`], snapping each cut to the nearest residual-balanced
//! op boundary, and the resulting shards drive a
//! [`ShardChain`](super::pipeline::ShardChain) whose simulated FPS can
//! be checked against [`MultiFpgaPlan::fps`]. Serving and the CLI reach
//! the chain through the engine's `BackendKind::Sharded`
//! (DESIGN.md S19), which cuts with `NetworkPlan::shard_evenly`; this
//! module's partition stays the analytic overlay `lutmul multi --run`
//! cross-checks against.

use crate::fabric::device::FpgaDevice;
use crate::graph::arch::{ArchSpec, LayerSpec};
use crate::graph::plan::{NetworkPlan, PlanShard};
use crate::synth::design::{stage_resources, choose_mode};

/// A network link between consecutive devices in the chain.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Usable bandwidth (bytes/s), e.g. 100 GbE ~ 12.5e9 * 0.8.
    pub bandwidth_bps: f64,
    /// One-way latency (seconds), e.g. ~2 us for a switched 100 GbE hop.
    pub latency_s: f64,
}

impl LinkModel {
    /// 100 GbE with typical efficiency — the OCT testbed's fabric.
    pub fn gbe100() -> Self {
        Self { bandwidth_bps: 12.5e9 * 0.8, latency_s: 2e-6 }
    }

    /// Wire cycles to move one `ch`-element token of `bits`-wide codes
    /// at a device clock of `freq_mhz` (>= 1: a link faster than the
    /// pipeline's one-token-per-cycle issue rate cannot help further).
    pub fn cycles_per_token(&self, ch: usize, bits: u32, freq_mhz: f64) -> u64 {
        let bytes = ch as f64 * bits.max(1) as f64 / 8.0;
        (bytes * freq_mhz * 1e6 / self.bandwidth_bps).ceil().max(1.0) as u64
    }

    /// One-way hop latency in device cycles.
    pub fn latency_cycles(&self, freq_mhz: f64) -> u64 {
        (self.latency_s * freq_mhz * 1e6).round() as u64
    }
}

/// The placement of a contiguous slice of layers on one device.
#[derive(Debug, Clone)]
pub struct DevicePartition {
    pub device: String,
    pub first_layer: usize,
    pub last_layer: usize, // inclusive
    pub luts: f64,
    /// Steady-state cycles/image of the slowest stage on this device.
    pub bound_cycles: u64,
    /// Activation bytes crossing the link *out* of this device per image.
    pub egress_bytes: u64,
}

/// A multi-device plan.
#[derive(Debug, Clone)]
pub struct MultiFpgaPlan {
    pub partitions: Vec<DevicePartition>,
    pub link: LinkModel,
    pub freq_mhz: f64,
}

/// Activation bytes emitted by a layer per image (codes are `a_bits` wide).
fn egress_bytes(layer: &LayerSpec) -> u64 {
    let px = (layer.out_hw() * layer.out_hw()) as u64;
    px * layer.cout as u64 * layer.a_bits as u64 / 8
}

/// Greedy balanced partition of an architecture over `n` identical
/// devices: walk layers, cutting when the running LUT total exceeds an
/// equal share of the whole design (the same spill rule used for SLRs).
pub fn partition(
    arch: &ArchSpec,
    device: &FpgaDevice,
    n_devices: usize,
    folds: &[usize],
    link: LinkModel,
) -> MultiFpgaPlan {
    assert_eq!(folds.len(), arch.layers.len());
    assert!(n_devices >= 1);
    let per_layer: Vec<f64> = arch
        .layers
        .iter()
        .zip(folds)
        .map(|(l, &f)| stage_resources(l, choose_mode(l, f), f).0)
        .collect();
    let total: f64 = per_layer.iter().sum();
    let share = total / n_devices as f64;

    let mut partitions = Vec::new();
    let mut first = 0usize;
    let mut acc = 0.0f64;
    for (i, luts) in per_layer.iter().enumerate() {
        acc += luts;
        let last_device = partitions.len() + 1 == n_devices;
        if (acc >= share && !last_device) || i + 1 == arch.layers.len() {
            let bound = arch.layers[first..=i]
                .iter()
                .zip(&folds[first..=i])
                .map(|(l, &f)| (l.out_hw() * l.out_hw()) as u64 * f as u64)
                .max()
                .unwrap_or(1);
            partitions.push(DevicePartition {
                device: device.name.to_string(),
                first_layer: first,
                last_layer: i,
                luts: acc,
                bound_cycles: bound,
                egress_bytes: egress_bytes(&arch.layers[i]),
            });
            first = i + 1;
            acc = 0.0;
        }
    }
    MultiFpgaPlan { partitions, link, freq_mhz: device.max_freq_mhz }
}

impl MultiFpgaPlan {
    /// Steady-state FPS of the compute alone: the slowest device bound.
    pub fn compute_fps(&self) -> f64 {
        let f = self.freq_mhz * 1e6;
        self.partitions
            .iter()
            .map(|p| f / p.bound_cycles as f64)
            .fold(f64::INFINITY, f64::min)
    }

    /// Steady-state FPS of the inter-device links alone (infinite for a
    /// single device).
    pub fn link_fps(&self) -> f64 {
        self.partitions[..self.partitions.len().saturating_sub(1)]
            .iter()
            .map(|p| self.link.bandwidth_bps / p.egress_bytes.max(1) as f64)
            .fold(f64::INFINITY, f64::min)
    }

    /// Whether the links, not the devices, cap throughput.
    pub fn is_link_bound(&self) -> bool {
        self.link_fps() < self.compute_fps()
    }

    /// Steady-state FPS: min over {device compute bounds, link bounds}.
    pub fn fps(&self) -> f64 {
        self.compute_fps().min(self.link_fps())
    }

    /// Lower the analytic partition onto a compiled plan as executable
    /// shards (DESIGN.md S18). Arch layer `i` maps to the plan's `i`-th
    /// conv stage (the final arch layer is the dense head); each modeled
    /// cut snaps *forward* to the nearest residual-balanced op boundary,
    /// so trained networks with bypasses shard without splitting a tee
    /// from its join. Snapped cuts that collide are merged, so the chain
    /// may have fewer shards than the analytic plan has devices.
    pub fn to_shards(&self, plan: &NetworkPlan) -> anyhow::Result<Vec<PlanShard>> {
        let conv_ops: Vec<usize> = plan
            .ops
            .iter()
            .enumerate()
            .filter_map(|(i, op)| {
                matches!(op, crate::graph::plan::PlanOp::Conv(_)).then_some(i)
            })
            .collect();
        let depths = plan.res_depths();
        let mut cuts: Vec<usize> = Vec::new();
        for p in &self.partitions[..self.partitions.len().saturating_sub(1)] {
            // cut after the partition's last arch layer; layers at or past
            // the conv count live in the dense head, which cannot be cut off
            let Some(&conv_op) = conv_ops.get(p.last_layer) else { continue };
            let mut cut = conv_op + 1;
            while cut < plan.ops.len() && depths[cut] != 0 {
                cut += 1;
            }
            if cut < plan.ops.len() && cuts.last() != Some(&cut) {
                cuts.push(cut);
            }
        }
        plan.shard(&cuts)
    }

    /// Added end-to-end latency from the network hops.
    pub fn added_latency_s(&self) -> f64 {
        let hops = self.partitions.len().saturating_sub(1) as f64;
        // store-and-forward of one image's activations per hop + wire time
        let xfer: f64 = self.partitions[..self.partitions.len().saturating_sub(1)]
            .iter()
            .map(|p| p.egress_bytes as f64 / self.link.bandwidth_bps)
            .sum();
        hops * self.link.latency_s + xfer
    }

    /// Largest per-device LUT usage (the fit criterion).
    pub fn max_device_luts(&self) -> f64 {
        self.partitions.iter().map(|p| p.luts).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::U280;
    use crate::graph::arch::mobilenet_v2_full;
    use crate::synth::fold::{optimize_folding, Budget};

    fn setup() -> (ArchSpec, Vec<usize>) {
        let arch = mobilenet_v2_full();
        let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
        (arch, folds)
    }

    #[test]
    fn partitions_cover_all_layers_contiguously() {
        let (arch, folds) = setup();
        for n in [1usize, 2, 3, 4] {
            let plan = partition(&arch, &U280, n, &folds, LinkModel::gbe100());
            assert_eq!(plan.partitions.len(), n);
            assert_eq!(plan.partitions[0].first_layer, 0);
            assert_eq!(plan.partitions.last().unwrap().last_layer, arch.layers.len() - 1);
            for w in plan.partitions.windows(2) {
                assert_eq!(w[0].last_layer + 1, w[1].first_layer, "contiguous cut");
            }
        }
    }

    #[test]
    fn more_devices_reduce_per_device_footprint() {
        let (arch, folds) = setup();
        let one = partition(&arch, &U280, 1, &folds, LinkModel::gbe100());
        let four = partition(&arch, &U280, 4, &folds, LinkModel::gbe100());
        assert!(four.max_device_luts() < one.max_device_luts());
        // balanced within ~3x (layer granularity limits perfection)
        let min = four.partitions.iter().map(|p| p.luts).fold(f64::INFINITY, f64::min);
        assert!(four.max_device_luts() / min.max(1.0) < 3.0);
    }

    #[test]
    fn link_never_bottlenecks_mobilenet_on_100gbe() {
        // activations between MobileNetV2 layers are tiny vs 100 GbE
        let (arch, folds) = setup();
        let plan = partition(&arch, &U280, 3, &folds, LinkModel::gbe100());
        let f = plan.freq_mhz * 1e6;
        let compute_fps = plan
            .partitions
            .iter()
            .map(|p| f / p.bound_cycles as f64)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(plan.fps(), compute_fps, "compute-bound, not link-bound");
    }

    #[test]
    fn slow_link_becomes_the_bottleneck() {
        let (arch, folds) = setup();
        let slow = LinkModel { bandwidth_bps: 1e6, latency_s: 1e-3 };
        let plan = partition(&arch, &U280, 2, &folds, slow);
        let fast = partition(&arch, &U280, 2, &folds, LinkModel::gbe100());
        assert!(plan.fps() < fast.fps());
        assert!(plan.added_latency_s() > fast.added_latency_s());
    }

    #[test]
    fn single_device_has_no_link_overhead() {
        let (arch, folds) = setup();
        let plan = partition(&arch, &U280, 1, &folds, LinkModel::gbe100());
        assert_eq!(plan.added_latency_s(), 0.0);
        assert!(!plan.is_link_bound(), "one device has no links to bind on");
        assert_eq!(plan.fps(), plan.compute_fps());
    }

    #[test]
    fn bound_split_flags_the_actual_bottleneck() {
        let (arch, folds) = setup();
        let fast = partition(&arch, &U280, 3, &folds, LinkModel::gbe100());
        assert!(!fast.is_link_bound());
        assert_eq!(fast.fps(), fast.compute_fps());
        let slow = partition(
            &arch,
            &U280,
            3,
            &folds,
            LinkModel { bandwidth_bps: 1e6, latency_s: 1e-3 },
        );
        assert!(slow.is_link_bound());
        assert_eq!(slow.fps(), slow.link_fps());
    }

    #[test]
    fn link_cycle_conversion() {
        let l = LinkModel::gbe100();
        // 2us at 333 MHz
        assert_eq!(l.latency_cycles(333.0), 666);
        // a link faster than one token/cycle clamps to 1
        assert_eq!(l.cycles_per_token(3, 4, 333.0), 1);
        // 1 MB/s link: a 16-ch 4-bit token (8 B) takes 8e-6 s = 2664 cycles
        let slow = LinkModel { bandwidth_bps: 1e6, latency_s: 0.0 };
        assert_eq!(slow.cycles_per_token(16, 4, 333.0), 2664);
    }

    #[test]
    fn to_shards_tiles_the_compiled_plan() {
        use crate::graph::mobilenet_v2_small;
        use crate::graph::network::Network;
        use crate::graph::plan::{Datapath, NetworkPlan};
        let arch = mobilenet_v2_small();
        let folds = vec![1usize; arch.layers.len()];
        let net = Network::synthetic(&arch, 0x5A0);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        for n in [1usize, 2, 3] {
            let mplan = partition(&arch, &U280, n, &folds, LinkModel::gbe100());
            let shards = mplan.to_shards(&plan).unwrap();
            assert!(!shards.is_empty() && shards.len() <= n);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, plan.ops.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert_eq!((w[0].out_pixels, w[0].out_ch), (w[1].in_pixels, w[1].in_ch));
            }
            assert!(shards.last().unwrap().is_tail());
            let convs: usize = shards.iter().map(|s| s.plan.n_convs()).sum();
            assert_eq!(convs, plan.n_convs());
            if n > 1 {
                assert!(shards.len() > 1, "small net has boundaries for {n} devices");
            }
        }
    }
}
