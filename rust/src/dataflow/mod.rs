//! Reconfigurable dataflow architecture (DESIGN.md S6-S7): streaming
//! convolution generator, bounded FIFOs, and the cycle-level pipeline
//! simulator that executes a streamlined network exactly as the generated
//! accelerator would — all layers resident, activations flowing on-chip.

pub mod convgen;
pub mod multi;
pub mod fifo;
pub mod pipeline;

pub use convgen::{ConvGenConfig, ConvGenerator};
pub use fifo::Fifo;
pub use pipeline::{FoldConfig, Pipeline, SimReport, StageStat};
