//! Reconfigurable dataflow architecture (DESIGN.md S6-S7, S18):
//! streaming convolution generator, bounded FIFOs, the cycle-level
//! pipeline simulator that executes a streamlined network exactly as the
//! generated accelerator would — all layers resident, activations
//! flowing on-chip — and the multi-device layer: plan shards linked by
//! bandwidth/latency-charged channels into an executable [`ShardChain`].

pub mod convgen;
pub mod multi;
pub mod fifo;
pub mod pipeline;

pub use convgen::{ConvGenConfig, ConvGenerator};
pub use fifo::{Fifo, LinkChannel};
pub use pipeline::{
    ChainReport, FoldConfig, LinkStat, Pipeline, ShardChain, ShardCounters, ShardReport,
    SimError, SimReport, StageStat,
};
