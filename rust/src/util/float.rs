//! f32 ULP comparison.
//!
//! The integer network is bit-exact end to end; the only floating-point
//! op is the final dense `acc * scale + bias`. XLA's CPU backend lowers
//! it as a fused multiply-add while jax's CPU jit keeps mul+add separate,
//! so the two golden sources legitimately differ by 1 ULP. Comparisons
//! against the JSON golden therefore allow a configurable ULP distance
//! (default 1); comparisons among Rust/PJRT paths stay exact.

/// Distance in units-in-the-last-place between two f32s.
pub fn ulp_distance(a: f32, b: f32) -> u32 {
    if a == b {
        return 0; // covers -0.0 == 0.0
    }
    if a.is_nan() || b.is_nan() || a.is_sign_positive() != b.is_sign_positive() {
        return u32::MAX;
    }
    let ia = a.abs().to_bits();
    let ib = b.abs().to_bits();
    ia.abs_diff(ib)
}

/// True when every element pair is within `max_ulps`.
pub fn slices_ulp_eq(a: &[f32], b: &[f32], max_ulps: u32) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(&x, &y)| ulp_distance(x, y) <= max_ulps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_zero() {
        assert_eq!(ulp_distance(1.5, 1.5), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
    }

    #[test]
    fn adjacent_is_one() {
        let a = 1.0f32;
        let b = f32::from_bits(a.to_bits() + 1);
        assert_eq!(ulp_distance(a, b), 1);
        assert_eq!(ulp_distance(-a, -b), 1);
    }

    #[test]
    fn sign_mismatch_is_max() {
        assert_eq!(ulp_distance(1.0, -1.0), u32::MAX);
        assert_eq!(ulp_distance(f32::NAN, 1.0), u32::MAX);
    }

    #[test]
    fn slice_compare() {
        let a = [1.0f32, 2.0, -3.0];
        let mut b = a;
        b[1] = f32::from_bits(b[1].to_bits() + 1);
        assert!(slices_ulp_eq(&a, &b, 1));
        assert!(!slices_ulp_eq(&a, &b, 0));
        assert!(!slices_ulp_eq(&a, &b[..2], 1));
    }
}
