//! Minimal benchmark harness (no criterion in the vendored crate set).
//!
//! `bench(name, iters, f)` runs a warmup, then `iters` timed runs, and
//! reports min/median/mean — enough to track the §Perf iteration log in
//! EXPERIMENTS.md. All benches are plain `fn main` binaries
//! (`harness = false`).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12?} min {:>12?} med {:>12?} mean ({} iters)",
            self.name, self.min, self.median, self.mean, self.iters
        )
    }
}

/// Time `f` over `iters` runs (after one warmup); prints and returns stats.
pub fn bench<R>(name: &str, iters: usize, f: impl FnMut() -> R) -> BenchResult {
    bench_warm(name, 1, iters, f)
}

/// Like [`bench`] but with an explicit warmup count: `warmup` untimed
/// runs settle caches, branch predictors and the first-touch page
/// faults of freshly grown arenas before the `iters` timed runs. Gates
/// compare the reported **median**, so a single preempted run can't
/// flip a threshold — the warmup+median-of-k recipe `make kernel-smoke`
/// relies on for stable ratios.
pub fn bench_warm<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchResult {
    for _ in 0..warmup.max(1) {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let r = BenchResult { name: name.to_string(), iters: times.len(), min, median, mean };
    println!("{r}");
    r
}

/// Throughput helper: items/s at the median time.
pub fn per_second(items: usize, r: &BenchResult) -> f64 {
    items as f64 / r.median.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 5, || 42);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median >= Duration::ZERO);
    }

    #[test]
    fn bench_warm_runs_warmups_then_iters() {
        let mut calls = 0usize;
        let r = bench_warm("warm", 3, 4, || calls += 1);
        assert_eq!(calls, 3 + 4, "3 warmups + 4 timed runs");
        assert_eq!(r.iters, 4);
    }

    #[test]
    fn per_second_scales() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            min: Duration::from_millis(10),
            median: Duration::from_millis(10),
            mean: Duration::from_millis(10),
        };
        assert!((per_second(100, &r) - 10_000.0).abs() < 1e-6);
    }
}
