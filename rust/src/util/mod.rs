//! Small self-contained substrates: JSON interchange and deterministic
//! property testing (the offline vendored crate set has neither
//! `serde_json` nor `proptest`).

pub mod bench;
pub mod float;
pub mod json;
pub mod prop;

pub use float::{slices_ulp_eq, ulp_distance};
pub use json::Json;
pub use prop::Rng;
