//! Minimal JSON parser/writer (substrate, DESIGN.md S5).
//!
//! The offline vendored crate set has no `serde_json`, so the artifact
//! interchange (`network.json`, `fig2_accuracy.json`) is handled by this
//! small, well-tested recursive-descent implementation. Numbers are f64
//! (exact for every integer the export contains: weight codes, int32
//! thresholds and counts are all < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, ensure, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access with a useful error.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        ensure!(f.fract() == 0.0 && f.abs() < 2f64.powi(53), "not an integer: {f}");
        Ok(f as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        ensure!(i >= 0, "negative where usize expected: {i}");
        Ok(i as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    /// `[1,2,3]` -> `Vec<i32>`.
    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_i64()? as i32)).collect()
    }

    /// `[[..],[..]]` -> `Vec<Vec<i32>>`.
    pub fn as_i32_mat(&self) -> Result<Vec<Vec<i32>>> {
        self.as_arr()?.iter().map(Json::as_i32_vec).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of input")
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek()? == c, "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "invalid literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs not needed for our artifacts;
                            // map unpaired surrogates to the replacement char
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    ensure!(start + len <= self.b.len(), "truncated UTF-8");
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        ensure!(self.i > start, "invalid value at byte {start}");
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": -7}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.field("a").unwrap().as_arr().unwrap()[2].field("b").unwrap().as_i64().unwrap(),
            -7
        );
        assert_eq!(j.field("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn i32_matrix() {
        let j = Json::parse("[[1,-3],[7,-8]]").unwrap();
        assert_eq!(j.as_i32_mat().unwrap(), vec![vec![1, -3], vec![7, -8]]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"meta":{"n":3,"s":0.25},"ops":[{"op":"input"},[1,2,3]]}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café \t ok""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café \t ok");
        let j = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" \n\t{ \"a\" :\n[ 1 , 2 ] } ").unwrap();
        assert_eq!(j.field("a").unwrap().as_i32_vec().unwrap(), vec![1, 2]);
    }
}
