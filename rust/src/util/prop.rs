//! Tiny deterministic property-testing helper (the vendored crate set has
//! no `proptest`). A seeded SplitMix64/LCG generator plus a `cases` runner
//! that reports the failing seed, so any failure reproduces exactly.

/// Deterministic pseudo-random generator (SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        lo + (self.below((hi - lo) as u64 + 1) as i32)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Random i32 vector with entries in `[lo, hi]`.
    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i32(lo, hi)).collect()
    }
}

/// Run `n` property cases; on failure, panics with the offending seed.
pub fn cases(n: u64, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed.wrapping_mul(0x51_7c_c1_b7_27_22_0a_95));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.range_i32(-8, 7);
            assert!((-8..=7).contains(&v));
            let f = r.range_f64(0.5, 2.0);
            assert!((0.5..2.0).contains(&f));
        }
    }

    #[test]
    fn below_covers_domain() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cases_runs_all() {
        let mut count = 0;
        cases(25, |_| count += 1);
        assert_eq!(count, 25);
    }
}
