//! One Engine API (DESIGN.md S19): a unified session builder plus the
//! [`InferenceBackend`] trait over every run surface of the stack.
//!
//! * [`backend`] — the uniform inference contract: [`InferenceBackend`]
//!   (`infer_batch(&[Vec<i32>]) -> BatchOutput`) implemented by the
//!   reference executor, the cycle-level dataflow pipeline, the
//!   multi-device shard chain, and the (feature-gated) PJRT runtime.
//! * [`builder`] — [`Engine::builder()`]: the one place that resolves
//!   artifact-or-synthetic networks, optimizes folding, compiles the
//!   [`NetworkPlan`](crate::graph::plan::NetworkPlan) and constructs
//!   backends over it.
//!
//! The serving coordinator's workers, the CLI subcommands, the benches
//! and the conformance suite (`rust/tests/engine.rs`) all drive
//! batches through this module; `lutmul bench --backends all` prints
//! the cross-backend bit-exactness + throughput comparison.

pub mod backend;
pub mod builder;

pub use backend::{
    BatchOutput, ExecutorBackend, InferenceBackend, PipelineBackend, PjrtBackend,
    ShardChainBackend,
};
pub use builder::{
    Arch, BackendFactory, BackendKind, Engine, EngineBuilder, Folding, NetworkSource,
};
