//! [`EngineBuilder`] — one fluent construction path for the whole
//! inference stack (DESIGN.md S19).
//!
//! Every entry point used to hand-assemble its own stack: arch spec →
//! fold/budget optimization → artifact-or-synthetic network → plan
//! compile → one of four mutually incompatible run surfaces. The
//! builder owns each of those steps exactly once:
//!
//! ```no_run
//! use lutmul::engine::{Arch, BackendKind, Engine};
//! use lutmul::runtime::Artifacts;
//!
//! # fn main() -> anyhow::Result<()> {
//! let a = Artifacts::new("artifacts");
//! let mut engine = Engine::builder()
//!     .arch(Arch::Small)
//!     .artifacts(&a)          // trained network.json when present...
//!     .or_synthetic(0x5EED)   // ...its synthetic twin otherwise
//!     .backend(BackendKind::Sharded { devices: 2 })
//!     .build()?;
//! let images = engine.images(4)?;
//! let out = engine.infer_batch(&images)?;
//! # Ok(()) }
//! ```
//!
//! The resulting [`Engine`] owns the network, the compiled
//! [`NetworkPlan`] (shared by every backend it constructs), the fold
//! configuration, and one ready [`InferenceBackend`]. Further backends
//! over the same plan come from [`Engine::make_backend`] (comparison
//! tables, golden cross-checks) and [`Engine::backend_factory`] (the
//! serving coordinator's per-worker construction + rebuild-on-failure).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::dataflow::multi::LinkModel;
use crate::dataflow::FoldConfig;
use crate::fabric::device::U280;
use crate::graph::approx::ApproxSpec;
use crate::graph::arch::ArchSpec;
use crate::graph::network::Network;
use crate::graph::plan::{Datapath, IoGeom, NetworkPlan};
use crate::graph::prune::PruneSpec;
use crate::graph::{mobilenet_v2_full, mobilenet_v2_small};
use crate::runtime::Artifacts;
use crate::synth::fold::{optimize_folding, Budget};

use super::backend::{
    BatchOutput, ExecutorBackend, InferenceBackend, PipelineBackend, PjrtBackend,
    ShardChainBackend,
};

/// Architecture selection: which MobileNetV2 shape spec drives the fold
/// optimizer and the synthetic-network fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// `mobilenet_v2_small` — the trained-artifact shape.
    Small,
    /// `mobilenet_v2_full` — the paper's ImageNet-scale shape.
    Full,
}

impl Arch {
    pub fn spec(self) -> ArchSpec {
        match self {
            Arch::Small => mobilenet_v2_small(),
            Arch::Full => mobilenet_v2_full(),
        }
    }
}

/// Which [`InferenceBackend`] the engine constructs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Whole-network reference executor (the serving fast path).
    Reference,
    /// Cycle-level dataflow pipeline simulator.
    Pipeline,
    /// The network sliced across `devices` simulated FPGAs joined by
    /// cycle-charged links (DESIGN.md S18).
    Sharded { devices: usize },
    /// PJRT runtime executing the AOT HLO artifact compiled for `batch`
    /// (needs `artifacts(..)`; loads only with the `xla` feature).
    Pjrt { batch: usize },
}

impl BackendKind {
    /// Stable short label (comparison tables, skip messages).
    pub fn label(&self) -> String {
        match *self {
            BackendKind::Reference => "executor".into(),
            BackendKind::Pipeline => "pipeline".into(),
            BackendKind::Sharded { devices } => format!("sharded x{devices}"),
            BackendKind::Pjrt { batch } => format!("pjrt b{batch}"),
        }
    }
}

/// Per-layer fold (initiation interval) selection.
#[derive(Debug, Clone)]
pub enum Folding {
    /// II = 1 on every conv stage (the serving default).
    FullyParallel,
    /// Uniform fold factor on every conv stage.
    Uniform(usize),
    /// `synth::fold::optimize_folding` against a device budget — errors
    /// at `build()` when the optimizer's fold vector (sized by the
    /// `Arch` spec) cannot cover the network's conv stages, i.e. the
    /// network was built from a different model than the spec.
    Optimized(Budget),
    /// An explicit fold vector the caller already computed (e.g. the
    /// arch-level vector an analytic multi-FPGA partition was cut with,
    /// head entry included — `lutmul multi --run` optimizes once and
    /// feeds both the partition and the engine). Validated and
    /// truncated to the plan's conv count like `Optimized`.
    Explicit(FoldConfig),
}

/// How the engine obtained its network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetworkSource {
    /// Loaded from `artifacts/network.json`.
    Trained,
    /// `Network::synthetic` twin of the arch spec (artifacts absent).
    Synthetic { seed: u64 },
    /// Injected directly via [`EngineBuilder::network`] (tests).
    Injected,
}

impl NetworkSource {
    pub fn label(&self) -> &'static str {
        match self {
            NetworkSource::Trained => "trained artifacts",
            NetworkSource::Synthetic { .. } => "synthetic network",
            NetworkSource::Injected => "injected network",
        }
    }
}

/// Thread-safe backend constructor: each call builds an independent
/// [`InferenceBackend`] over the engine's shared compiled plan (the
/// serving coordinator hands one to every worker, and workers rebuild
/// through it after a failed batch).
pub type BackendFactory = Arc<dyn Fn() -> Result<Box<dyn InferenceBackend>> + Send + Sync>;

/// Everything needed to construct backends over one compiled plan —
/// cloned into [`BackendFactory`] closures.
#[derive(Clone)]
struct BackendEnv {
    plan: Arc<NetworkPlan>,
    folds: FoldConfig,
    fifo_depth: usize,
    link: LinkModel,
    freq_mhz: f64,
    a_bits: u32,
    artifacts_dir: Option<PathBuf>,
}

impl BackendEnv {
    /// `pool_size` is the number of concurrent backends sharing the
    /// machine: executor backends split the cores evenly so a worker
    /// pool never oversubscribes the CPU.
    fn build(&self, kind: &BackendKind, pool_size: usize) -> Result<Box<dyn InferenceBackend>> {
        let backend: Box<dyn InferenceBackend> = match *kind {
            BackendKind::Reference => {
                let cores = std::thread::available_parallelism()
                    .map(std::num::NonZeroUsize::get)
                    .unwrap_or(1);
                let threads = (cores / pool_size.max(1)).max(1);
                Box::new(ExecutorBackend::new(self.plan.clone(), threads))
            }
            BackendKind::Pipeline => {
                Box::new(PipelineBackend::new(&self.plan, &self.folds, self.fifo_depth))
            }
            BackendKind::Sharded { devices } => Box::new(ShardChainBackend::new(
                &self.plan,
                devices,
                &self.folds,
                self.fifo_depth,
                &self.link,
                self.freq_mhz,
                self.a_bits,
            )?),
            BackendKind::Pjrt { batch } => {
                let dir = self.artifacts_dir.as_ref().context(
                    "the PJRT backend needs an artifact directory (EngineBuilder::artifacts)",
                )?;
                let batch = batch.max(1);
                let a = Artifacts::new(dir.clone());
                Box::new(PjrtBackend::load(a.model_hlo(batch), batch, &self.plan.io)?)
            }
        };
        Ok(backend)
    }
}

/// Fluent builder for an [`Engine`]; see the module docs for the shape.
pub struct EngineBuilder {
    arch: Arch,
    artifacts_dir: Option<PathBuf>,
    synthetic_seed: Option<u64>,
    injected: Option<Network>,
    datapath: Datapath,
    prune: Option<PruneSpec>,
    approx: Option<ApproxSpec>,
    kind: BackendKind,
    folding: Folding,
    fifo_depth: usize,
    link: LinkModel,
    freq_mhz: f64,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self {
            arch: Arch::Small,
            artifacts_dir: None,
            synthetic_seed: None,
            injected: None,
            datapath: Datapath::Arithmetic,
            prune: None,
            approx: None,
            kind: BackendKind::Reference,
            folding: Folding::FullyParallel,
            fifo_depth: 16,
            link: LinkModel::gbe100(),
            freq_mhz: U280.max_freq_mhz,
        }
    }
}

impl EngineBuilder {
    /// Architecture spec for folding and the synthetic fallback.
    pub fn arch(mut self, arch: Arch) -> Self {
        self.arch = arch;
        self
    }

    /// Load the trained network (and test set / HLO artifacts) from this
    /// artifact directory.
    pub fn artifacts(mut self, a: &Artifacts) -> Self {
        self.artifacts_dir = Some(a.dir.clone());
        self
    }

    /// Fall back to the arch spec's synthetic twin (seeded) when the
    /// artifacts are absent or fail to load.
    pub fn or_synthetic(mut self, seed: u64) -> Self {
        self.synthetic_seed = Some(seed);
        self
    }

    /// Inject a network directly, bypassing artifact loading (tests and
    /// embedders that already hold a `Network`).
    pub fn network(mut self, net: Network) -> Self {
        self.injected = Some(net);
        self
    }

    /// Multiply datapath the plan is compiled for (every backend the
    /// engine constructs shares the one compiled plan).
    pub fn datapath(mut self, datapath: Datapath) -> Self {
        self.datapath = datapath;
        self
    }

    /// Structured pruning pass applied at plan-compile time (DESIGN.md
    /// S23): the plan is compiled through `NetworkPlan::compile_pruned`,
    /// so every backend the engine constructs — executor, pipeline,
    /// sharded — runs the compacted sparse kernels. A noop spec compiles
    /// the plain dense plan.
    pub fn prune(mut self, spec: PruneSpec) -> Self {
        self.prune = Some(spec);
        self
    }

    /// Maddness-style approximate datapath (DESIGN.md S24): the plan is
    /// compiled through `NetworkPlan::compile_approx`, so every backend
    /// the engine constructs — executor, pipeline, sharded — hashes
    /// eligible std/pw layers through trained codebooks instead of
    /// exact LUT tables. Approximate by construction (see `lutmul
    /// eval`); does not compose with [`prune`](Self::prune).
    pub fn approx(mut self, spec: ApproxSpec) -> Self {
        self.approx = Some(spec);
        self
    }

    /// Which backend [`build`](Self::build) constructs (and the factory
    /// reproduces).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.kind = kind;
        self
    }

    /// Fold selection for cycle-modeled backends (default fully
    /// parallel).
    pub fn folding(mut self, folding: Folding) -> Self {
        self.folding = folding;
        self
    }

    /// Inter-stage FIFO depth for cycle-modeled backends (default 16).
    pub fn fifo_depth(mut self, depth: usize) -> Self {
        self.fifo_depth = depth.max(1);
        self
    }

    /// Inter-device link model for sharded backends (default 100 GbE).
    pub fn link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    /// Resolve the network source, compile the plan once, optimize
    /// folding, and construct the selected backend.
    ///
    /// The engine's own backend is built eagerly so a misconfigured
    /// selection fails here, at the construction site. An engine used
    /// purely as a coordinator factory carries that one idle backend —
    /// a deliberate trade for loud-at-build errors (the plan itself is
    /// shared, not duplicated).
    pub fn build(self) -> Result<Engine> {
        let spec = self.arch.spec();
        let (net, source) = if let Some(net) = self.injected {
            (net, NetworkSource::Injected)
        } else if let Some(dir) = &self.artifacts_dir {
            let a = Artifacts::new(dir.clone());
            match Network::load(a.network_json()) {
                Ok(net) => (net, NetworkSource::Trained),
                Err(e) => match self.synthetic_seed {
                    Some(seed) => {
                        (Network::synthetic(&spec, seed), NetworkSource::Synthetic { seed })
                    }
                    None => {
                        return Err(e.context(format!(
                            "no usable network: loading {} failed and no synthetic fallback \
                             is configured (EngineBuilder::or_synthetic)",
                            a.network_json().display()
                        )))
                    }
                },
            }
        } else if let Some(seed) = self.synthetic_seed {
            (Network::synthetic(&spec, seed), NetworkSource::Synthetic { seed })
        } else {
            anyhow::bail!(
                "EngineBuilder needs a network source: artifacts(..), or_synthetic(..) \
                 or network(..)"
            )
        };

        anyhow::ensure!(
            self.prune.is_none() || self.approx.is_none(),
            "EngineBuilder::prune and ::approx do not compose — a compacted weight \
             matrix would retrain different codebooks; pick one"
        );
        let plan = Arc::new(match (&self.prune, &self.approx) {
            (Some(spec), _) => NetworkPlan::compile_pruned(&net, self.datapath, spec),
            (None, Some(aspec)) => NetworkPlan::compile_approx(&net, self.datapath, aspec),
            (None, None) => NetworkPlan::compile(&net, self.datapath),
        });
        let folds = match self.folding {
            Folding::FullyParallel => FoldConfig::fully_parallel(plan.n_convs()),
            Folding::Uniform(fold) => FoldConfig::uniform(plan.n_convs(), fold),
            Folding::Optimized(budget) => {
                // the optimizer folds the arch spec's layers (head
                // included); the compiled plan's conv stages consume the
                // leading entries
                let (folds, _) = optimize_folding(&spec, &budget);
                anyhow::ensure!(
                    folds.len() >= plan.n_convs(),
                    "the {} architecture optimizes {} fold factors but the network has {} \
                     conv layers — the network was built from a different model than \
                     EngineBuilder::arch selects",
                    spec.name,
                    folds.len(),
                    plan.n_convs()
                );
                FoldConfig { folds: folds[..plan.n_convs()].to_vec() }
            }
            Folding::Explicit(cfg) => {
                anyhow::ensure!(
                    cfg.folds.len() >= plan.n_convs(),
                    "the explicit fold vector has {} entries but the network has {} conv \
                     layers",
                    cfg.folds.len(),
                    plan.n_convs()
                );
                FoldConfig { folds: cfg.folds[..plan.n_convs()].to_vec() }
            }
        };

        let env = BackendEnv {
            plan,
            folds,
            fifo_depth: self.fifo_depth,
            link: self.link,
            freq_mhz: self.freq_mhz,
            a_bits: net.meta.a_bits.max(1),
            artifacts_dir: self.artifacts_dir,
        };
        let backend = env.build(&self.kind, 1)?;
        Ok(Engine { net: Arc::new(net), source, kind: self.kind, env, backend })
    }
}

/// A fully assembled inference session: the network, its compiled plan,
/// the fold configuration, and one ready [`InferenceBackend`] — plus
/// constructors for further backends over the same plan.
pub struct Engine {
    net: Arc<Network>,
    source: NetworkSource,
    kind: BackendKind,
    env: BackendEnv,
    backend: Box<dyn InferenceBackend>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// The deployed network (shared with the serving metrics, which read
    /// `ops_per_image` for the GOPS denominator).
    pub fn net(&self) -> &Arc<Network> {
        &self.net
    }

    /// The one compiled plan every backend of this engine runs over.
    pub fn plan(&self) -> &NetworkPlan {
        &self.env.plan
    }

    /// I/O geometry of the deployed network.
    pub fn io(&self) -> IoGeom {
        self.env.plan.io
    }

    /// The resolved per-conv fold configuration.
    pub fn folds(&self) -> &FoldConfig {
        &self.env.folds
    }

    /// How the network was obtained (trained / synthetic / injected).
    pub fn source(&self) -> NetworkSource {
        self.source
    }

    /// The backend kind this engine was built for.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// The engine's own backend.
    pub fn backend(&mut self) -> &mut dyn InferenceBackend {
        self.backend.as_mut()
    }

    /// Name of the engine's own backend.
    pub fn backend_name(&self) -> &str {
        self.backend.name()
    }

    /// Run one batch on the engine's own backend.
    pub fn infer_batch(&mut self, images: &[Vec<i32>]) -> Result<BatchOutput> {
        self.backend.infer_batch(images)
    }

    /// Construct a fresh backend of any kind over the engine's compiled
    /// plan — comparison tables, golden cross-checks, extra workers.
    pub fn make_backend(&self, kind: BackendKind) -> Result<Box<dyn InferenceBackend>> {
        self.env.build(&kind, 1)
    }

    /// A thread-safe factory for the engine's own backend kind.
    /// `pool_size` is the number of concurrent backends that will share
    /// the machine (executor backends split the cores evenly).
    pub fn backend_factory(&self, pool_size: usize) -> BackendFactory {
        let env = self.env.clone();
        let kind = self.kind;
        Arc::new(move || env.build(&kind, pool_size))
    }

    /// A thread-safe factory for an *explicit* backend kind over this
    /// engine's compiled plan — the heterogeneous-fleet seam
    /// (DESIGN.md S25): one engine hands out executor-replica factories
    /// to the latency pool and shard-chain factories to the throughput
    /// pool, and the fleet rebuilds failed backends through the same
    /// closure.
    pub fn backend_factory_for(&self, kind: BackendKind, pool_size: usize) -> BackendFactory {
        let env = self.env.clone();
        Arc::new(move || env.build(&kind, pool_size))
    }

    /// `n` test images for the engine's network: the leading artifact
    /// test images (cycled if `n` exceeds the set) for a trained
    /// network, seeded random code vectors otherwise.
    pub fn images(&self, n: usize) -> Result<Vec<Vec<i32>>> {
        let n = n.max(1);
        match self.source {
            NetworkSource::Trained => {
                let (images, _) = self.artifacts()?.load_test_set_for(&self.io())?;
                anyhow::ensure!(!images.is_empty(), "artifact test set is empty");
                Ok(images.into_iter().cycle().take(n).collect())
            }
            _ => {
                let io = self.io();
                let px = io.image_size * io.image_size * io.in_ch;
                let mut rng = crate::util::prop::Rng::new(0x1234_5678);
                Ok((0..n).map(|_| rng.vec_i32(px, 0, 15)).collect())
            }
        }
    }

    /// The labeled artifact test set — trained networks only (synthetic
    /// networks have no ground truth).
    pub fn labeled_test_set(&self) -> Result<(Vec<Vec<i32>>, Vec<u8>)> {
        anyhow::ensure!(
            self.source == NetworkSource::Trained,
            "labels exist only for the trained artifact test set (this engine runs a {})",
            self.source.label()
        );
        self.artifacts()?.load_test_set_for(&self.io())
    }

    fn artifacts(&self) -> Result<Artifacts> {
        let dir = self
            .env
            .artifacts_dir
            .as_ref()
            .context("engine has no artifact directory (EngineBuilder::artifacts)")?;
        Ok(Artifacts::new(dir.clone()))
    }
}
