//! The uniform inference contract (DESIGN.md S19).
//!
//! Every run surface of the stack — the reference integer executor, the
//! cycle-level dataflow pipeline, the multi-device shard chain, and the
//! PJRT runtime — implements [`InferenceBackend`], so callers (the CLI,
//! the serving coordinator's workers, benches, tests) drive batches
//! through one boxed trait object instead of matching on
//! backend-specific types. LUT-based inference stacks such as NeuraLUT
//! and PolyLUT-Add treat the LUT datapath as one interchangeable
//! backend behind a fixed contract; this module gives rust_pallas the
//! same seam, so a new backend (or serving mode) is a single trait
//! impl, not a change to every caller.
//!
//! All backends run over the same compiled [`NetworkPlan`] (DESIGN.md
//! S17), so bit-exactness across them holds by construction — the
//! `lutmul bench --backends all` subcommand and the conformance suite
//! (`rust/tests/engine.rs`) assert it on every build.

use anyhow::Result;

use crate::dataflow::multi::LinkModel;
use crate::dataflow::{FoldConfig, Pipeline, ShardChain, ShardCounters};
use crate::graph::executor::{Executor, Tensor};
use crate::graph::plan::{IoGeom, NetworkPlan};
use crate::graph::scratch::ScratchPool;
use crate::runtime::Runtime;

/// Uniform result of one dispatched batch, whatever backend ran it.
#[derive(Debug, Clone, Default)]
pub struct BatchOutput {
    /// Per-image logits, in submission order.
    pub logits: Vec<Vec<f32>>,
    /// Simulated device cycles this batch consumed (0 for backends
    /// without a cycle model: the executor and the PJRT runtime).
    pub cycles: u64,
    /// Cumulative per-shard occupancy/stall counters (sharded backends
    /// only — empty otherwise).
    pub counters: Vec<ShardCounters>,
}

/// One inference backend behind the engine's uniform contract: a batch
/// of flat `[H*W*C]` code images in, a [`BatchOutput`] out.
///
/// Implementations are `Send` (the serving coordinator moves each
/// worker's backend into its thread) and stateful across batches —
/// persistent backends amortize their compiled plans, line buffers and
/// LUT product tables over every batch they serve.
pub trait InferenceBackend: Send {
    /// Stable short name for logs and comparison tables.
    fn name(&self) -> &str;

    /// Run one batch to per-image logits. A backend whose `infer_batch`
    /// fails must be discarded and rebuilt (a failed pipeline/chain
    /// still holds the dead batch's partial-image tokens).
    fn infer_batch(&mut self, images: &[Vec<i32>]) -> Result<BatchOutput>;

    /// Cumulative per-shard occupancy counters (sharded backends only —
    /// empty otherwise). Readable even after a failed batch, so the
    /// serving worker can bank a dying chain's counters before
    /// rebuilding it.
    fn shard_occupancy(&self) -> Vec<ShardCounters> {
        Vec::new()
    }

    /// Analytic steady-state cycles per image, for cycle-modeled
    /// backends (`None` for the executor and the PJRT runtime).
    fn steady_cycles(&self) -> Option<u64> {
        None
    }
}

/// The reference integer executor behind the uniform contract
/// (spec-level, batch-major across `threads` cores — DESIGN.md S22
/// batch-major layer sweeps through `Executor::run_batch_into`). Owns
/// a persistent [`ScratchPool`] of per-thread tensor arenas (DESIGN.md
/// S20), so a serving worker's steady-state batches run the
/// zero-allocation kernel path — working buffers are sized once and
/// reused for the backend's lifetime.
pub struct ExecutorBackend {
    ex: Executor,
    io: IoGeom,
    threads: usize,
    name: &'static str,
    pool: ScratchPool,
    /// Drive the image-major witness path instead of the batch-major
    /// sweeps (see [`image_major`](Self::image_major)).
    image_major: bool,
}

impl ExecutorBackend {
    /// Wrap a shared compiled plan (no clone — a pool of executor
    /// backends reads one copy of the weights and LUT product tables).
    /// `threads` caps the scoped-thread fan-out of
    /// `Executor::run_batch_with_threads` (a worker pool divides the
    /// machine's cores so concurrent backends don't oversubscribe).
    pub fn new(plan: std::sync::Arc<NetworkPlan>, threads: usize) -> Self {
        let io = plan.io;
        // the datapath lives in the plan's multiplier arrays (S17)
        let name = if plan.lut_count() > 0 { "executor/lut-fabric" } else { "executor" };
        Self {
            ex: Executor::shared(plan),
            io,
            threads: threads.max(1),
            name,
            pool: ScratchPool::new(),
            image_major: false,
        }
    }

    /// Like [`new`](Self::new) but driving the **image-major witness
    /// path** (`Executor::run_image_major_into`, the pre-S22 per-image
    /// driver) instead of the batch-major sweeps — the perf-baseline
    /// row `lutmul bench --json` charts the batch-major speedup
    /// against (EXPERIMENTS.md E15). Bit-exact with the default
    /// backend by construction.
    pub fn image_major(plan: std::sync::Arc<NetworkPlan>, threads: usize) -> Self {
        let mut b = Self::new(plan, threads);
        b.image_major = true;
        b.name = if b.name == "executor/lut-fabric" {
            "executor/lut-fabric/image-major"
        } else {
            "executor/image-major"
        };
        b
    }
}

impl InferenceBackend for ExecutorBackend {
    fn name(&self) -> &str {
        self.name
    }

    /// Lifting each borrowed image into an owned `Tensor` costs one copy
    /// per image — the price of the uniform borrowed-batch contract
    /// (cycle-modeled backends stream the same borrowed images with no
    /// copy). The per-layer work of a batch dwarfs it; see the
    /// EXPERIMENTS.md §Perf PR 4 row. Working memory comes from the
    /// backend's persistent arena pool: only this copy and the returned
    /// logits are allocated per batch.
    fn infer_batch(&mut self, images: &[Vec<i32>]) -> Result<BatchOutput> {
        let (s, c) = (self.io.image_size, self.io.in_ch);
        let px = s * s * c;
        let mut tensors = Vec::with_capacity(images.len());
        for img in images {
            anyhow::ensure!(
                img.len() == px,
                "image has {} codes, the network expects {px} ({s}x{s}x{c})",
                img.len()
            );
            tensors.push(Tensor::from_hwc(s, s, c, img.clone()));
        }
        let mut logits = Vec::with_capacity(images.len());
        if self.image_major {
            self.ex.run_image_major_into(&tensors, self.threads, &mut self.pool, &mut logits);
        } else {
            self.ex.run_batch_into(&tensors, self.threads, &mut self.pool, &mut logits);
        }
        Ok(BatchOutput { logits, cycles: 0, counters: Vec::new() })
    }
}

/// The cycle-level dataflow pipeline simulator behind the uniform
/// contract: batches stream through with successive images overlapped
/// in flight, and `BatchOutput::cycles` carries the simulated drain
/// time.
pub struct PipelineBackend {
    pipe: Pipeline,
}

impl PipelineBackend {
    pub fn new(plan: &NetworkPlan, folds: &FoldConfig, fifo_depth: usize) -> Self {
        Self { pipe: Pipeline::from_plan(plan, folds, fifo_depth) }
    }
}

impl InferenceBackend for PipelineBackend {
    fn name(&self) -> &str {
        "pipeline"
    }

    fn infer_batch(&mut self, images: &[Vec<i32>]) -> Result<BatchOutput> {
        let rep = self.pipe.run(images)?;
        Ok(BatchOutput { logits: rep.logits, cycles: rep.cycles, counters: Vec::new() })
    }

    fn steady_cycles(&self) -> Option<u64> {
        Some(self.pipe.steady_cycles())
    }
}

/// The multi-device shard chain behind the uniform contract: the plan
/// cut into MAC-balanced shards (DESIGN.md S18), co-simulated over
/// bandwidth/latency-charged links. `BatchOutput::counters` carries the
/// cumulative per-shard occupancy snapshot after each batch.
pub struct ShardChainBackend {
    chain: ShardChain,
    name: String,
}

impl ShardChainBackend {
    /// Shard `plan` evenly across `devices` simulated FPGAs and join
    /// them with `link` at the device clock. `folds` covers the whole
    /// plan's conv stages in network order. A zero device count is a
    /// hard error, not a silent clamp (same contract as the CLI flags).
    pub fn new(
        plan: &NetworkPlan,
        devices: usize,
        folds: &FoldConfig,
        fifo_depth: usize,
        link: &LinkModel,
        freq_mhz: f64,
        a_bits: u32,
    ) -> Result<Self> {
        anyhow::ensure!(devices >= 1, "a sharded backend needs at least 1 device, got 0");
        let shards = plan.shard_evenly(devices);
        let chain = ShardChain::new(&shards, folds, fifo_depth, link, freq_mhz, a_bits)?;
        let name = format!("sharded x{}", chain.n_shards());
        Ok(Self { chain, name })
    }
}

impl InferenceBackend for ShardChainBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn infer_batch(&mut self, images: &[Vec<i32>]) -> Result<BatchOutput> {
        let rep = self.chain.run(images)?;
        Ok(BatchOutput {
            logits: rep.logits,
            cycles: rep.cycles,
            counters: self.chain.occupancy(),
        })
    }

    fn shard_occupancy(&self) -> Vec<ShardCounters> {
        self.chain.occupancy()
    }

    fn steady_cycles(&self) -> Option<u64> {
        Some(self.chain.steady_cycles())
    }
}

/// The PJRT runtime behind the uniform contract: executes the AOT HLO
/// artifact (with the Pallas LUTMUL kernels inside) batch-major via
/// `Runtime::run_batched`. Without the `xla` cargo feature the runtime
/// is a stub whose `load` errors, so construction fails loudly and the
/// engine's callers report the backend as unavailable instead of
/// silently skipping it.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    pub fn load(path: impl AsRef<std::path::Path>, batch: usize, io: &IoGeom) -> Result<Self> {
        Ok(Self { rt: Runtime::load_for(path, batch.max(1), io)? })
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn infer_batch(&mut self, images: &[Vec<i32>]) -> Result<BatchOutput> {
        Ok(BatchOutput { logits: self.rt.run_batched(images)?, cycles: 0, counters: Vec::new() })
    }
}
