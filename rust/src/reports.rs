//! Experiment report printers — shared by the CLI (`lutmul report ...`),
//! the examples and the bench harnesses. One printer per paper artifact
//! (see the experiment index in DESIGN.md).

use crate::baselines;
use crate::dataflow::multi::{partition, LinkModel};
use crate::dataflow::{FoldConfig, Pipeline};
use crate::fabric::cost::layer_lut_area;
use crate::fabric::device::{u280_datasheet_int8_tops, U280, V100};
use crate::graph::plan::{Datapath, NetworkPlan};
use crate::graph::{
    mobilenet_v2_full, mobilenet_v2_small, ApproxSpec, Executor, Multipliers, Network, Op,
    PruneSpec, Tensor,
};
use crate::roofline;
use crate::synth::breakdown::{fig6_breakdown, Fig6Published};
use crate::synth::design::Design;
use crate::synth::fold::{optimize_folding, Budget};
use crate::synth::synthesize;
use crate::util::Json;

/// Table 1: GPU vs FPGA device comparison (datasheet constants).
pub fn table1() {
    println!("Table 1: GPU vs FPGA comparison (datasheet constants)");
    println!("{:<14}{:>16}{:>20}", "", V100.name, U280.name);
    println!("{:<14}{:>14}nm{:>18}nm", "Technology", V100.technology_nm, U280.technology_nm);
    println!("{:<14}{:>13}MHz{:>17}MHz", "Clock", V100.clock_mhz, U280.max_freq_mhz);
    println!(
        "{:<14}{:>16}{:>20}",
        "Cores",
        format!("{} CUDA", V100.cuda_cores),
        format!("{} DSP48E2", U280.dsps)
    );
    println!(
        "{:<14}{:>16}{:>20}",
        "Perf",
        format!("{} TFLOPs", V100.fp32_tflops),
        format!("{:.1} TOPs INT8", u280_datasheet_int8_tops())
    );
    println!("{:<14}{:>12}GB/s{:>11}GB/s(HBM)", "Bandwidth", V100.bw_gbps, U280.hbm_gbps);
    println!("{:<14}{:>15}W{:>14}W(max)", "Power", V100.power_w, U280.power_max_w);
    println!("{:<14}{:>15}$ {:>17}$", "Price", V100.price_usd, 7717);
}

/// Figure 1: roofline analysis for 1/64 of U280.
pub fn fig1() {
    println!("Figure 1: roofline, 1/64 of U280 resources + HBM BW, 333 MHz");
    let curves = roofline::figure1_curves(&U280, 64);
    println!("{:<16}{:>12}{:>22}", "architecture", "peak GOPS", "ridge (ops/byte)");
    for c in &curves {
        println!("{:<16}{:>12.1}{:>22.1}", c.label, c.peak_gops, c.ridge_ops_per_byte);
    }
    let lut = &curves[0];
    println!("\nattainable GOPS vs arithmetic intensity ({}):", lut.label);
    for (ai, gops) in lut.points.iter().step_by(4) {
        println!("  AI {ai:>10.3} ops/B -> {gops:>9.2} GOPS");
    }
}

/// Figure 2: accuracy + LUTs/mult vs bit-width (QAT sweep artifact).
pub fn fig2(path: &std::path::Path) {
    println!("Figure 2: accuracy loss + LUTs/mult vs quantization bit-width");
    println!("(LUT curve is Eq. 3; accuracy from the QAT sweep artifact)");
    let sweep =
        std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok());
    println!("{:>5}{:>16}{:>16}", "bits", "LUTs/mult", "deployed acc");
    match &sweep {
        Some(v) => {
            let bits = v.field("bits").and_then(|b| Ok(b.as_arr()?.to_vec())).unwrap_or_default();
            let acc = v.field("acc_int").and_then(|b| Ok(b.as_arr()?.to_vec())).unwrap_or_default();
            let luts =
                v.field("luts_per_mul").and_then(|b| Ok(b.as_arr()?.to_vec())).unwrap_or_default();
            for i in 0..bits.len() {
                println!(
                    "{:>5}{:>16.1}{:>15.1}%",
                    bits[i].as_i64().unwrap_or(0),
                    luts[i].as_f64().unwrap_or(0.0),
                    100.0 * acc[i].as_f64().unwrap_or(0.0)
                );
            }
            if let Some(fp) = v.get("acc_fp32").and_then(|f| f.as_f64().ok()) {
                println!("fp32 baseline: {:.1}%", 100.0 * fp);
            }
        }
        None => {
            for b in [1u32, 2, 3, 4, 5, 6, 8] {
                println!(
                    "{:>5}{:>16.1}{:>16}",
                    b,
                    crate::fabric::cost::luts_per_mult(b),
                    "(run `make artifacts-fig2`)"
                );
            }
        }
    }
}

/// Figure 6: LUT resource breakdown of MobileNetV2's second conv layer.
pub fn fig6() {
    let b = fig6_breakdown();
    println!(
        "Figure 6: LUT breakdown, MobileNetV2 conv2 (1x1, 32->32, {} weights)",
        b.n_weights
    );
    println!("{:<28}{:>12}{:>12}", "", "ours", "paper");
    println!(
        "{:<28}{:>12.0}{:>12.0}",
        "HLS multiplication LUTs", b.hls_mult_luts, Fig6Published::HLS_MULT_LUTS
    );
    println!("{:<28}{:>12.0}{:>12.0}", "impl ROM LUTs", b.impl_rom_luts, Fig6Published::IMPL_ROM_LUTS);
    println!(
        "{:<28}{:>12.0}{:>12.0}",
        "impl adder+other LUTs",
        b.impl_adder_luts + b.threshold_luts,
        Fig6Published::IMPL_ADDER_OTHER_LUTS
    );
    println!(
        "{:<28}{:>12.0}{:>12.0}",
        "impl total LUTs", b.impl_total_luts, Fig6Published::IMPL_TOTAL_LUTS
    );
    println!("(theory = Eq.3: {:.0} LUTs)", b.theory_mult_luts);
}

/// Synthesize our LUTMUL design of full MobileNetV2 on the U280
/// (pixel-rate input interface: the dataflow optimum).
pub fn our_design() -> Design {
    let arch = mobilenet_v2_full();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    synthesize(&arch, &U280, &folds)
}

/// Paper-style design point: element-serial input ingestion (FINN-heritage
/// sliding-window generators consume one activation element per cycle),
/// which floors the pipeline at `in_px * in_ch` cycles — the regime the
/// paper's 1627 FPS lives in.
pub fn paper_style_design() -> Design {
    let arch = mobilenet_v2_full();
    let floor = (arch.input_hw * arch.input_hw * arch.input_ch) as u64;
    let (folds, cycles) =
        crate::synth::fold::optimize_folding_with_floor(&arch, &Budget::whole(&U280), floor);
    let mut d = synthesize(&arch, &U280, &folds);
    d.cycles_per_image = d.cycles_per_image.max(cycles);
    d
}

/// Multi-device scaling table (DESIGN.md S18 / EXPERIMENTS.md E11): FPS
/// of full MobileNetV2 partitioned over 1–4 U280s, flagging whether each
/// point is compute- or link-bound. Printed for the 100 GbE fabric the
/// paper's testbed uses and a deliberately thin 1 GbE contrast where the
/// links take over as the bottleneck.
pub fn multi_scaling() {
    let arch = mobilenet_v2_full();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    println!("Multi-device scaling: MobileNetV2 across 1-4 x {}", U280.name);
    for (label, link) in [
        ("100 GbE", LinkModel::gbe100()),
        ("1 GbE", LinkModel { bandwidth_bps: 125e6 * 0.8, latency_s: 20e-6 }),
    ] {
        println!("\n{label} links:");
        println!(
            "{:>8}{:>14}{:>10}{:>10}{:>12}{:>14}",
            "devices", "max LUT/dev", "FPS", "speedup", "bound", "+latency(us)"
        );
        let base = partition(&arch, &U280, 1, &folds, link).fps();
        for n in 1..=4usize {
            let plan = partition(&arch, &U280, n, &folds, link);
            println!(
                "{:>8}{:>14.0}{:>10.0}{:>9.2}x{:>12}{:>14.1}",
                n,
                plan.max_device_luts(),
                plan.fps(),
                plan.fps() / base,
                if plan.is_link_bound() { "link" } else { "compute" },
                plan.added_latency_s() * 1e6
            );
        }
    }
    println!(
        "\n(per-device folds held at the single-device optimum, so the table\n\
         isolates the partition: balanced slices fit smaller devices at the\n\
         same steady-state FPS until the link bandwidth takes over; re-run\n\
         `lutmul multi --run` for the executable-chain cross-check)"
    );
}

/// Table 2: accelerator comparison (published rows + our regenerated row).
pub fn table2() {
    println!("Table 2: MobileNet accelerator comparison");
    let ours = our_design();
    println!(
        "{:<16}{:>10}{:>9}{:>9}{:>8}{:>9}{:>9}{:>10}{:>9}",
        "design", "LUT", "BRAM36", "DSP", "P(W)", "FPS", "GOPS", "GOPS/W", "top-1"
    );
    for r in baselines::table2_published() {
        println!(
            "{:<16}{:>10}{:>9.1}{:>9}{:>8}{:>9.1}{:>9.1}{:>10}{:>8.1}%",
            r.name,
            r.luts,
            r.bram36,
            r.dsps,
            r.power_w.map_or("-".into(), |p| format!("{p:.1}")),
            r.fps,
            r.gops,
            r.gops_per_watt.map_or("-".into(), |g| format!("{g:.2}")),
            r.top1_acc
        );
    }
    let p = baselines::lutmul_published();
    println!(
        "{:<16}{:>10}{:>9.1}{:>9}{:>8.1}{:>9.1}{:>9.1}{:>10.2}{:>8.2}%",
        p.name,
        p.luts,
        p.bram36,
        p.dsps,
        p.power_w.unwrap(),
        p.fps,
        p.gops,
        p.gops_per_watt.unwrap(),
        p.top1_acc
    );
    let style = paper_style_design();
    println!(
        "{:<16}{:>10}{:>9}{:>9}{:>8.1}{:>9.1}{:>9.1}{:>10.2}{:>9}",
        "ours (elem-in)",
        style.luts,
        style.bram36,
        style.dsps,
        style.power_w,
        style.fps(),
        style.gops(),
        style.gops_per_watt(),
        "(sim)"
    );
    println!(
        "{:<16}{:>10}{:>9}{:>9}{:>8.1}{:>9.1}{:>9.1}{:>10.2}{:>9}",
        "ours (px-in)",
        ours.luts,
        ours.bram36,
        ours.dsps,
        ours.power_w,
        ours.fps(),
        ours.gops(),
        ours.gops_per_watt(),
        "(sim)"
    );
    println!("\nshape checks (paper -> ours):");
    let finn = &baselines::table2_published()[0];
    println!(
        "  LUTMUL beats every published FPS: paper 1627 vs best baseline {:.0}; ours {:.0} (elem-serial input) / {:.0} (pixel input)",
        finn.fps,
        style.fps(),
        ours.fps()
    );
    println!(
        "  LUTMUL/FINN FPS ratio: paper {:.2}x, ours {:.2}x (elem-serial, same ingest style)",
        baselines::lutmul_published().fps / finn.fps,
        style.fps() / finn.fps
    );
}

/// `lutmul report approx` (DESIGN.md S24 / EXPERIMENTS.md E17):
/// per-layer LUT-area and accumulation savings of a Maddness-style
/// approximate compile of the synthetic MobileNetV2-small network. Two
/// cross-checks close the loop: the **saturated** configuration
/// (`cols_per_codebook = 1`) must reproduce the exact LUT-fabric
/// executor bit-for-bit (the degenerate-exactness anchor of
/// `graph::approx`), and the measured batch throughput of the
/// approximate executor is printed next to the exact one so the
/// accumulation saving is visible as wall-clock, not just as a count.
/// Accuracy is deliberately *not* gated here — that is `lutmul eval`'s
/// job; this report owns the area/cycle side of the trade.
pub fn approx(cols_per_codebook: usize, depth: usize, n: usize) -> anyhow::Result<()> {
    anyhow::ensure!(cols_per_codebook >= 1, "--cols must be >= 1, got {cols_per_codebook}");
    anyhow::ensure!((1..=8).contains(&depth), "--depth must be in 1..=8, got {depth}");
    let net = Network::synthetic(&mobilenet_v2_small(), 0x5EED);
    let spec = ApproxSpec { cols_per_codebook, depth, ..ApproxSpec::default() };
    let exact = NetworkPlan::compile(&net, Datapath::LutFabric);
    let approx = NetworkPlan::compile_approx(&net, Datapath::LutFabric, &spec);
    let w_bits: Vec<u32> = net
        .ops
        .iter()
        .filter_map(|op| match op {
            Op::Conv { w_bits, .. } => Some(*w_bits),
            _ => None,
        })
        .collect();

    println!(
        "Maddness approximate datapath: synthetic MobileNetV2-small, {cols_per_codebook} \
         col(s)/codebook, depth {depth}, LUT-fabric datapath"
    );
    println!(
        "{:<12}{:>16}{:>15}{:>18}{:>17}",
        "layer", "cols->codebooks", "axpys/pixel", "LUT6 tables", "LUT area(impl)"
    );
    // accumulation counts: one axpy per column exactly, one per codebook
    // approximately — the layer-level MAC fraction that feeds the roofline
    let (mut area_exact, mut area_approx) = (0.0f64, 0.0f64);
    let (mut live, mut full) = (0u64, 0u64);
    for (i, (ec, ac)) in exact.convs().zip(approx.convs()).enumerate() {
        let bits = w_bits[i];
        let ae = layer_lut_area(bits, ec.geom.cout, ec.cols);
        area_exact += ae;
        full += ec.macs();
        match &ac.mults {
            Multipliers::LutApprox { layer } => {
                let aa = layer.lut6 as f64;
                area_approx += aa;
                live += ac.geom.out_pixels() as u64
                    * ac.rows() as u64
                    * layer.n_codebooks as u64;
                println!(
                    "{:<12}{:>16}{:>15}{:>18}{:>17}",
                    ac.name,
                    format!("{}->{}", ac.cols, layer.n_codebooks),
                    format!("{}->{}", ac.cols, layer.n_codebooks),
                    format!("{}->{}", ec.lut_count(), ac.lut_count()),
                    format!("{ae:.0}->{aa:.0}"),
                );
            }
            // dw layers (and any non-lut_ok layer) keep their exact
            // lowering — printed so the coverage is visible
            _ => {
                area_approx += ae;
                live += ec.macs();
                println!(
                    "{:<12}{:>16}{:>15}{:>18}{:>17}",
                    ac.name,
                    format!("{} (exact)", ac.cols),
                    format!("{}", ac.cols),
                    format!("{}", ec.lut_count()),
                    format!("{ae:.0}"),
                );
            }
        }
    }
    let frac = live as f64 / full.max(1) as f64;
    println!(
        "totals: {live}/{full} accumulations (MAC fraction {frac:.3}) | LUT area {area_exact:.0} -> {area_approx:.0} ({:+.1}%)",
        100.0 * (area_approx - area_exact) / area_exact.max(1.0),
    );
    let slice = U280.fraction(64);
    let f_hz = 333e6;
    println!(
        "roofline (1/64 U280, W4A4): exact peak {:.1} GOPS -> effective {:.1} GOPS at MAC fraction {frac:.3}",
        roofline::lutmul_peak(&slice, 4, f_hz) / 1e9,
        roofline::lutmul_peak_approx(&slice, 4, f_hz, frac) / 1e9,
    );

    // measured throughput: the same seeded batch through the exact and
    // approximate batch-major executors
    let n = n.max(2);
    let (hw, ch) = (net.meta.image_size, net.meta.in_ch);
    let amax = 1i64 << net.meta.a_bits.max(1);
    let mut s = 0x0123_4567_89ab_cdefu64;
    let tensors: Vec<Tensor> = (0..n)
        .map(|_| {
            let v: Vec<i32> = (0..hw * hw * ch)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((s >> 40) as i64).rem_euclid(amax) as i32
                })
                .collect();
            Tensor::from_hwc(hw, hw, ch, v)
        })
        .collect();
    let ex = Executor::from_plan(exact);
    let t0 = std::time::Instant::now();
    let exact_logits = ex.run_batch_with_threads(&tensors, 1);
    let exact_ips = n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let ax = Executor::from_plan(approx);
    let t0 = std::time::Instant::now();
    ax.run_batch_with_threads(&tensors, 1);
    let approx_ips = n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    println!(
        "executor throughput ({n} images, 1 thread): exact {exact_ips:.0} img/s -> approx {approx_ips:.0} img/s ({:.2}x)",
        approx_ips / exact_ips.max(1e-9),
    );

    // the degenerate-exactness witness: the saturated configuration must
    // reproduce the exact LUT-fabric datapath bit-for-bit
    let sat = Executor::from_plan(NetworkPlan::compile_approx(
        &net,
        Datapath::LutFabric,
        &ApproxSpec::saturated(),
    ));
    let sat_logits = sat.run_batch_with_threads(&tensors, 1);
    anyhow::ensure!(
        sat_logits == exact_logits,
        "saturated approximate datapath diverged from the exact executor"
    );
    println!("saturated config bit-exact vs exact executor: {n}/{n} images");
    Ok(())
}

/// `lutmul report prune` (DESIGN.md S23 / EXPERIMENTS.md E16): per-layer
/// LUT-area and cycle savings of a structurally pruned compile of the
/// synthetic MobileNetV2-small network. Two cross-checks close the loop:
/// the analytic steady-state FPS of the pruned pipeline must agree with
/// the simulated one (within 15% once the pipeline is warm), and the
/// pruned pipeline's logits must be bit-exact against a *dense* compile
/// of the same network with the prune mask zeroed into its weights.
pub fn prune(sparsity: f64, fold: usize, n: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        (0.0..1.0).contains(&sparsity),
        "--sparsity must be in [0, 1), got {sparsity}"
    );
    let net = Network::synthetic(&mobilenet_v2_small(), 0x5EED);
    let spec = PruneSpec::channels(sparsity);
    let dense = NetworkPlan::compile(&net, Datapath::LutFabric);
    let pruned = NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &spec);
    let w_bits: Vec<u32> = net
        .ops
        .iter()
        .filter_map(|op| match op {
            Op::Conv { w_bits, .. } => Some(*w_bits),
            _ => None,
        })
        .collect();
    let base = FoldConfig::uniform(dense.n_convs(), fold);
    let rescaled = base.rescaled_for(&pruned);

    println!(
        "Structured pruning: synthetic MobileNetV2-small, magnitude channel sparsity {sparsity:.2}, LUT-fabric datapath"
    );
    println!(
        "{:<12}{:>11}{:>13}{:>14}{:>17}{:>9}{:>15}",
        "layer", "rows", "cols", "LUT6 tables", "LUT area(impl)", "fold", "pixel cycles"
    );
    let (mut area_dense, mut area_pruned) = (0.0f64, 0.0f64);
    for (i, (dc, pc)) in dense.convs().zip(pruned.convs()).enumerate() {
        let bits = w_bits[i];
        let (fd, fp) = (base.folds[i], rescaled.folds[i]);
        let ad = layer_lut_area(bits, dc.geom.cout, dc.cols);
        let ap = layer_lut_area(bits, pc.rows(), pc.cols);
        area_dense += ad;
        area_pruned += ap;
        println!(
            "{:<12}{:>11}{:>13}{:>14}{:>17}{:>9}{:>15}",
            dc.name,
            format!("{}->{}", dc.geom.cout, pc.rows()),
            format!("{}->{}", dc.cols, pc.cols),
            format!("{}->{}", dc.lut_count(), pc.lut_count()),
            format!("{:.0}->{:.0}", ad, ap),
            format!("{fd}->{fp}"),
            format!(
                "{}->{}",
                dc.geom.out_pixels() * fd,
                pc.geom.out_pixels() * fp
            ),
        );
    }

    let live: u64 = pruned.convs().map(|c| c.macs()).sum();
    let full: u64 = pruned.convs().map(|c| c.dense_macs()).sum();
    let density = live as f64 / full.max(1) as f64;
    println!(
        "totals: {live}/{full} live MACs (density {density:.3}) | LUT area {area_dense:.0} -> {area_pruned:.0} ({:+.1}%)",
        100.0 * (area_pruned - area_dense) / area_dense.max(1.0),
    );
    let slice = U280.fraction(64);
    let f_hz = 333e6;
    println!(
        "roofline (1/64 U280, W4A4): dense peak {:.1} GOPS -> effective {:.1} GOPS at density {density:.3}",
        roofline::lutmul_peak(&slice, 4, f_hz) / 1e9,
        roofline::lutmul_peak_pruned(&slice, 4, f_hz, density) / 1e9,
    );

    // the executable cross-check: fold-rescaled pruned pipeline vs the
    // dense one, analytic steady-state vs simulated incremental interval
    let freq_mhz = 333.0;
    let dense_pipe = Pipeline::from_plan(&dense, &base, 16);
    let mut pruned_pipe = Pipeline::from_plan(&pruned, &rescaled, 16);
    println!(
        "pipeline steady-state: dense {} cycles/img ({:.0} FPS) -> pruned {} cycles/img ({:.0} FPS @{freq_mhz:.0}MHz)",
        dense_pipe.steady_cycles(),
        freq_mhz * 1e6 / dense_pipe.steady_cycles().max(1) as f64,
        pruned_pipe.steady_cycles(),
        freq_mhz * 1e6 / pruned_pipe.steady_cycles().max(1) as f64,
    );

    let n = n.max(2);
    let (hw, ch) = (net.meta.image_size, net.meta.in_ch);
    let amax = 1i64 << net.meta.a_bits.max(1);
    let mut s = 0x0123_4567_89ab_cdefu64;
    let images: Vec<Vec<i32>> = (0..n)
        .map(|_| {
            (0..hw * hw * ch)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((s >> 40) as i64).rem_euclid(amax) as i32
                })
                .collect()
        })
        .collect();
    let report = pruned_pipe.run(&images)?;
    let analytic = report.steady_state_fps(freq_mhz);
    let measured = freq_mhz * 1e6 / report.incremental_cycles_per_image().max(1) as f64;
    println!(
        "simulated pruned pipeline: {n} images | incremental {} cycles/img | measured {measured:.0} FPS vs analytic {analytic:.0} FPS | ratio {:.3}",
        report.incremental_cycles_per_image(),
        measured / analytic,
    );
    if n >= 4 {
        anyhow::ensure!(
            (measured / analytic - 1.0).abs() <= 0.15,
            "simulated FPS {measured:.0} deviates more than 15% from the analytic {analytic:.0}"
        );
        println!("  within 15% of the analytic model: OK");
    }

    // bit-exactness: the pruned pipeline must reproduce the dense compile
    // of the network with the same mask zeroed into its weights
    let masked = Executor::from_plan(NetworkPlan::compile(
        &spec.masked_network(&net),
        Datapath::LutFabric,
    ));
    let tensors: Vec<Tensor> =
        images.iter().map(|v| Tensor::from_hwc(hw, hw, ch, v.clone())).collect();
    let want = masked.run_batch_with_threads(&tensors, 1);
    anyhow::ensure!(
        report.logits == want,
        "pruned pipeline diverged from the masked-dense executor"
    );
    println!("bit-exact vs masked-dense executor: {n}/{n} images");
    Ok(())
}

/// `lutmul report fleet` (DESIGN.md S25 / EXPERIMENTS.md E18): drive the
/// heterogeneous fleet through its whole elastic envelope in-process —
/// mixed-class serving, a chaos kill with drain-and-rebuild recovery,
/// a burst that forces a scale-up, and the idle drain back to the
/// worker floor — then print the per-class table and gate the
/// invariants (zero lost requests, `rebuilds >= 1` after the kill,
/// at least one scale-up and one scale-down).
pub fn fleet(requests: usize, devices: usize) -> anyhow::Result<()> {
    use crate::coordinator::{Fleet, FleetConfig, PoolScale, RequestClass};
    use crate::engine::{BackendKind, Engine};
    use std::time::{Duration, Instant};

    let requests = requests.max(16);
    let devices = devices.max(2);
    let net = Network::synthetic(&mobilenet_v2_small(), 0x5EED);
    let engine = Engine::builder()
        .network(net)
        .backend(BackendKind::Reference)
        .build()?;
    // aggressive elasticity so the whole envelope fits in one run
    let cfg = FleetConfig {
        latency: PoolScale { min_workers: 1, max_workers: 2 },
        throughput: PoolScale { min_workers: 1, max_workers: 2 },
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_depth: 4 * requests,
        retry_budget: 2,
        rebuild_backoff: Duration::from_millis(1),
        scale_tick: Duration::from_millis(2),
        high_water: 4,
        up_ticks: 2,
        idle_ticks: 25,
    };
    let fleet = Fleet::start(&engine, devices, cfg)?;
    let images = engine.images(requests)?;
    println!(
        "fleet report: {} | {requests} requests | latency pool = executor replicas, \
         throughput pool = sharded x{devices} chains",
        engine.source().label(),
    );

    // phase 1 — mixed-class serving: 3:1 latency:throughput
    let t0 = Instant::now();
    let tickets: Vec<_> = images
        .iter()
        .enumerate()
        .map(|(i, img)| {
            let class =
                if i % 4 == 3 { RequestClass::Throughput } else { RequestClass::Latency };
            fleet.try_submit(img.clone(), None, class).map(|t| (i, t))
        })
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| anyhow::anyhow!("fleet admission failed: {e}"))?;
    let mut ok = 0usize;
    for (i, t) in tickets {
        t.wait().map_err(|e| anyhow::anyhow!("mixed request {i} lost: {e}"))?;
        ok += 1;
    }
    println!("phase mixed: {ok}/{requests} served across both classes in {:.2?}", t0.elapsed());

    // phase 2 — chaos: kill the next throughput batch mid-flight; every
    // drained request must re-run on the rebuilt chain
    fleet.chaos_kill(RequestClass::Throughput);
    let n_chaos = (requests / 4).max(4);
    let tickets: Vec<_> = images
        .iter()
        .take(n_chaos)
        .map(|img| fleet.try_submit(img.clone(), None, RequestClass::Throughput))
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| anyhow::anyhow!("fleet admission failed: {e}"))?;
    for (i, t) in tickets.into_iter().enumerate() {
        t.wait().map_err(|e| anyhow::anyhow!("request {i} lost to the chaos kill: {e}"))?;
    }
    let rebuilds = fleet.rebuilds(RequestClass::Throughput);
    println!(
        "phase chaos: killed one throughput batch mid-flight; {n_chaos}/{n_chaos} served, \
         {rebuilds} rebuild(s)"
    );
    anyhow::ensure!(rebuilds >= 1, "the chaos kill never drove a rebuild");

    // phase 3 — burst: a deep latency backlog must trip the autoscaler
    let n_burst = 2 * requests;
    let tickets: Vec<_> = (0..n_burst)
        .map(|i| {
            fleet.try_submit(images[i % images.len()].clone(), None, RequestClass::Latency)
        })
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| anyhow::anyhow!("fleet admission failed: {e}"))?;
    for (i, t) in tickets.into_iter().enumerate() {
        t.wait().map_err(|e| anyhow::anyhow!("burst request {i} lost: {e}"))?;
    }
    let up = fleet.class_summary(RequestClass::Latency).scale_up;
    println!("phase burst: {n_burst}/{n_burst} served | latency pool scale-ups {up}");
    anyhow::ensure!(up >= 1, "the burst never drove a scale-up");

    // phase 4 — idle: retire orders drain the pool back to the floor
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let cs = fleet.class_summary(RequestClass::Latency);
        if cs.scale_down >= 1 && cs.workers == cfg.latency.min_workers {
            println!(
                "phase idle: latency pool retired to {} worker(s) ({} scale-down(s))",
                cs.workers, cs.scale_down
            );
            break;
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "the idle pool never retired to the floor (workers {}, scale_down {})",
            cs.workers,
            cs.scale_down
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // per-class table + gates
    let summary = fleet.summary();
    println!(
        "\n{:<11}{:<12}{:>8}{:>9}{:>9}{:>9}{:>9}{:>9}{:>11}{:>10}{:>10}",
        "class", "backend", "workers", "spawned", "scale+", "scale-", "rebuilds", "retried",
        "completed", "p50(us)", "p99(us)"
    );
    for c in &summary.classes {
        println!(
            "{:<11}{:<12}{:>8}{:>9}{:>9}{:>9}{:>9}{:>9}{:>11}{:>10}{:>10}",
            c.class.label(),
            c.backend,
            c.workers,
            c.spawned,
            c.scale_up,
            c.scale_down,
            c.rebuilds,
            c.retried,
            c.summary.completed,
            c.summary.p50_us,
            c.summary.p99_us,
        );
    }
    for class in RequestClass::ALL {
        let c = summary.class(class).expect("summary covers both classes");
        anyhow::ensure!(c.summary.completed > 0, "{class} pool never served");
        anyhow::ensure!(c.summary.failed == 0, "{class} pool failed requests");
    }
    anyhow::ensure!(summary.scale_events() >= 2, "autoscaler never cycled");
    fleet.shutdown();
    println!("report fleet: OK");
    Ok(())
}
