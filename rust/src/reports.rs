//! Experiment report printers — shared by the CLI (`lutmul report ...`),
//! the examples and the bench harnesses. One printer per paper artifact
//! (see the experiment index in DESIGN.md).

use crate::baselines;
use crate::dataflow::multi::{partition, LinkModel};
use crate::fabric::device::{u280_datasheet_int8_tops, U280, V100};
use crate::graph::mobilenet_v2_full;
use crate::roofline;
use crate::synth::breakdown::{fig6_breakdown, Fig6Published};
use crate::synth::design::Design;
use crate::synth::fold::{optimize_folding, Budget};
use crate::synth::synthesize;
use crate::util::Json;

/// Table 1: GPU vs FPGA device comparison (datasheet constants).
pub fn table1() {
    println!("Table 1: GPU vs FPGA comparison (datasheet constants)");
    println!("{:<14}{:>16}{:>20}", "", V100.name, U280.name);
    println!("{:<14}{:>14}nm{:>18}nm", "Technology", V100.technology_nm, U280.technology_nm);
    println!("{:<14}{:>13}MHz{:>17}MHz", "Clock", V100.clock_mhz, U280.max_freq_mhz);
    println!(
        "{:<14}{:>16}{:>20}",
        "Cores",
        format!("{} CUDA", V100.cuda_cores),
        format!("{} DSP48E2", U280.dsps)
    );
    println!(
        "{:<14}{:>16}{:>20}",
        "Perf",
        format!("{} TFLOPs", V100.fp32_tflops),
        format!("{:.1} TOPs INT8", u280_datasheet_int8_tops())
    );
    println!("{:<14}{:>12}GB/s{:>11}GB/s(HBM)", "Bandwidth", V100.bw_gbps, U280.hbm_gbps);
    println!("{:<14}{:>15}W{:>14}W(max)", "Power", V100.power_w, U280.power_max_w);
    println!("{:<14}{:>15}$ {:>17}$", "Price", V100.price_usd, 7717);
}

/// Figure 1: roofline analysis for 1/64 of U280.
pub fn fig1() {
    println!("Figure 1: roofline, 1/64 of U280 resources + HBM BW, 333 MHz");
    let curves = roofline::figure1_curves(&U280, 64);
    println!("{:<16}{:>12}{:>22}", "architecture", "peak GOPS", "ridge (ops/byte)");
    for c in &curves {
        println!("{:<16}{:>12.1}{:>22.1}", c.label, c.peak_gops, c.ridge_ops_per_byte);
    }
    let lut = &curves[0];
    println!("\nattainable GOPS vs arithmetic intensity ({}):", lut.label);
    for (ai, gops) in lut.points.iter().step_by(4) {
        println!("  AI {ai:>10.3} ops/B -> {gops:>9.2} GOPS");
    }
}

/// Figure 2: accuracy + LUTs/mult vs bit-width (QAT sweep artifact).
pub fn fig2(path: &std::path::Path) {
    println!("Figure 2: accuracy loss + LUTs/mult vs quantization bit-width");
    println!("(LUT curve is Eq. 3; accuracy from the QAT sweep artifact)");
    let sweep =
        std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok());
    println!("{:>5}{:>16}{:>16}", "bits", "LUTs/mult", "deployed acc");
    match &sweep {
        Some(v) => {
            let bits = v.field("bits").and_then(|b| Ok(b.as_arr()?.to_vec())).unwrap_or_default();
            let acc = v.field("acc_int").and_then(|b| Ok(b.as_arr()?.to_vec())).unwrap_or_default();
            let luts =
                v.field("luts_per_mul").and_then(|b| Ok(b.as_arr()?.to_vec())).unwrap_or_default();
            for i in 0..bits.len() {
                println!(
                    "{:>5}{:>16.1}{:>15.1}%",
                    bits[i].as_i64().unwrap_or(0),
                    luts[i].as_f64().unwrap_or(0.0),
                    100.0 * acc[i].as_f64().unwrap_or(0.0)
                );
            }
            if let Some(fp) = v.get("acc_fp32").and_then(|f| f.as_f64().ok()) {
                println!("fp32 baseline: {:.1}%", 100.0 * fp);
            }
        }
        None => {
            for b in [1u32, 2, 3, 4, 5, 6, 8] {
                println!(
                    "{:>5}{:>16.1}{:>16}",
                    b,
                    crate::fabric::cost::luts_per_mult(b),
                    "(run `make artifacts-fig2`)"
                );
            }
        }
    }
}

/// Figure 6: LUT resource breakdown of MobileNetV2's second conv layer.
pub fn fig6() {
    let b = fig6_breakdown();
    println!(
        "Figure 6: LUT breakdown, MobileNetV2 conv2 (1x1, 32->32, {} weights)",
        b.n_weights
    );
    println!("{:<28}{:>12}{:>12}", "", "ours", "paper");
    println!(
        "{:<28}{:>12.0}{:>12.0}",
        "HLS multiplication LUTs", b.hls_mult_luts, Fig6Published::HLS_MULT_LUTS
    );
    println!("{:<28}{:>12.0}{:>12.0}", "impl ROM LUTs", b.impl_rom_luts, Fig6Published::IMPL_ROM_LUTS);
    println!(
        "{:<28}{:>12.0}{:>12.0}",
        "impl adder+other LUTs",
        b.impl_adder_luts + b.threshold_luts,
        Fig6Published::IMPL_ADDER_OTHER_LUTS
    );
    println!(
        "{:<28}{:>12.0}{:>12.0}",
        "impl total LUTs", b.impl_total_luts, Fig6Published::IMPL_TOTAL_LUTS
    );
    println!("(theory = Eq.3: {:.0} LUTs)", b.theory_mult_luts);
}

/// Synthesize our LUTMUL design of full MobileNetV2 on the U280
/// (pixel-rate input interface: the dataflow optimum).
pub fn our_design() -> Design {
    let arch = mobilenet_v2_full();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    synthesize(&arch, &U280, &folds)
}

/// Paper-style design point: element-serial input ingestion (FINN-heritage
/// sliding-window generators consume one activation element per cycle),
/// which floors the pipeline at `in_px * in_ch` cycles — the regime the
/// paper's 1627 FPS lives in.
pub fn paper_style_design() -> Design {
    let arch = mobilenet_v2_full();
    let floor = (arch.input_hw * arch.input_hw * arch.input_ch) as u64;
    let (folds, cycles) =
        crate::synth::fold::optimize_folding_with_floor(&arch, &Budget::whole(&U280), floor);
    let mut d = synthesize(&arch, &U280, &folds);
    d.cycles_per_image = d.cycles_per_image.max(cycles);
    d
}

/// Multi-device scaling table (DESIGN.md S18 / EXPERIMENTS.md E11): FPS
/// of full MobileNetV2 partitioned over 1–4 U280s, flagging whether each
/// point is compute- or link-bound. Printed for the 100 GbE fabric the
/// paper's testbed uses and a deliberately thin 1 GbE contrast where the
/// links take over as the bottleneck.
pub fn multi_scaling() {
    let arch = mobilenet_v2_full();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    println!("Multi-device scaling: MobileNetV2 across 1-4 x {}", U280.name);
    for (label, link) in [
        ("100 GbE", LinkModel::gbe100()),
        ("1 GbE", LinkModel { bandwidth_bps: 125e6 * 0.8, latency_s: 20e-6 }),
    ] {
        println!("\n{label} links:");
        println!(
            "{:>8}{:>14}{:>10}{:>10}{:>12}{:>14}",
            "devices", "max LUT/dev", "FPS", "speedup", "bound", "+latency(us)"
        );
        let base = partition(&arch, &U280, 1, &folds, link).fps();
        for n in 1..=4usize {
            let plan = partition(&arch, &U280, n, &folds, link);
            println!(
                "{:>8}{:>14.0}{:>10.0}{:>9.2}x{:>12}{:>14.1}",
                n,
                plan.max_device_luts(),
                plan.fps(),
                plan.fps() / base,
                if plan.is_link_bound() { "link" } else { "compute" },
                plan.added_latency_s() * 1e6
            );
        }
    }
    println!(
        "\n(per-device folds held at the single-device optimum, so the table\n\
         isolates the partition: balanced slices fit smaller devices at the\n\
         same steady-state FPS until the link bandwidth takes over; re-run\n\
         `lutmul multi --run` for the executable-chain cross-check)"
    );
}

/// Table 2: accelerator comparison (published rows + our regenerated row).
pub fn table2() {
    println!("Table 2: MobileNet accelerator comparison");
    let ours = our_design();
    println!(
        "{:<16}{:>10}{:>9}{:>9}{:>8}{:>9}{:>9}{:>10}{:>9}",
        "design", "LUT", "BRAM36", "DSP", "P(W)", "FPS", "GOPS", "GOPS/W", "top-1"
    );
    for r in baselines::table2_published() {
        println!(
            "{:<16}{:>10}{:>9.1}{:>9}{:>8}{:>9.1}{:>9.1}{:>10}{:>8.1}%",
            r.name,
            r.luts,
            r.bram36,
            r.dsps,
            r.power_w.map_or("-".into(), |p| format!("{p:.1}")),
            r.fps,
            r.gops,
            r.gops_per_watt.map_or("-".into(), |g| format!("{g:.2}")),
            r.top1_acc
        );
    }
    let p = baselines::lutmul_published();
    println!(
        "{:<16}{:>10}{:>9.1}{:>9}{:>8.1}{:>9.1}{:>9.1}{:>10.2}{:>8.2}%",
        p.name,
        p.luts,
        p.bram36,
        p.dsps,
        p.power_w.unwrap(),
        p.fps,
        p.gops,
        p.gops_per_watt.unwrap(),
        p.top1_acc
    );
    let style = paper_style_design();
    println!(
        "{:<16}{:>10}{:>9}{:>9}{:>8.1}{:>9.1}{:>9.1}{:>10.2}{:>9}",
        "ours (elem-in)",
        style.luts,
        style.bram36,
        style.dsps,
        style.power_w,
        style.fps(),
        style.gops(),
        style.gops_per_watt(),
        "(sim)"
    );
    println!(
        "{:<16}{:>10}{:>9}{:>9}{:>8.1}{:>9.1}{:>9.1}{:>10.2}{:>9}",
        "ours (px-in)",
        ours.luts,
        ours.bram36,
        ours.dsps,
        ours.power_w,
        ours.fps(),
        ours.gops(),
        ours.gops_per_watt(),
        "(sim)"
    );
    println!("\nshape checks (paper -> ours):");
    let finn = &baselines::table2_published()[0];
    println!(
        "  LUTMUL beats every published FPS: paper 1627 vs best baseline {:.0}; ours {:.0} (elem-serial input) / {:.0} (pixel input)",
        finn.fps,
        style.fps(),
        ours.fps()
    );
    println!(
        "  LUTMUL/FINN FPS ratio: paper {:.2}x, ours {:.2}x (elem-serial, same ingest style)",
        baselines::lutmul_published().fps / finn.fps,
        style.fps() / finn.fps
    );
}
