//! Folding optimizer: balance per-layer initiation intervals against the
//! device budget (paper section 3.2: "HLS layers are folded according to
//! performance and resource requirements ... all layers are balanced and
//! pipelined for better throughput").
//!
//! Strategy: binary-search the steady-state cycles-per-image target `C`;
//! for each candidate, every layer takes the largest fold that keeps it
//! off the critical path (`fold <= C / out_pixels`), which minimizes its
//! resources; feasibility = total LUT/BRAM/DSP within budget. The smallest
//! feasible `C` gives the throughput-optimal balanced design.

use crate::fabric::device::FpgaDevice;
use crate::graph::arch::ArchSpec;

use super::design::{stage_resources, choose_mode, synthesize, Design};

/// Resource budget for the optimizer (absolute units).
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub luts: u64,
    pub bram36: u64,
    pub dsps: u64,
}

impl Budget {
    /// A fraction of a device's *compute* resources (e.g. `1/64` of U280
    /// for Figure 1). BRAM stays at device capacity: line buffers and
    /// weight storage are fixed costs of the dataflow that no fold factor
    /// removes — fractioning them would make every design point
    /// infeasible rather than slower, which is not what the paper's
    /// resource-slice analysis means.
    pub fn fraction(device: &FpgaDevice, denom: u64) -> Self {
        Self {
            luts: device.luts / denom,
            bram36: device.bram36,
            dsps: device.dsps / denom,
        }
    }

    pub fn whole(device: &FpgaDevice) -> Self {
        // leave headroom for shell/infrastructure (the paper's design uses
        // 529k of 1304k LUTs; the U280 shell + routing margin caps usable
        // fabric well below 100%)
        Self {
            luts: (device.luts as f64 * 0.85) as u64,
            bram36: (device.bram36 as f64 * 0.85) as u64,
            dsps: device.dsps,
        }
    }
}

/// Per-layer folds for a cycles-per-image target.
fn folds_for_target(arch: &ArchSpec, target_cycles: u64) -> Vec<usize> {
    arch.layers
        .iter()
        .map(|l| {
            let out_px = (l.out_hw() * l.out_hw()) as u64;
            let max_fold = (target_cycles / out_px.max(1)).max(1);
            // fold beyond the per-pixel work is useless
            max_fold.min(l.mults_per_pixel().max(1)) as usize
        })
        .collect()
}

/// Total resources for an arch at given folds (mode chosen per layer).
fn total_resources(arch: &ArchSpec, folds: &[usize]) -> (f64, f64, f64) {
    let mut t = (0.0, 0.0, 0.0);
    for (l, &f) in arch.layers.iter().zip(folds) {
        let mode = choose_mode(l, f);
        let (lu, br, ds) = stage_resources(l, mode, f);
        t.0 += lu;
        t.1 += br;
        t.2 += ds;
    }
    t
}

fn feasible(arch: &ArchSpec, folds: &[usize], budget: &Budget) -> bool {
    let (l, b, d) = total_resources(arch, folds);
    l <= budget.luts as f64 && b <= budget.bram36 as f64 && d <= budget.dsps as f64
}

/// Find the smallest steady-state cycles-per-image achievable within the
/// budget; returns the folds and the target.
pub fn optimize_folding(arch: &ArchSpec, budget: &Budget) -> (Vec<usize>, u64) {
    optimize_folding_with_floor(arch, budget, 0)
}

/// Like [`optimize_folding`] but with an external cycles-per-image floor —
/// e.g. an element-serial input interface (the paper's FINN-heritage
/// sliding-window generators ingest one activation element per cycle, so
/// the floor is `in_px * in_ch` rather than `in_px`). A higher floor lets
/// every layer fold deeper at no throughput cost.
pub fn optimize_folding_with_floor(
    arch: &ArchSpec,
    budget: &Budget,
    floor_cycles: u64,
) -> (Vec<usize>, u64) {
    // lower bound: the largest layer output (II=1 everywhere);
    // input streaming also bounds at input_hw^2 (one pixel per cycle),
    // plus any external interface floor.
    let lo_bound = arch
        .layers
        .iter()
        .map(|l| (l.out_hw() * l.out_hw()) as u64)
        .max()
        .unwrap_or(1)
        .max((arch.input_hw * arch.input_hw) as u64)
        .max(floor_cycles);
    // upper bound: fully sequential
    let hi_bound = arch
        .layers
        .iter()
        .map(|l| (l.out_hw() * l.out_hw()) as u64 * l.mults_per_pixel())
        .max()
        .unwrap_or(1);

    let mut lo = lo_bound;
    let mut hi = hi_bound.max(lo_bound);
    if feasible(arch, &folds_for_target(arch, lo), budget) {
        return (folds_for_target(arch, lo), lo);
    }
    // binary search smallest feasible target
    while lo + 1 < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(arch, &folds_for_target(arch, mid), budget) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    (folds_for_target(arch, hi), hi)
}

/// Convenience: optimize folding and synthesize on a device.
pub fn synthesize_optimized(arch: &ArchSpec, device: &FpgaDevice, budget: &Budget) -> Design {
    let (folds, _) = optimize_folding(arch, budget);
    synthesize(arch, device, &folds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::U280;
    use crate::graph::arch::{mobilenet_v2_full, mobilenet_v2_small};

    #[test]
    fn small_model_reaches_input_bound() {
        let arch = mobilenet_v2_small();
        let (folds, cycles) = optimize_folding(&arch, &Budget::whole(&U280));
        // the tiny model is input-streaming bound: 16x16 pixels/image
        assert_eq!(cycles, 256);
        // layers on the critical path (out_px == 256) must be II=1;
        // smaller layers may fold into the slack without hurting FPS
        for (l, &f) in arch.layers.iter().zip(&folds) {
            let out_px = (l.out_hw() * l.out_hw()) as u64;
            assert!(out_px * f as u64 <= 256, "{} violates the target", l.name);
        }
    }

    #[test]
    fn full_mobilenet_fits_budget() {
        let arch = mobilenet_v2_full();
        let budget = Budget::whole(&U280);
        let (folds, _) = optimize_folding(&arch, &budget);
        assert!(feasible(&arch, &folds, &budget));
        assert!(folds.iter().any(|&f| f > 1), "deep layers must fold");
    }

    #[test]
    fn tighter_budget_means_slower_design() {
        let arch = mobilenet_v2_full();
        let (_, c_full) = optimize_folding(&arch, &Budget::whole(&U280));
        let (_, c_frac) = optimize_folding(&arch, &Budget::fraction(&U280, 8));
        assert!(c_frac >= c_full);
    }

    #[test]
    fn paper_scale_throughput_shape() {
        // Shape checks for the headline claim (paper: 1627 FPS / 978.6
        // GOPS on U280 @333 MHz). Our balanced fold optimizer lands at
        // the input-streaming bound (224^2 pixels/image -> ~6.6k FPS),
        // faster than the paper's manual design — the *ordering* and the
        // LUTMUL>FINN factor are what must reproduce (EXPERIMENTS.md E6).
        let arch = mobilenet_v2_full();
        let d = synthesize_optimized(&arch, &U280, &Budget::whole(&U280));
        let fps = d.fps();
        assert!(fps > 1000.0 && fps < 10_000.0, "FPS {fps} out of regime");
        // beats FINN's published 925 FPS by at least the paper's 1.76x
        assert!(fps / 925.0 > 1.76, "LUTMUL/FINN factor too small: {fps}/925");
        // and the design actually fits the device
        assert!(d.luts < U280.luts);
        assert!((d.dsps as f64) < U280.dsps as f64);
    }

    #[test]
    fn monotone_feasibility() {
        // if C is feasible, C' > C must be feasible too (more folding
        // shrinks resources) — the invariant the binary search relies on.
        let arch = mobilenet_v2_full();
        let budget = Budget::whole(&U280);
        let (_, c) = optimize_folding(&arch, &budget);
        for mult in [2u64, 4, 16] {
            assert!(feasible(&arch, &folds_for_target(&arch, c * mult), &budget));
        }
    }
}
