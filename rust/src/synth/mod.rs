//! Synthesis analog (DESIGN.md S8): maps an architecture spec onto FPGA
//! resources the way the paper's HLS-template + Vivado flow does.
//!
//! For every layer it sizes the LUT-ROM multiplier array (Eq. 3 with the
//! Figure 6-calibrated implementation factors), the adder tree, the
//! multi-threshold unit and the line-buffer BRAM; the folding optimizer
//! then balances per-layer initiation intervals against the device (or
//! device-fraction) budget — the paper's "folded according to performance
//! and resource requirements" step. SLR assignment follows section 3.3:
//! stages fill one Super Logic Region before spilling into the next.

pub mod breakdown;
pub mod design;
pub mod fold;
pub mod report;

pub use breakdown::{fig6_breakdown, LayerBreakdown};
pub use design::{synthesize, Design, StageDesign};
pub use fold::optimize_folding;
pub use report::utilization_report;
