//! Design generation: architecture spec -> synthesized accelerator.
//!
//! Each layer is implemented in one of three modes, mirroring the paper's
//! actual U280 design ("we implement the first 15 layers of MobileNetV2 in
//! a fully parallel manner and fold the remaining layers"):
//!
//!  * `LutRom`  — LUTMUL proper: weights embedded in LUT ROMs (Eq. 3),
//!    adder trees + threshold units per physical output channel.
//!  * `BramMac` — folded layers whose weight count would blow the LUT
//!    budget: weights stream from BRAM into general soft-logic MACs
//!    (the FINN-style fallback for deep layers).
//!  * `Dsp`     — 8-bit first/last layers on DSP48 slices with p=2
//!    packing (the paper's residual 106 DSPs).


use crate::dataflow::convgen::ConvGenConfig;
use crate::fabric::cost;
use crate::fabric::device::FpgaDevice;
use crate::fabric::power::estimate_power_w;
use crate::graph::arch::{ArchSpec, LayerSpec};

use super::breakdown::layer_breakdown;

/// Implementation mode of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerMode {
    LutRom,
    BramMac,
    Dsp,
}

/// Synthesized per-layer hardware stage.
#[derive(Debug, Clone)]
pub struct StageDesign {
    pub name: String,
    pub mode: LayerMode,
    pub fold: usize,
    /// Initiation interval: cycles per output pixel.
    pub ii: u64,
    /// Cycles to produce one whole image through this stage.
    pub cycles_per_image: u64,
    pub luts: f64,
    pub ffs: f64,
    pub bram36: f64,
    pub dsps: f64,
    /// SLR this stage is placed on (0-based).
    pub slr: u32,
}

/// A complete synthesized design.
#[derive(Debug, Clone)]
pub struct Design {
    pub arch_name: String,
    pub device: String,
    pub stages: Vec<StageDesign>,
    pub freq_mhz: f64,
    pub luts: u64,
    pub ffs: u64,
    pub bram36: u64,
    pub dsps: u64,
    /// Steady-state cycles per image (slowest stage).
    pub cycles_per_image: u64,
    pub ops_per_image: u64,
    pub power_w: f64,
}

impl Design {
    pub fn fps(&self) -> f64 {
        self.freq_mhz * 1e6 / self.cycles_per_image as f64
    }

    pub fn gops(&self) -> f64 {
        self.ops_per_image as f64 * self.fps() / 1e9
    }

    pub fn gops_per_watt(&self) -> f64 {
        self.gops() / self.power_w
    }

    /// Fraction of device LUTs used.
    pub fn lut_utilization(&self, device: &FpgaDevice) -> f64 {
        self.luts as f64 / device.luts as f64
    }
}

/// Per-layer resource estimate in a given mode at a given fold.
pub fn stage_resources(layer: &LayerSpec, mode: LayerMode, fold: usize) -> (f64, f64, f64) {
    // returns (luts, bram36, dsps)
    let fold = fold.max(1) as f64;
    let gen_cfg = ConvGenConfig {
        in_h: layer.in_hw,
        in_w: layer.in_hw,
        cin: layer.cin,
        k: layer.k,
        stride: layer.stride,
        pad: (layer.k - 1) / 2,
    };
    let line_bram = gen_cfg.line_buffer_bits(layer.a_bits) as f64 / 36_864.0;
    match mode {
        LayerMode::LutRom => {
            let b = layer_breakdown(layer, fold as usize);
            (b.impl_total_luts, line_bram, 0.0)
        }
        LayerMode::BramMac => {
            // weights in BRAM, general multipliers for the folded array
            let phys_mults = (layer.mults_per_pixel() as f64 / fold).ceil();
            let mac_luts = phys_mults
                * (cost::luts_per_general_mult(layer.w_bits)
                    + cost::luts_per_adder(cost::accumulator_width(
                        2 * layer.w_bits,
                        layer.cin_eff() as u32,
                    )));
            let w_bram = (layer.n_weights() * layer.w_bits as u64) as f64 / 36_864.0;
            (mac_luts, line_bram + w_bram, 0.0)
        }
        LayerMode::Dsp => {
            // p=2 packing at 8 bit: two MACs per DSP per cycle
            let phys_mults = (layer.mults_per_pixel() as f64 / fold).ceil();
            let dsps = (phys_mults / 2.0).ceil();
            let w_bram = (layer.n_weights() * layer.w_bits as u64) as f64 / 36_864.0;
            // control + accumulation glue
            let glue_luts = dsps * 12.0;
            (glue_luts, line_bram + w_bram, dsps)
        }
    }
}

/// Pick the cheapest implementation mode for a layer at a given fold.
///
/// 8-bit layers go to DSP (the paper's first/last-layer choice); 4-bit
/// layers use LUT ROMs unless the general-MAC form is cheaper in LUTs
/// (deep, heavily folded layers where storage dominates).
pub fn choose_mode(layer: &LayerSpec, fold: usize) -> LayerMode {
    if layer.w_bits >= 8 {
        return LayerMode::Dsp;
    }
    let (lut_rom, ..) = stage_resources(layer, LayerMode::LutRom, fold);
    let (bram_mac, ..) = stage_resources(layer, LayerMode::BramMac, fold);
    if bram_mac < lut_rom {
        LayerMode::BramMac
    } else {
        LayerMode::LutRom
    }
}

/// Synthesize an architecture with explicit per-layer folds.
pub fn synthesize(arch: &ArchSpec, device: &FpgaDevice, folds: &[usize]) -> Design {
    assert_eq!(folds.len(), arch.layers.len(), "one fold per layer");
    let mut stages = Vec::with_capacity(arch.layers.len());
    let (mut luts, mut bram, mut dsps) = (0.0f64, 0.0f64, 0.0f64);
    let mut cycles_max: u64 = arch.input_hw as u64 * arch.input_hw as u64;
    // SLR spill: fill one Super Logic Region before crossing (section 3.3)
    let slr_capacity = device.luts as f64 / device.slrs as f64;
    let mut slr = 0u32;
    let mut slr_fill = 0.0f64;

    for (layer, &fold) in arch.layers.iter().zip(folds) {
        let fold = fold.max(1);
        let mode = choose_mode(layer, fold);
        let (l, b, d) = stage_resources(layer, mode, fold);
        let out_px = (layer.out_hw() * layer.out_hw()) as u64;
        let cycles = out_px * fold as u64;
        cycles_max = cycles_max.max(cycles);
        if slr_fill + l > slr_capacity && slr + 1 < device.slrs {
            slr += 1;
            slr_fill = 0.0;
        }
        slr_fill += l;
        stages.push(StageDesign {
            name: layer.name.clone(),
            mode,
            fold,
            ii: fold as u64,
            cycles_per_image: cycles,
            luts: l,
            ffs: l * 0.95, // paper's FF/LUT ratio (503192/529242)
            bram36: b,
            dsps: d,
            slr,
        });
        luts += l;
        bram += b;
        dsps += d;
    }

    // FIFO BRAM between stages (depth ~ a few rows of the wider side)
    let fifo_bram = stages.len() as f64 * 2.0;
    bram += fifo_bram;

    // frequency: target 333 MHz; derate when utilization is extreme
    // (routing congestion), per the paper's timing-closure discussion.
    let util = luts / device.luts as f64;
    let freq = if util <= 0.5 {
        device.max_freq_mhz
    } else if util <= 0.85 {
        device.max_freq_mhz * 0.9
    } else {
        device.max_freq_mhz * 0.75
    };

    let power = estimate_power_w(device, luts as u64, bram as u64, dsps as u64, freq);
    Design {
        arch_name: arch.name.clone(),
        device: device.name.to_string(),
        stages,
        freq_mhz: freq,
        luts: luts as u64,
        ffs: (luts * 0.95) as u64,
        bram36: bram.ceil() as u64,
        dsps: dsps as u64,
        cycles_per_image: cycles_max,
        ops_per_image: arch.ops_per_image(),
        power_w: power,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::U280;
    use crate::graph::arch::{mobilenet_v2_full, mobilenet_v2_small};

    #[test]
    fn small_arch_fully_parallel_fits_u280() {
        let arch = mobilenet_v2_small();
        let folds = vec![1; arch.layers.len()];
        let d = synthesize(&arch, &U280, &folds);
        assert!(d.luts < U280.luts, "small model must fit: {} LUTs", d.luts);
        assert!(d.fps() > 0.0 && d.gops() > 0.0);
    }

    #[test]
    fn full_mobilenet_fully_parallel_overflows() {
        // Full MobileNetV2 with every weight in LUT ROMs cannot fit —
        // this is why the paper folds the deep layers.
        let arch = mobilenet_v2_full();
        let mut rom_luts = 0.0;
        for l in &arch.layers {
            if l.w_bits < 8 {
                rom_luts += stage_resources(l, LayerMode::LutRom, 1).0;
            }
        }
        assert!(rom_luts > U280.luts as f64, "got {rom_luts}");
        // but a folded design must fit (modes switch to BRAM/DSP)
        let folds2: Vec<usize> = arch.layers.iter().map(|l| {
            if l.n_weights() > 20_000 { 64 } else { 1 }
        }).collect();
        let d = synthesize(&arch, &U280, &folds2);
        assert!(d.stages.iter().any(|s| s.mode == LayerMode::BramMac));
        let _ = d;
    }

    #[test]
    fn eight_bit_layers_use_dsp() {
        let arch = mobilenet_v2_small();
        let folds = vec![4; arch.layers.len()];
        let d = synthesize(&arch, &U280, &folds);
        assert_eq!(d.stages[0].mode, LayerMode::Dsp, "stem is 8-bit");
        assert!(d.dsps > 0);
    }

    #[test]
    fn folding_trades_throughput_for_resources() {
        let arch = mobilenet_v2_small();
        let fast = synthesize(&arch, &U280, &vec![1; arch.layers.len()]);
        let slow = synthesize(&arch, &U280, &vec![8; arch.layers.len()]);
        assert!(fast.fps() > slow.fps());
        assert!(fast.luts > slow.luts);
    }

    #[test]
    fn slr_assignment_monotonic() {
        let arch = mobilenet_v2_full();
        let folds: Vec<usize> = arch.layers.iter().map(|l| if l.n_weights() > 20_000 { 64 } else { 1 }).collect();
        let d = synthesize(&arch, &U280, &folds);
        let slrs: Vec<u32> = d.stages.iter().map(|s| s.slr).collect();
        assert!(slrs.windows(2).all(|w| w[0] <= w[1]), "stages cross SLRs monotonically");
        assert!(*slrs.last().unwrap() < U280.slrs);
    }

    #[test]
    fn gops_consistent_with_fps() {
        let arch = mobilenet_v2_small();
        let d = synthesize(&arch, &U280, &vec![1; arch.layers.len()]);
        let expect = d.ops_per_image as f64 * d.fps() / 1e9;
        assert!((d.gops() - expect).abs() < 1e-9);
    }
}
