//! Vivado-style utilization report for a synthesized design — the
//! human-readable artifact an FPGA engineer would sanity-check before
//! `place_design` (per-SLR tables, per-mode rollups, device percentages).

use std::fmt::Write as _;

use crate::fabric::device::FpgaDevice;

use super::design::{Design, LayerMode};

/// Render a utilization report (deterministic text).
pub fn utilization_report(design: &Design, device: &FpgaDevice) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "+--------------------------------------------------------------+");
    let _ = writeln!(s, "| Utilization Report — {} on {}", design.arch_name, design.device);
    let _ = writeln!(s, "| target {} MHz, {} cycles/image, {:.0} FPS", design.freq_mhz, design.cycles_per_image, design.fps());
    let _ = writeln!(s, "+--------------------------------------------------------------+");

    // device-level rollup
    let pct = |used: f64, avail: u64| 100.0 * used / avail as f64;
    let _ = writeln!(s, "\n1. Device totals\n----------------");
    let _ = writeln!(s, "{:<10}{:>12}{:>12}{:>9}", "resource", "used", "available", "util%");
    let _ = writeln!(s, "{:<10}{:>12}{:>12}{:>8.1}%", "LUT", design.luts, device.luts, pct(design.luts as f64, device.luts));
    let _ = writeln!(s, "{:<10}{:>12}{:>12}{:>8.1}%", "FF", design.ffs, device.ffs, pct(design.ffs as f64, device.ffs));
    let _ = writeln!(s, "{:<10}{:>12}{:>12}{:>8.1}%", "BRAM36", design.bram36, device.bram36, pct(design.bram36 as f64, device.bram36));
    let _ = writeln!(s, "{:<10}{:>12}{:>12}{:>8.1}%", "DSP", design.dsps, device.dsps, pct(design.dsps as f64, device.dsps));

    // per-SLR
    let _ = writeln!(s, "\n2. Super Logic Regions\n----------------------");
    let slr_cap = device.luts as f64 / device.slrs as f64;
    for slr in 0..device.slrs {
        let stages: Vec<_> = design.stages.iter().filter(|st| st.slr == slr).collect();
        let luts: f64 = stages.iter().map(|st| st.luts).sum();
        let _ = writeln!(
            s,
            "SLR{slr}: {:>3} stages, {:>9.0} LUTs ({:.1}% of SLR)",
            stages.len(),
            luts,
            100.0 * luts / slr_cap
        );
    }

    // per-mode rollup
    let _ = writeln!(s, "\n3. Implementation modes\n-----------------------");
    for mode in [LayerMode::LutRom, LayerMode::BramMac, LayerMode::Dsp] {
        let stages: Vec<_> = design.stages.iter().filter(|st| st.mode == mode).collect();
        if stages.is_empty() {
            continue;
        }
        let luts: f64 = stages.iter().map(|st| st.luts).sum();
        let bram: f64 = stages.iter().map(|st| st.bram36).sum();
        let dsp: f64 = stages.iter().map(|st| st.dsps).sum();
        let _ = writeln!(
            s,
            "{:<9?}: {:>3} layers | {:>9.0} LUT | {:>7.1} BRAM36 | {:>6.0} DSP",
            mode,
            stages.len(),
            luts,
            bram,
            dsp
        );
    }

    // critical path (throughput, not timing)
    let _ = writeln!(s, "\n4. Throughput-critical stages\n-----------------------------");
    let mut by_cycles: Vec<_> = design.stages.iter().collect();
    by_cycles.sort_by_key(|st| std::cmp::Reverse(st.cycles_per_image));
    for st in by_cycles.iter().take(5) {
        let _ = writeln!(
            s,
            "{:<16} {:>9} cycles/img (fold {:>4}, {:?})",
            st.name, st.cycles_per_image, st.fold, st.mode
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::U280;
    use crate::graph::arch::mobilenet_v2_full;
    use crate::synth::fold::{optimize_folding, Budget};
    use crate::synth::synthesize;

    fn design() -> Design {
        let arch = mobilenet_v2_full();
        let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
        synthesize(&arch, &U280, &folds)
    }

    #[test]
    fn report_contains_all_sections() {
        let r = utilization_report(&design(), &U280);
        for sec in ["Device totals", "Super Logic Regions", "Implementation modes", "Throughput-critical"] {
            assert!(r.contains(sec), "missing section {sec}");
        }
        assert!(r.contains("SLR0"));
        assert!(r.contains("LUT"));
    }

    #[test]
    fn utilization_under_100_percent() {
        let d = design();
        let r = utilization_report(&d, &U280);
        assert!(d.lut_utilization(&U280) < 1.0);
        // every printed util% is parseable and < 100
        for line in r.lines() {
            if let Some(p) = line.strip_suffix('%') {
                if let Some(v) = p.rsplit(' ').next().and_then(|t| t.parse::<f64>().ok()) {
                    assert!(v < 100.0, "{line}");
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let d = design();
        assert_eq!(utilization_report(&d, &U280), utilization_report(&d, &U280));
    }
}
