//! Per-layer LUT resource breakdown — the Figure 6 analysis.
//!
//! Cost model (calibrated to Figure 6, see `fabric::cost`):
//!  * **ROM LUTs** store the embedded weights: Eq. (3) per weight —
//!    *fold-independent* (folding time-multiplexes compute, but every
//!    weight still needs its INIT bits; Figure 5's WS packing is exactly
//!    the fold=2 sharing that keeps the per-weight cost at 2 LUT6).
//!  * **Adder/threshold LUTs** are per *physical* output channel and
//!    shrink by the fold factor (`cout / fold` channels per cycle).


use crate::fabric::cost;
use crate::graph::arch::LayerSpec;
use crate::graph::network::ConvKind;

/// LUT breakdown of one synthesized layer, at three points of the flow
/// (theory / HLS report / post-implementation), mirroring Figure 6.
#[derive(Debug, Clone)]
pub struct LayerBreakdown {
    pub name: String,
    pub n_weights: u64,
    pub n_mults: u64,
    /// Eq. (3) theoretical multiplier (ROM) LUTs.
    pub theory_mult_luts: f64,
    /// HLS-reported multiplier LUTs (logic optimization trims constants).
    pub hls_mult_luts: f64,
    /// Post-implementation LUTs instantiated as ROM.
    pub impl_rom_luts: f64,
    /// Post-implementation adder + other logic LUTs.
    pub impl_adder_luts: f64,
    /// Threshold-unit LUTs (comparators).
    pub threshold_luts: f64,
    /// Total post-implementation LUTs.
    pub impl_total_luts: f64,
}

/// Resource breakdown for a LUTMUL layer with a given fold factor.
pub fn layer_breakdown(layer: &LayerSpec, fold: usize) -> LayerBreakdown {
    let fold = fold.max(1) as f64;
    let n_weights = layer.n_weights();
    let w = layer.w_bits;

    // Weight storage: Eq. 3 per weight, independent of folding.
    let theory = n_weights as f64 * cost::luts_per_mult(w);
    let hls = theory * cost::HLS_MULT_FACTOR;
    let rom_impl = theory * cost::VIVADO_ROM_FACTOR;

    // Compute: one adder tree + threshold unit per physical output
    // channel; folding processes cout/fold channels per cycle.
    let phys_cout = (layer.cout as f64 / fold).ceil();
    let prod_bits = 2 * w;
    let tree = cost::adder_tree_luts(prod_bits, layer.cin_eff() as u32);
    let adders_impl = phys_cout * tree * cost::VIVADO_ADDER_SHRINK;

    // Multi-threshold unit: (2^a - 1) compare-to-constant levels. A naive
    // comparator is ~acc_width/6 LUT6 (six accumulator bits per LUT), but
    // Vivado optimizes the thermometer bank jointly (adjacent levels share
    // their upper-bit prefix logic), landing near 1 LUT per level — the
    // residual of Figure 6's 2645 "adder and other" after the adder trees.
    let levels = (1u64 << layer.a_bits) - 1;
    let threshold = phys_cout * levels as f64;

    LayerBreakdown {
        name: layer.name.clone(),
        n_weights,
        n_mults: layer.mults_per_pixel(),
        theory_mult_luts: theory,
        hls_mult_luts: hls,
        impl_rom_luts: rom_impl,
        impl_adder_luts: adders_impl,
        threshold_luts: threshold,
        impl_total_luts: rom_impl + adders_impl + threshold,
    }
}

/// The paper's Figure 6 subject: MobileNetV2's second convolution
/// (1x1, 32 -> 32 channels, 1024 4-bit weights), fully parallel.
pub fn fig6_breakdown() -> LayerBreakdown {
    layer_breakdown(&crate::graph::arch::fig6_conv2(), 1)
}

/// Paper-published Figure 6 reference values for validation.
pub struct Fig6Published;

impl Fig6Published {
    pub const HLS_MULT_LUTS: f64 = 1829.0;
    pub const IMPL_ROM_LUTS: f64 = 3277.0;
    pub const IMPL_ADDER_OTHER_LUTS: f64 = 2645.0;
    pub const IMPL_TOTAL_LUTS: f64 = 5922.0;
}

/// Depthwise layers keep one small ROM array per channel.
pub fn is_dw(layer: &LayerSpec) -> bool {
    layer.kind == ConvKind::Dw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::arch::fig6_conv2;

    #[test]
    fn fig6_matches_paper_within_tolerance() {
        let b = fig6_breakdown();
        assert_eq!(b.n_weights, 1024);
        let e_hls = (b.hls_mult_luts - Fig6Published::HLS_MULT_LUTS).abs() / Fig6Published::HLS_MULT_LUTS;
        assert!(e_hls < 0.02, "HLS mult LUTs {} vs 1829", b.hls_mult_luts);
        let e_rom = (b.impl_rom_luts - Fig6Published::IMPL_ROM_LUTS).abs() / Fig6Published::IMPL_ROM_LUTS;
        assert!(e_rom < 0.02, "impl ROM {} vs 3277", b.impl_rom_luts);
        // "adder and other logic" = adder trees + threshold bank
        let other = b.impl_adder_luts + b.threshold_luts;
        let e_add =
            (other - Fig6Published::IMPL_ADDER_OTHER_LUTS).abs() / Fig6Published::IMPL_ADDER_OTHER_LUTS;
        assert!(e_add < 0.05, "impl adder+other {other} vs 2645");
        // total within 5% of the paper's 5922
        let e_tot = (b.impl_total_luts - Fig6Published::IMPL_TOTAL_LUTS).abs()
            / Fig6Published::IMPL_TOTAL_LUTS;
        assert!(e_tot < 0.05, "impl total {} vs 5922", b.impl_total_luts);
    }

    #[test]
    fn theory_is_eq3() {
        let b = layer_breakdown(&fig6_conv2(), 1);
        assert_eq!(b.theory_mult_luts, 1024.0 * 2.0); // Eq. 3 at 4 bits
    }

    #[test]
    fn rom_is_fold_independent_storage() {
        // Weights cannot fold away: the ROM term is storage.
        let l = fig6_conv2();
        let f1 = layer_breakdown(&l, 1);
        let f8 = layer_breakdown(&l, 8);
        assert_eq!(f1.impl_rom_luts, f8.impl_rom_luts);
    }

    #[test]
    fn folding_shrinks_compute() {
        let l = fig6_conv2();
        let full = layer_breakdown(&l, 1);
        let folded = layer_breakdown(&l, 8);
        assert!(folded.impl_adder_luts < full.impl_adder_luts / 4.0);
        assert!(folded.threshold_luts < full.threshold_luts / 4.0);
        assert!(folded.impl_total_luts < full.impl_total_luts);
    }

    #[test]
    fn eight_bit_layers_cost_more_per_mult() {
        let mut l = fig6_conv2();
        l.w_bits = 8;
        l.a_bits = 8;
        let b8 = layer_breakdown(&l, 1);
        let b4 = fig6_breakdown();
        assert!(b8.theory_mult_luts > b4.theory_mult_luts * 10.0);
    }
}
