//! # LUTMUL — LUT-based efficient multiplication for NN inference
//!
//! Reproduction of *LUTMUL: Exceed Conventional FPGA Roofline Limit by
//! LUT-based Efficient MULtiplication for Neural Network Inference*
//! (Xie et al., ASPDAC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (build-time Python)** — the LUT-lookup multiplication kernel in
//!   Pallas (`python/compile/kernels/lutmul.py`), bit-exact against a
//!   pure-jnp oracle.
//! * **L2 (build-time Python)** — quantization-aware-trained MobileNetV2
//!   in JAX, streamlined to an integer-only network and AOT-lowered to
//!   HLO text artifacts.
//! * **L3 (this crate)** — the accelerator generator and runtime:
//!   bit-exact FPGA fabric simulation ([`fabric`]), the streamlined graph
//!   IR, compiled layer plans + kernel engine, and reference executor
//!   ([`graph`]), the cycle-level reconfigurable dataflow architecture
//!   ([`dataflow`]), the synthesis analog with folding optimizer
//!   ([`synth`]), roofline analysis ([`roofline`]), baseline accelerator
//!   models ([`baselines`]), the PJRT runtime that executes the AOT
//!   artifacts ([`runtime`]), the async serving coordinator
//!   ([`coordinator`]), the network-facing serving tier with its
//!   open-loop load generator ([`serve`], [`loadgen`]), and the
//!   accuracy harness charting the accuracy–speed–area Pareto front of
//!   the exact, pruned and Maddness-approximate datapaths ([`eval`]).
//!
//! The inference path is batch-major end to end: the coordinator's
//! dynamic batcher dispatches whole batches to persistent per-worker
//! backends, which execute them through
//! [`graph::executor::Executor::run_batch`] (layer-major loops, scoped
//! threads) or stream them overlapped through the dataflow pipeline —
//! batching buys arithmetic throughput, not just queueing fairness.
//! Every backend runs compiled layer plans ([`graph::plan`], DESIGN.md
//! S17): networks are lowered once — flattened weights, interior/border
//! im2row splits, memoized LUT6_2 product tables — and the executor,
//! simulator and serving stack consume the same plans.
//!
//! All of it sits behind one construction path ([`engine`], DESIGN.md
//! S19): `Engine::builder()` resolves the artifact-or-synthetic
//! network, optimizes folding and compiles the plan exactly once, and
//! every run surface — executor, pipeline, shard chain, PJRT —
//! implements the same `InferenceBackend` trait, so the CLI, the
//! coordinator's workers, benches and tests drive batches through one
//! boxed contract (`lutmul bench --backends all` prints the
//! cross-backend bit-exactness + throughput comparison).
//!
//! See the repo-root `README.md` for build/run instructions, `DESIGN.md`
//! for the system inventory (S1-S21) and the experiment index
//! (Table 1/2, Figures 1/2/5/6), and `EXPERIMENTS.md` for measured
//! results vs the paper.

pub mod baselines;
pub mod coordinator;
pub mod util;
pub mod dataflow;
pub mod engine;
pub mod eval;
pub mod fabric;
pub mod graph;
pub mod loadgen;
pub mod quant;
pub mod reports;
pub mod roofline;
pub mod runtime;
pub mod serve;
pub mod synth;
