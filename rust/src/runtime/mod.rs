//! PJRT runtime (DESIGN.md S11): load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them on the XLA CPU client.
//!
//! The interchange format is HLO *text* — jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Python never runs on the request path: the artifacts are compiled once
//! at startup and executed from Rust.
//!
//! The real PJRT client needs the external `xla` bindings and is gated
//! behind the `xla` cargo feature; without it, [`Runtime`] is a stub whose
//! `load` returns an error, so the rest of the stack (executor, dataflow
//! simulator, coordinator) builds and serves offline. See EXPERIMENTS.md
//! ("Test triage") for which tests this disables.

use std::path::Path;

use anyhow::{Context, Result};

/// A compiled model artifact bound to the PJRT CPU client.
#[cfg(feature = "xla")]
pub struct Runtime {
    exe: xla::PjRtLoadedExecutable,
    /// input geometry: [batch, h, w, c] int32 codes
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Load + compile an HLO text artifact for a fixed batch geometry.
    pub fn load(
        path: impl AsRef<Path>,
        batch: usize,
        h: usize,
        w: usize,
        c: usize,
        num_classes: usize,
    ) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path.as_ref())
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.as_ref().display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.as_ref().display()))?;
        Ok(Self { exe, batch, h, w, c, num_classes })
    }

    /// Execute on a batch of images (flattened `[batch, h, w, c]` codes).
    /// Returns per-image logits.
    pub fn run(&self, codes: &[i32]) -> Result<Vec<Vec<f32>>> {
        let expect = self.batch * self.h * self.w * self.c;
        anyhow::ensure!(
            codes.len() == expect,
            "input length {} != batch geometry {}",
            codes.len(),
            expect
        );
        let lit = xla::Literal::vec1(codes)
            .reshape(&[self.batch as i64, self.h as i64, self.w as i64, self.c as i64])
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        let flat = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(
            flat.len() == self.batch * self.num_classes,
            "output length {} != {}x{}",
            flat.len(),
            self.batch,
            self.num_classes
        );
        Ok(flat.chunks(self.num_classes).map(<[f32]>::to_vec).collect())
    }
}

/// Stub runtime compiled without the `xla` feature: same API, `load`
/// always errors. Keeps the offline build green while making the missing
/// capability loud at the exact call site.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    /// input geometry: [batch, h, w, c] int32 codes
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Always fails: the real PJRT client needs `--features xla`.
    pub fn load(
        path: impl AsRef<Path>,
        _batch: usize,
        _h: usize,
        _w: usize,
        _c: usize,
        _num_classes: usize,
    ) -> Result<Self> {
        anyhow::bail!(
            "PJRT runtime for {} unavailable: built without the `xla` feature (see rust/Cargo.toml)",
            path.as_ref().display()
        )
    }

    /// Unreachable in practice (`load` never constructs the stub).
    pub fn run(&self, _codes: &[i32]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("PJRT runtime unavailable: built without the `xla` feature")
    }
}

impl Runtime {
    /// [`load`](Self::load) from a network's compiled I/O geometry
    /// (`Network::io()` / `NetworkPlan::io`, DESIGN.md S17) instead of
    /// loose dimensions — keeps the PJRT geometry and the executor /
    /// simulator geometry from drifting apart.
    pub fn load_for(
        path: impl AsRef<Path>,
        batch: usize,
        io: &crate::graph::plan::IoGeom,
    ) -> Result<Self> {
        Self::load(path, batch, io.image_size, io.image_size, io.in_ch, io.num_classes)
    }

    /// Run a batch given per-image code vectors (must match `batch`).
    pub fn run_images(&self, images: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(images.len() == self.batch, "need exactly {} images", self.batch);
        let flat: Vec<i32> = images.iter().flatten().copied().collect();
        self.run(&flat)
    }

    /// Batch-major driver over an arbitrary number of images: chunk into
    /// the executable's fixed batch geometry, zero-pad the final partial
    /// chunk, and return exactly `images.len()` logit vectors. This is the
    /// PJRT face of the serving fast path (DESIGN.md S10/S11): the batcher
    /// can hand any dispatch size to a batch-compiled artifact.
    pub fn run_batched(&self, images: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let px = self.h * self.w * self.c;
        let mut out = Vec::with_capacity(images.len());
        for chunk in images.chunks(self.batch) {
            let mut flat: Vec<i32> = Vec::with_capacity(self.batch * px);
            for img in chunk {
                anyhow::ensure!(img.len() == px, "image length {} != {px}", img.len());
                flat.extend_from_slice(img);
            }
            flat.resize(self.batch * px, 0); // zero-pad the partial tail
            let logits = self.run(&flat)?;
            out.extend(logits.into_iter().take(chunk.len()));
        }
        Ok(out)
    }
}

/// Artifact paths convention (relative to the repo root).
pub struct Artifacts {
    pub dir: std::path::PathBuf,
}

impl Artifacts {
    pub fn new(dir: impl Into<std::path::PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    pub fn network_json(&self) -> std::path::PathBuf {
        self.dir.join("network.json")
    }

    pub fn model_hlo(&self, batch: usize) -> std::path::PathBuf {
        if batch == 1 {
            self.dir.join("model.hlo.txt")
        } else {
            self.dir.join(format!("model_b{batch}.hlo.txt"))
        }
    }

    pub fn test_images(&self) -> std::path::PathBuf {
        self.dir.join("test_images.bin")
    }

    pub fn test_labels(&self) -> std::path::PathBuf {
        self.dir.join("test_labels.bin")
    }

    pub fn fig2_json(&self) -> std::path::PathBuf {
        self.dir.join("fig2_accuracy.json")
    }

    /// [`load_test_set`](Self::load_test_set) from a network's compiled
    /// I/O geometry (`Network::io()` / `NetworkPlan::io`, DESIGN.md S17)
    /// instead of loose dimensions, mirroring [`Runtime::load_for`].
    pub fn load_test_set_for(
        &self,
        io: &crate::graph::plan::IoGeom,
    ) -> Result<(Vec<Vec<i32>>, Vec<u8>)> {
        self.load_test_set(io.image_size, io.image_size, io.in_ch)
    }

    /// Load the test set (images as code vectors + labels).
    ///
    /// The image file must divide exactly into `h*w*c`-byte records — a
    /// truncated `test_images.bin` or a geometry mismatch errors with
    /// the expected/actual sizes instead of silently dropping the
    /// trailing bytes.
    pub fn load_test_set(&self, h: usize, w: usize, c: usize) -> Result<(Vec<Vec<i32>>, Vec<u8>)> {
        let img_bytes = std::fs::read(self.test_images())
            .context("reading test_images.bin (run `make artifacts`)")?;
        let labels = std::fs::read(self.test_labels()).context("reading test_labels.bin")?;
        let px = h * w * c;
        anyhow::ensure!(px > 0, "degenerate image geometry {h}x{w}x{c}");
        anyhow::ensure!(
            img_bytes.len() % px == 0,
            "{} is {} bytes, not a whole number of {h}x{w}x{c} images ({px} bytes each; \
             {} bytes of trailing garbage — truncated file or geometry mismatch?)",
            self.test_images().display(),
            img_bytes.len(),
            img_bytes.len() % px
        );
        let images: Vec<Vec<i32>> = img_bytes
            .chunks_exact(px)
            .map(|ch| ch.iter().map(|&b| b as i32).collect())
            .collect();
        anyhow::ensure!(
            images.len() == labels.len(),
            "test set size mismatch: {} images vs {} labels",
            images.len(),
            labels.len()
        );
        Ok((images, labels))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_paths() {
        let a = Artifacts::new("artifacts");
        assert_eq!(a.model_hlo(1).to_str().unwrap(), "artifacts/model.hlo.txt");
        assert_eq!(a.model_hlo(8).to_str().unwrap(), "artifacts/model_b8.hlo.txt");
    }

    #[test]
    fn load_test_set_rejects_truncated_images() {
        let dir =
            std::env::temp_dir().join(format!("lutmul-testset-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = Artifacts::new(dir.clone());
        // 10 bytes is not a whole number of 2x2x1 = 4-byte images
        std::fs::write(a.test_images(), vec![7u8; 10]).unwrap();
        std::fs::write(a.test_labels(), vec![0u8; 2]).unwrap();
        let err = a.load_test_set(2, 2, 1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("not a whole number"), "{msg}");
        assert!(msg.contains("10 bytes"), "actual size named: {msg}");
        assert!(msg.contains("4 bytes each"), "expected record size named: {msg}");
        // an exact multiple loads, and label mismatches are named too
        std::fs::write(a.test_images(), vec![7u8; 8]).unwrap();
        let (imgs, labels) = a.load_test_set(2, 2, 1).unwrap();
        assert_eq!((imgs.len(), labels.len()), (2, 2));
        std::fs::write(a.test_labels(), vec![0u8; 3]).unwrap();
        let err = a.load_test_set(2, 2, 1).unwrap_err();
        assert!(err.to_string().contains("2 images vs 3 labels"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_is_a_loud_error() {
        let e = Runtime::load("artifacts/model.hlo.txt", 1, 16, 16, 3, 10).unwrap_err();
        assert!(e.to_string().contains("xla"), "{e}");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_for_takes_io_geometry() {
        let io = crate::graph::plan::IoGeom { image_size: 16, in_ch: 3, num_classes: 10 };
        let e = Runtime::load_for("artifacts/model.hlo.txt", 1, &io).unwrap_err();
        assert!(e.to_string().contains("xla"), "{e}");
    }

    // Full runtime round-trips are covered by rust/tests/runtime_golden.rs
    // (they need the artifacts built and the `xla` feature).
}
