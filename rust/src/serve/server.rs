//! Network front end over the serving coordinator (DESIGN.md S21).
//!
//! A `std`-only TCP server: one acceptor thread plus a reader/writer
//! thread pair per connection (bounded by `max_conns`), all feeding the
//! coordinator's batch-forming window — concurrent sockets coalesce
//! into the plan's `IoGeom` batch geometry exactly like in-process
//! submitters, so the LUT datapath sees full batches whenever the
//! offered load sustains them.
//!
//! Two framings share the listener, told apart by a connection's first
//! four bytes:
//!
//! * **binary** (`serve::proto`) — length-prefixed frames, pipelined:
//!   the reader submits every frame as it arrives and hands the ticket
//!   to the connection's writer, which resolves them *in submission
//!   order*, so responses are never reordered within a connection even
//!   when the batcher interleaves its images with other sockets';
//! * **HTTP/1.1 fallback** — `POST /infer` with raw code bytes,
//!   `GET /metrics` / `GET /healthz`, one request per connection. An
//!   HTTP method read as a little-endian length exceeds
//!   [`proto::MAX_FRAME`](super::proto::MAX_FRAME), so the framings
//!   cannot be confused.
//!
//! Admission control is end-to-end: a full coordinator queue resolves
//! the frame with `Status::Rejected` (and drives the coordinator's
//! `rejected` counter — the overload path the chaos suite exercises
//! from a real socket), expired deadlines come back as
//! `Status::DeadlineExceeded`, a worker failure as `Status::Failed`,
//! and malformed-but-framed requests as `Status::Malformed` without
//! killing the connection. Only an unrecoverable framing error (bogus
//! length prefix, truncated frame) closes the socket, because the byte
//! stream cannot be resynchronized.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{
    Coordinator, Fleet, FleetConfig, FleetSummary, MetricsSummary, RequestClass, ServeConfig,
    ServeError, SubmitError, Ticket,
};
use crate::engine::Engine;

use super::proto::{self, RequestFrame, ResponseFrame, Status};

/// What the network layer serves: the single-pool S21 coordinator or
/// the class-routed S25 fleet. Connections never branch on this beyond
/// `try_submit` — both resolve tickets over the same waiting contract,
/// so the reader/writer machinery is shared verbatim.
enum FrontEnd {
    Single(Coordinator),
    Fleet(Fleet),
}

impl FrontEnd {
    /// Typed submission; single-pool front ends ignore the class.
    fn try_submit(
        &self,
        image: Vec<i32>,
        deadline: Option<Duration>,
        class: RequestClass,
    ) -> std::result::Result<Ticket, SubmitError> {
        match self {
            FrontEnd::Single(c) => c.try_submit(image, deadline),
            FrontEnd::Fleet(f) => f.try_submit(image, deadline, class),
        }
    }

    fn metrics(&self) -> MetricsSummary {
        match self {
            FrontEnd::Single(c) => c.metrics(),
            FrontEnd::Fleet(f) => f.metrics(),
        }
    }

    fn rejected(&self) -> u64 {
        match self {
            FrontEnd::Single(c) => c.rejected(),
            FrontEnd::Fleet(f) => f.rejected(),
        }
    }

    fn fleet(&self) -> Option<&Fleet> {
        match self {
            FrontEnd::Single(_) => None,
            FrontEnd::Fleet(f) => Some(f),
        }
    }

    fn shutdown(self) {
        match self {
            FrontEnd::Single(c) => c.shutdown(),
            FrontEnd::Fleet(f) => f.shutdown(),
        }
    }
}

/// Network configuration; the batching/worker knobs live in
/// [`ServeConfig`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, loadgen
    /// self-hosting).
    pub addr: String,
    /// Connection cap: accepts beyond it are closed immediately (each
    /// connection costs a reader + writer thread).
    pub max_conns: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:0".into(), max_conns: 256 }
    }
}

/// Cumulative socket-level counters (the coordinator's metrics cover
/// everything past admission).
#[derive(Debug, Default)]
pub struct NetStats {
    pub connections: AtomicU64,
    pub refused_conns: AtomicU64,
    pub frames: AtomicU64,
    pub malformed: AtomicU64,
    pub http_requests: AtomicU64,
}

/// Handle to a running network server. Dropping it does NOT stop the
/// server; call [`shutdown`](Server::shutdown) for a deterministic
/// stop-and-join.
pub struct Server {
    addr: SocketAddr,
    front: Option<Arc<FrontEnd>>,
    stop: Arc<AtomicBool>,
    stats: Arc<NetStats>,
    accept_thread: Option<JoinHandle<()>>,
    /// Reader threads of live connections (each joins its own writer).
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Start a coordinator over `engine`'s backend kind and put this
    /// network front end on it.
    pub fn start(engine: &Engine, serve_cfg: ServeConfig, cfg: ServerConfig) -> Result<Server> {
        Self::over(Coordinator::start(engine, serve_cfg)?, cfg)
    }

    /// Start a heterogeneous fleet over `engine` (executor replicas for
    /// latency traffic, `devices`-way shard chains for throughput) and
    /// put this network front end on it (DESIGN.md S25).
    pub fn start_fleet(
        engine: &Engine,
        devices: usize,
        fleet_cfg: FleetConfig,
        cfg: ServerConfig,
    ) -> Result<Server> {
        Self::over_fleet(Fleet::start(engine, devices, fleet_cfg)?, cfg)
    }

    /// Put the network front end over an already-running coordinator
    /// (the chaos suite injects flaky backends through
    /// `Coordinator::start_with` and serves them here).
    pub fn over(coord: Coordinator, cfg: ServerConfig) -> Result<Server> {
        Self::over_front(FrontEnd::Single(coord), cfg)
    }

    /// Put the network front end over an already-running fleet (the
    /// fleet chaos suite injects per-class backends through
    /// `Fleet::start_with` and serves them here).
    pub fn over_fleet(fleet: Fleet, cfg: ServerConfig) -> Result<Server> {
        Self::over_front(FrontEnd::Fleet(fleet), cfg)
    }

    fn over_front(front: FrontEnd, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding lutmul serve to {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let coord = Arc::new(front);
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(NetStats::default());
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(0));

        let accept_thread = {
            let (coord, stop, stats, conns, live) =
                (coord.clone(), stop.clone(), stats.clone(), conns.clone(), live.clone());
            let max_conns = cfg.max_conns.max(1);
            std::thread::Builder::new()
                .name("lutmul-accept".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        if live.load(Ordering::Relaxed) >= max_conns {
                            // over the cap: refuse by closing; the client
                            // sees EOF before any response frame
                            stats.refused_conns.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        stats.connections.fetch_add(1, Ordering::Relaxed);
                        live.fetch_add(1, Ordering::Relaxed);
                        let (coord, stop, stats, live2) =
                            (coord.clone(), stop.clone(), stats.clone(), live.clone());
                        let handle = std::thread::Builder::new()
                            .name("lutmul-conn".into())
                            .spawn(move || {
                                handle_connection(stream, &coord, &stop, &stats);
                                live2.fetch_sub(1, Ordering::Relaxed);
                            })
                            .expect("spawn connection thread");
                        let mut guard = conns.lock().unwrap_or_else(|e| e.into_inner());
                        // reap finished connections so a long-running
                        // server does not accumulate handles
                        let mut alive = Vec::with_capacity(guard.len() + 1);
                        for h in guard.drain(..) {
                            if h.is_finished() {
                                let _ = h.join();
                            } else {
                                alive.push(h);
                            }
                        }
                        alive.push(handle);
                        *guard = alive;
                    }
                })
                .expect("spawn acceptor")
        };

        Ok(Server {
            addr,
            front: Some(coord),
            stop,
            stats,
            accept_thread: Some(accept_thread),
            conns,
        })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serving metrics snapshot (`rejected` included; merged across
    /// pools when serving a fleet).
    pub fn metrics(&self) -> MetricsSummary {
        self.front.as_ref().expect("server running").metrics()
    }

    /// Requests bounced at admission (queue full).
    pub fn rejected(&self) -> u64 {
        self.front.as_ref().expect("server running").rejected()
    }

    /// Per-class fleet snapshot, when this server fronts a fleet.
    pub fn fleet_summary(&self) -> Option<FleetSummary> {
        self.front.as_ref().expect("server running").fleet().map(|f| f.summary())
    }

    /// Arm one injected mid-batch failure on `class`'s pool (fleet
    /// front ends only); returns whether a fleet was armed. The
    /// loadgen's fleet smoke drives its kill through this.
    pub fn chaos_kill(&self, class: RequestClass) -> bool {
        match self.front.as_ref().expect("server running").fleet() {
            Some(f) => {
                f.chaos_kill(class);
                true
            }
            None => false,
        }
    }

    /// Socket-level counters.
    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    /// Stop accepting, drain the connections, and shut the coordinator
    /// down. In-flight requests resolve before this returns (their
    /// connection threads hold the coordinator alive until they exit).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // unblock the acceptor with a wake-up connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let handles: Vec<_> = {
            let mut guard = self.conns.lock().unwrap_or_else(|e| e.into_inner());
            guard.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // every connection thread has exited, so this is the last Arc;
        // fall back to a plain drop if something still races
        if let Some(front) = self.front.take() {
            match Arc::try_unwrap(front) {
                Ok(f) => f.shutdown(),
                Err(_) => eprintln!("lutmul serve: front end still referenced at shutdown"),
            }
        }
    }
}

/// A `Read` over a timeout-armed `TcpStream` that turns read timeouts
/// into retries until the server's stop flag is raised — so connection
/// readers block "forever" on idle sockets yet still join promptly at
/// shutdown.
struct StopAwareStream<'a> {
    inner: &'a TcpStream,
    stop: &'a AtomicBool,
}

impl Read for StopAwareStream<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionAborted,
                    "server shutting down",
                ));
            }
            match (&mut &*self.inner).read(buf) {
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue
                }
                r => return r,
            }
        }
    }
}

/// What the connection's writer does with one submission slot, in
/// arrival order.
enum Outcome {
    /// Wait on the coordinator and forward the result.
    Pending(u64, Ticket),
    /// Answer immediately with this status (admission miss, malformed).
    Immediate(u64, Status),
}

fn handle_connection(
    stream: TcpStream,
    coord: &Arc<FrontEnd>,
    stop: &Arc<AtomicBool>,
    stats: &Arc<NetStats>,
) {
    let _ = stream.set_nodelay(true);
    // periodic wake-ups keep readers joinable at shutdown
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));

    // the first four bytes pick the framing
    let mut first4 = [0u8; 4];
    {
        let mut r = StopAwareStream { inner: &stream, stop };
        let mut filled = 0;
        while filled < 4 {
            match r.read(&mut first4[filled..]) {
                Ok(0) => return, // silent connect-and-close (shutdown wake-up)
                Ok(n) => filled += n,
                Err(_) => return,
            }
        }
    }
    if &first4 == b"POST" || &first4 == b"GET " {
        stats.http_requests.fetch_add(1, Ordering::Relaxed);
        handle_http(&stream, &first4, coord, stop, stats);
        return;
    }
    handle_binary(&stream, first4, coord, stop, stats);
}

fn handle_binary(
    stream: &TcpStream,
    first4: [u8; 4],
    coord: &Arc<FrontEnd>,
    stop: &Arc<AtomicBool>,
    stats: &Arc<NetStats>,
) {
    // writer half: resolves outcomes in submission order, so responses
    // on this connection are never reordered
    let (tx, rx): (Sender<Outcome>, Receiver<Outcome>) = channel();
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = std::thread::Builder::new()
        .name("lutmul-conn-writer".into())
        .spawn(move || {
            let mut w = std::io::BufWriter::new(&writer_stream);
            while let Ok(outcome) = rx.recv() {
                let resp = match outcome {
                    Outcome::Immediate(id, status) => {
                        ResponseFrame { id, status, class: 0, logits: Vec::new() }
                    }
                    Outcome::Pending(id, ticket) => match ticket.wait() {
                        Ok(r) => ResponseFrame {
                            id,
                            status: Status::Ok,
                            class: r.class as u32,
                            logits: r.logits,
                        },
                        Err(ServeError::DeadlineExceeded { .. }) => ResponseFrame {
                            id,
                            status: Status::DeadlineExceeded,
                            class: 0,
                            logits: Vec::new(),
                        },
                        Err(ServeError::RetriesExhausted { .. }) => ResponseFrame {
                            id,
                            status: Status::RetriesExhausted,
                            class: 0,
                            logits: Vec::new(),
                        },
                        Err(ServeError::WorkerFailed(_))
                        | Err(ServeError::Shutdown)
                        | Err(ServeError::Disconnected) => {
                            ResponseFrame { id, status: Status::Failed, class: 0, logits: Vec::new() }
                        }
                    },
                };
                if proto::write_frame(&mut w, &proto::encode_response(&resp)).is_err() {
                    return; // client gone; remaining tickets drop
                }
                if w.flush().is_err() {
                    return;
                }
            }
        })
        .expect("spawn connection writer");

    // reader half: one frame in, one outcome enqueued
    let mut first = Some(first4);
    {
        let mut r = StopAwareStream { inner: stream, stop };
        loop {
            let payload = match proto::read_frame(&mut r, first.take()) {
                Ok(Some(p)) => p,
                Ok(None) => break, // clean EOF at a frame boundary
                Err(_) => {
                    // framing broken (oversized length, truncation,
                    // shutdown): tell the client if possible, then close
                    stats.malformed.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Outcome::Immediate(0, Status::Malformed));
                    break;
                }
            };
            stats.frames.fetch_add(1, Ordering::Relaxed);
            let req = match proto::decode_request(&payload) {
                Ok(req) => req,
                Err(_) => {
                    // structurally invalid but the framing is intact —
                    // answer Malformed and keep serving the connection
                    stats.malformed.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Outcome::Immediate(0, Status::Malformed));
                    continue;
                }
            };
            let outcome = submit_frame(coord, req, stats);
            if tx.send(outcome).is_err() {
                break; // writer died (client gone)
            }
        }
    }
    drop(tx); // writer drains the queue, then exits
    let _ = writer.join();
}

/// Submit one decoded frame; admission misses become immediate statuses.
fn submit_frame(coord: &FrontEnd, req: RequestFrame, stats: &NetStats) -> Outcome {
    let image: Vec<i32> = req.codes.iter().map(|&c| c as i32).collect();
    let deadline =
        (req.deadline_us > 0).then(|| Duration::from_micros(req.deadline_us as u64));
    match coord.try_submit(image, deadline, req.class) {
        Ok(ticket) => Outcome::Pending(req.id, ticket),
        Err(SubmitError::Rejected) => Outcome::Immediate(req.id, Status::Rejected),
        Err(SubmitError::BadShape { .. }) => {
            stats.malformed.fetch_add(1, Ordering::Relaxed);
            Outcome::Immediate(req.id, Status::Malformed)
        }
        Err(SubmitError::Shutdown) => Outcome::Immediate(req.id, Status::Failed),
    }
}

/// Minimal HTTP/1.1 fallback: `POST /infer` (body = one code byte per
/// activation, optional `X-Deadline-Us` and `X-Request-Class` headers
/// — "latency" or "throughput"), `GET /metrics`, `GET /healthz`. One
/// request per connection (`Connection: close`).
fn handle_http(
    stream: &TcpStream,
    first4: &[u8; 4],
    coord: &Arc<FrontEnd>,
    stop: &Arc<AtomicBool>,
    stats: &Arc<NetStats>,
) {
    const MAX_HEAD: usize = 16 * 1024;
    let mut head = first4.to_vec();
    let mut r = StopAwareStream { inner: stream, stop };
    // read byte-wise until the blank line; requests are tiny and this
    // path is a fallback, not the throughput surface
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() > MAX_HEAD {
            respond_http(stream, 400, "{\"error\":\"header too large\"}");
            return;
        }
        match r.read(&mut byte) {
            Ok(1) => head.push(byte[0]),
            _ => return,
        }
    }
    let head_text = String::from_utf8_lossy(&head);
    let mut lines = head_text.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or("/"));

    let mut content_length = 0usize;
    let mut deadline_us = 0u64;
    let mut class = RequestClass::Latency;
    let mut bad_class = false;
    for line in lines {
        let Some((k, v)) = line.split_once(':') else { continue };
        let v = v.trim();
        match k.to_ascii_lowercase().as_str() {
            "content-length" => content_length = v.parse().unwrap_or(0),
            "x-deadline-us" => deadline_us = v.parse().unwrap_or(0),
            "x-request-class" => match RequestClass::parse(v) {
                Some(c) => class = c,
                None => bad_class = true,
            },
            _ => {}
        }
    }

    match (method, path) {
        ("GET", "/healthz") => respond_http(stream, 200, "ok"),
        ("GET", "/metrics") => {
            let m = coord.metrics();
            let mut body = format!(
                "{m}\nrejected {}\nshed_deadline {}\nfailed {}\n",
                m.rejected, m.shed_deadline, m.failed
            );
            if let Some(fleet) = coord.fleet() {
                body.push_str(&format!("{}\n", fleet.summary()));
            }
            respond_http(stream, 200, &body);
        }
        ("POST", _) => {
            if content_length == 0 || content_length > proto::MAX_FRAME {
                respond_http(stream, 400, "{\"error\":\"bad content-length\"}");
                return;
            }
            if bad_class {
                stats.malformed.fetch_add(1, Ordering::Relaxed);
                respond_http(
                    stream,
                    400,
                    "{\"error\":\"x-request-class must be latency or throughput\"}",
                );
                return;
            }
            let mut body = vec![0u8; content_length];
            if r.read_exact(&mut body).is_err() {
                return;
            }
            let image: Vec<i32> = body.iter().map(|&c| c as i32).collect();
            let deadline = (deadline_us > 0).then(|| Duration::from_micros(deadline_us));
            match coord.try_submit(image, deadline, class) {
                Ok(ticket) => match ticket.wait() {
                    Ok(res) => {
                        let logits: Vec<String> =
                            res.logits.iter().map(|l| format!("{l:?}")).collect();
                        respond_http(
                            stream,
                            200,
                            &format!(
                                "{{\"class\":{},\"logits\":[{}]}}",
                                res.class,
                                logits.join(",")
                            ),
                        );
                    }
                    Err(ServeError::DeadlineExceeded { .. }) => {
                        respond_http(stream, 504, "{\"error\":\"deadline exceeded\"}")
                    }
                    Err(ServeError::RetriesExhausted { .. }) => {
                        respond_http(stream, 503, "{\"error\":\"retry budget exhausted\"}")
                    }
                    Err(_) => respond_http(stream, 500, "{\"error\":\"worker failed\"}"),
                },
                Err(SubmitError::Rejected) => {
                    respond_http(stream, 503, "{\"error\":\"queue full\"}")
                }
                Err(SubmitError::BadShape { got, want }) => {
                    stats.malformed.fetch_add(1, Ordering::Relaxed);
                    respond_http(
                        stream,
                        400,
                        &format!("{{\"error\":\"image has {got} codes, expected {want}\"}}"),
                    );
                }
                Err(SubmitError::Shutdown) => {
                    respond_http(stream, 503, "{\"error\":\"shutting down\"}")
                }
            }
        }
        _ => respond_http(stream, 404, "{\"error\":\"try POST /infer, GET /metrics\"}"),
    }
}

fn respond_http(stream: &TcpStream, code: u16, body: &str) {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    };
    let content_type =
        if body.starts_with('{') { "application/json" } else { "text/plain" };
    let resp = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = (&mut &*stream).write_all(resp.as_bytes());
    let _ = (&mut &*stream).flush();
}
