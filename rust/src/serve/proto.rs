//! LUTMUL wire protocol (DESIGN.md S21): length-prefixed binary frames.
//!
//! Every frame is `[u32 LE payload length][payload]`. Request payload:
//!
//! ```text
//!   u8   version        (PROTO_VERSION)
//!   u64  request id     (LE; echoed verbatim in the response)
//!   u32  deadline_us    (LE; 0 = no deadline, else relative to receipt)
//!   u8   class          (RequestClass: 0 latency, 1 throughput)
//!   u8[] codes          (one activation code per byte, H*W*C of them)
//! ```
//!
//! Response payload:
//!
//! ```text
//!   u8   version
//!   u8   status         (Status as u8)
//!   u64  request id     (LE)
//!   u32  class          (LE; argmax logit, 0 unless status == Ok)
//!   u32  n_logits       (LE; 0 unless status == Ok)
//!   f32[] logits        (LE bit patterns — bit-exact across the wire)
//! ```
//!
//! Codes are one byte each: activations are 4-/8-bit quantization codes
//! by construction (the network's `a_bits <= 8`), so a byte per code is
//! lossless and keeps request frames 4x smaller than raw i32. Logits
//! cross the wire as raw f32 bit patterns, so the loadgen's bit-
//! exactness check compares the very bits the executor produced.
//!
//! The server tells binary traffic from the HTTP fallback by the first
//! four bytes of a connection: `POST`/`GET ` as a u32 length would be
//! > 1 GiB, far beyond [`MAX_FRAME`], so the two framings cannot be
//! confused (see `serve::server`).

use std::io::{self, Read, Write};

use crate::coordinator::RequestClass;

/// Protocol version byte; bumped on any layout change. v2 added the
/// request-class byte after the deadline (DESIGN.md S25 fleet routing).
pub const PROTO_VERSION: u8 = 2;

/// Hard cap on one frame's payload (4 MiB — a full-ImageNet 224x224x3
/// image is ~150 KiB of codes; anything near the cap is hostile or
/// corrupt, not a real request).
pub const MAX_FRAME: usize = 4 << 20;

/// Response status. `Ok` carries logits; everything else is a structured
/// miss whose name matches the serving-tier counter it increments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    /// Inference completed; logits attached.
    Ok = 0,
    /// Shed before compute: the deadline expired in queue.
    DeadlineExceeded = 1,
    /// Bounced at admission: the queue was full (backpressure).
    Rejected = 2,
    /// The frame was structurally invalid (bad version, wrong code
    /// count) — the connection survives; framing errors close it.
    Malformed = 3,
    /// The worker's backend failed mid-batch, or the server is shutting
    /// down with the request in flight.
    Failed = 4,
    /// The fleet drained the request from failed batches until its
    /// retry budget ran out (DESIGN.md S25).
    RetriesExhausted = 5,
}

impl Status {
    pub fn from_u8(v: u8) -> Option<Status> {
        match v {
            0 => Some(Status::Ok),
            1 => Some(Status::DeadlineExceeded),
            2 => Some(Status::Rejected),
            3 => Some(Status::Malformed),
            4 => Some(Status::Failed),
            5 => Some(Status::RetriesExhausted),
            _ => None,
        }
    }
}

/// One decoded request frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestFrame {
    pub id: u64,
    /// Relative deadline in microseconds; 0 = none.
    pub deadline_us: u32,
    /// Which fleet pool serves the request (ignored by single-pool
    /// servers). An unknown class byte is a malformed frame.
    pub class: RequestClass,
    /// One activation code per byte.
    pub codes: Vec<u8>,
}

/// One decoded response frame.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    pub id: u64,
    pub status: Status,
    pub class: u32,
    pub logits: Vec<f32>,
}

/// Fixed request header size (version + id + deadline + class).
const REQ_HEADER: usize = 1 + 8 + 4 + 1;
/// Fixed response header size (version + status + id + class + count).
const RESP_HEADER: usize = 1 + 1 + 8 + 4 + 4;

/// Encode one request as a complete frame (length prefix included).
pub fn encode_request(req: &RequestFrame) -> Vec<u8> {
    let payload_len = REQ_HEADER + req.codes.len();
    let mut buf = Vec::with_capacity(4 + payload_len);
    buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
    buf.push(PROTO_VERSION);
    buf.extend_from_slice(&req.id.to_le_bytes());
    buf.extend_from_slice(&req.deadline_us.to_le_bytes());
    buf.push(req.class as u8);
    buf.extend_from_slice(&req.codes);
    buf
}

/// Decode a request payload (frame body, length prefix already
/// consumed). Errors are descriptive strings — the server answers them
/// with [`Status::Malformed`].
pub fn decode_request(payload: &[u8]) -> Result<RequestFrame, String> {
    if payload.len() < REQ_HEADER {
        return Err(format!(
            "request payload is {} bytes, the header alone is {REQ_HEADER}",
            payload.len()
        ));
    }
    if payload[0] != PROTO_VERSION {
        return Err(format!(
            "protocol version {} not supported (this server speaks {PROTO_VERSION})",
            payload[0]
        ));
    }
    let id = u64::from_le_bytes(payload[1..9].try_into().unwrap());
    let deadline_us = u32::from_le_bytes(payload[9..13].try_into().unwrap());
    let class = RequestClass::from_u8(payload[13])
        .ok_or_else(|| format!("unknown request class byte {}", payload[13]))?;
    Ok(RequestFrame { id, deadline_us, class, codes: payload[REQ_HEADER..].to_vec() })
}

/// Encode one response as a complete frame (length prefix included).
pub fn encode_response(resp: &ResponseFrame) -> Vec<u8> {
    let logits = if resp.status == Status::Ok { resp.logits.as_slice() } else { &[] };
    let payload_len = RESP_HEADER + 4 * logits.len();
    let mut buf = Vec::with_capacity(4 + payload_len);
    buf.extend_from_slice(&(payload_len as u32).to_le_bytes());
    buf.push(PROTO_VERSION);
    buf.push(resp.status as u8);
    buf.extend_from_slice(&resp.id.to_le_bytes());
    buf.extend_from_slice(&resp.class.to_le_bytes());
    buf.extend_from_slice(&(logits.len() as u32).to_le_bytes());
    for l in logits {
        buf.extend_from_slice(&l.to_le_bytes());
    }
    buf
}

/// Decode a response payload (frame body, length prefix already
/// consumed).
pub fn decode_response(payload: &[u8]) -> Result<ResponseFrame, String> {
    if payload.len() < RESP_HEADER {
        return Err(format!(
            "response payload is {} bytes, the header alone is {RESP_HEADER}",
            payload.len()
        ));
    }
    if payload[0] != PROTO_VERSION {
        return Err(format!("protocol version {} not supported", payload[0]));
    }
    let status = Status::from_u8(payload[1])
        .ok_or_else(|| format!("unknown status byte {}", payload[1]))?;
    let id = u64::from_le_bytes(payload[2..10].try_into().unwrap());
    let class = u32::from_le_bytes(payload[10..14].try_into().unwrap());
    let n = u32::from_le_bytes(payload[14..18].try_into().unwrap()) as usize;
    let body = &payload[RESP_HEADER..];
    if body.len() != 4 * n {
        return Err(format!("response claims {n} logits but carries {} bytes", body.len()));
    }
    let logits = body
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(ResponseFrame { id, status, class, logits })
}

/// Read one length-prefixed payload. `first4` is the already-consumed
/// length prefix when the caller peeked it for HTTP detection; `None`
/// reads the prefix from the stream. Returns `Ok(None)` on clean EOF at
/// a frame boundary; an oversized or truncated frame is an error (the
/// stream cannot be resynchronized and must be closed).
pub fn read_frame(
    r: &mut impl Read,
    first4: Option<[u8; 4]>,
) -> io::Result<Option<Vec<u8>>> {
    let len_bytes = match first4 {
        Some(b) => b,
        None => {
            let mut b = [0u8; 4];
            match read_exact_or_eof(r, &mut b)? {
                true => b,
                false => return Ok(None),
            }
        }
    };
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Write one already-encoded frame.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    w.write_all(frame)
}

/// `read_exact` that distinguishes clean EOF before the first byte
/// (`Ok(false)`) from mid-buffer truncation (an error).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(false),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("stream closed {filled} bytes into a {}-byte read", buf.len()),
                ))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let req = RequestFrame {
            id: 0xDEAD_BEEF_0042,
            deadline_us: 1500,
            class: RequestClass::Latency,
            codes: vec![0, 7, 15, 3],
        };
        let wire = encode_request(&req);
        let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
        assert_eq!(decode_request(&payload).unwrap(), req);
    }

    #[test]
    fn request_class_rides_the_wire() {
        for class in RequestClass::ALL {
            let req = RequestFrame { id: 3, deadline_us: 0, class, codes: vec![1, 2] };
            let wire = encode_request(&req);
            let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
            assert_eq!(decode_request(&payload).unwrap().class, class);
        }
        // an unknown class byte is malformed, not silently defaulted
        let mut wire = encode_request(&RequestFrame {
            id: 3,
            deadline_us: 0,
            class: RequestClass::Latency,
            codes: vec![1, 2],
        });
        wire[4 + 13] = 9; // class byte of the payload
        let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
        let err = decode_request(&payload).unwrap_err();
        assert!(err.contains("class byte 9"), "{err}");
    }

    #[test]
    fn response_round_trips_bit_exact() {
        // exotic f32 bit patterns must survive the wire untouched
        let logits = vec![0.0f32, -0.0, 1.5e-39, f32::MAX, -3.25];
        let resp = ResponseFrame { id: 9, status: Status::Ok, class: 4, logits: logits.clone() };
        let wire = encode_response(&resp);
        let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
        let got = decode_response(&payload).unwrap();
        assert_eq!(got.id, 9);
        assert_eq!(got.status, Status::Ok);
        assert_eq!(got.class, 4);
        let want_bits: Vec<u32> = logits.iter().map(|l| l.to_bits()).collect();
        let got_bits: Vec<u32> = got.logits.iter().map(|l| l.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    #[test]
    fn error_statuses_drop_logits() {
        let resp = ResponseFrame {
            id: 1,
            status: Status::Rejected,
            class: 0,
            logits: vec![1.0, 2.0],
        };
        let wire = encode_response(&resp);
        let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
        let got = decode_response(&payload).unwrap();
        assert_eq!(got.status, Status::Rejected);
        assert!(got.logits.is_empty(), "non-Ok responses carry no logits");
    }

    #[test]
    fn bad_version_and_status_are_loud() {
        let mut wire = encode_request(&RequestFrame {
            id: 1,
            deadline_us: 0,
            class: RequestClass::Latency,
            codes: vec![1],
        });
        wire[4] = 99; // version byte of the payload
        let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
        let err = decode_request(&payload).unwrap_err();
        assert!(err.contains("version 99"), "{err}");
        assert!(Status::from_u8(250).is_none());
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        // truncated: claims 100 bytes, carries 2
        let mut wire = vec![];
        wire.extend_from_slice(&100u32.to_le_bytes());
        wire.extend_from_slice(&[1, 2]);
        assert!(read_frame(&mut wire.as_slice(), None).is_err());
        // oversized: the length prefix alone must kill the frame
        let wire = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let err = read_frame(&mut wire.as_slice(), None).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        // short header payload
        let mut wire = vec![];
        wire.extend_from_slice(&3u32.to_le_bytes());
        wire.extend_from_slice(&[1, 2, 3]);
        let payload = read_frame(&mut wire.as_slice(), None).unwrap().unwrap();
        assert!(decode_request(&payload).unwrap_err().contains("header"));
    }

    #[test]
    fn clean_eof_is_none() {
        let empty: &[u8] = &[];
        assert!(read_frame(&mut &*empty, None).unwrap().is_none());
    }

    #[test]
    fn http_prefixes_exceed_frame_cap() {
        // the disambiguation invariant the server relies on: an HTTP
        // method read as a length prefix can never be a legal frame
        for prefix in [*b"POST", *b"GET ", *b"HEAD", *b"PUT "] {
            assert!(u32::from_le_bytes(prefix) as usize > MAX_FRAME);
        }
    }
}
