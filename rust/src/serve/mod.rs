//! Network-facing serving tier (DESIGN.md S21).
//!
//! Puts a TCP front end over the [`Coordinator`]'s batch-forming
//! window so remote clients and in-process submitters share one
//! admission path, one batcher, and one metrics surface:
//!
//! * [`proto`] — the length-prefixed binary wire protocol (and the
//!   invariant that lets an HTTP/1.1 request share the same port);
//! * [`server`] — acceptor + per-connection reader/writer threads,
//!   deadline propagation, and admission-control status mapping.
//!
//! Everything here is `std`-only: `TcpListener`, OS threads, and
//! channels — no async runtime, matching the repo's no-new-deps rule.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator

pub mod proto;
pub mod server;

pub use proto::{RequestFrame, ResponseFrame, Status, MAX_FRAME, PROTO_VERSION};
pub use server::{NetStats, Server, ServerConfig};
