//! Network-facing serving tier (DESIGN.md S21/S25).
//!
//! Puts a TCP front end over the [`Coordinator`]'s batch-forming
//! window — or the class-routed [`Fleet`]'s pools — so remote clients
//! and in-process submitters share one admission path, one batcher,
//! and one metrics surface:
//!
//! * [`proto`] — the length-prefixed binary wire protocol, v2 carrying
//!   a per-request [`RequestClass`] byte (and the invariant that lets
//!   an HTTP/1.1 request share the same port);
//! * [`server`] — acceptor + per-connection reader/writer threads,
//!   deadline + class propagation, and admission-control status
//!   mapping, generic over the single-pool coordinator and the fleet.
//!
//! Everything here is `std`-only: `TcpListener`, OS threads, and
//! channels — no async runtime, matching the repo's no-new-deps rule.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator
//! [`Fleet`]: crate::coordinator::Fleet
//! [`RequestClass`]: crate::coordinator::RequestClass

pub mod proto;
pub mod server;

pub use proto::{RequestFrame, ResponseFrame, Status, MAX_FRAME, PROTO_VERSION};
pub use server::{NetStats, Server, ServerConfig};
