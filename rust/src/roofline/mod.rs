//! Roofline model (DESIGN.md S9): Eq. (1) and (2) of the paper, the
//! Table 1 device comparison, and the Figure 1 LUTMUL-vs-DSP analysis.


use crate::fabric::cost;
use crate::fabric::device::{FpgaDevice, FpgaSlice};

/// DSP packing factor `p` by operand bit-width (paper section 2.1):
/// p=1 for 16-bit, p=2 for 8-bit, p=4 for 4-bit MACs.
pub fn dsp_packing_factor(bits: u32) -> f64 {
    match bits {
        0..=4 => 4.0,
        5..=8 => 2.0,
        _ => 1.0,
    }
}

/// Eq. (1): `Peak performance = p x PEs x 2 x f` (ops/s).
pub fn peak_performance(p: f64, pes: f64, freq_hz: f64) -> f64 {
    p * pes * 2.0 * freq_hz
}

/// DSP-based peak for a resource slice at a bit-width (ops/s).
pub fn dsp_peak(slice: &FpgaSlice, bits: u32, freq_hz: f64) -> f64 {
    peak_performance(dsp_packing_factor(bits), slice.dsps as f64, freq_hz)
}

/// LUTMUL peak for a resource slice (ops/s): the number of parallel
/// LUT-mapped MACs the LUT budget sustains. Each MAC costs Eq. (3) ROM
/// LUTs plus its amortized share of the adder tree (calibrated factors
/// from `fabric::cost`), so a 4-bit MAC lands at ~5.8 LUTs all-in.
pub fn lutmul_peak(slice: &FpgaSlice, bits: u32, freq_hz: f64) -> f64 {
    let per_mac = lutmul_luts_per_mac(bits);
    let macs = slice.luts as f64 / per_mac;
    peak_performance(1.0, macs, freq_hz)
}

/// All-in LUT cost of one LUTMUL MAC: ROM (Eq. 3 x implementation factor)
/// + amortized adder-tree share (one adder per product, Vivado-shrunk).
pub fn lutmul_luts_per_mac(bits: u32) -> f64 {
    let rom = cost::luts_per_mult(bits) * cost::VIVADO_ROM_FACTOR;
    // one tree node per term, width ~ accumulator width of a 64-term sum
    let adder = cost::luts_per_adder(cost::accumulator_width(2 * bits, 64))
        * cost::VIVADO_ADDER_SHRINK;
    rom + adder
}

/// LUTMUL peak for a structurally pruned network (DESIGN.md S23):
/// pruning keeps only `density` of the MACs, so the *effective*
/// throughput per model pass rises by `1/density` — the pruned model's
/// dense-equivalent ops fit in proportionally fewer LUT-mapped MACs, or
/// equivalently the reclaimed LUT budget hosts more parallel live MACs.
/// `density` is live work over dense work (`ConvPlan::macs()` summed /
/// `dense_macs()` summed), clamped away from zero.
pub fn lutmul_peak_pruned(slice: &FpgaSlice, bits: u32, freq_hz: f64, density: f64) -> f64 {
    lutmul_peak(slice, bits, freq_hz) / density.clamp(1e-6, 1.0)
}

/// LUTMUL peak for a Maddness-style approximate datapath (DESIGN.md
/// S24): codebook hashing replaces the `cols` per-pixel MACs of a layer
/// with `n_codebooks` table accumulations, so each *effective* dense op
/// costs only `mac_fraction = n_codebooks / cols` of an exact LUT MAC.
/// The dense-equivalent peak therefore rises by `1 / mac_fraction`
/// (`NetworkPlan` reports the plan-wide fraction as approx MACs over
/// dense MACs), clamped away from zero like the pruned roof.
pub fn lutmul_peak_approx(slice: &FpgaSlice, bits: u32, freq_hz: f64, mac_fraction: f64) -> f64 {
    lutmul_peak(slice, bits, freq_hz) / mac_fraction.clamp(1e-6, 1.0)
}

/// Eq. (2)-style memory roof: attainable ops/s at arithmetic intensity
/// `ai` (ops/byte) with bandwidth `bw` (bytes/s).
pub fn memory_roof(bw_bytes_per_s: f64, ai: f64) -> f64 {
    bw_bytes_per_s * ai
}

/// One point of a roofline: attainable performance at an intensity.
pub fn attainable(peak_ops: f64, bw_bytes_per_s: f64, ai: f64) -> f64 {
    peak_ops.min(memory_roof(bw_bytes_per_s, ai))
}

/// The crossover intensity (ridge point) where compute becomes the bound.
pub fn ridge_point(peak_ops: f64, bw_bytes_per_s: f64) -> f64 {
    peak_ops / bw_bytes_per_s
}

/// A full roofline curve for Figure 1.
#[derive(Debug, Clone)]
pub struct RooflineCurve {
    pub label: String,
    pub peak_gops: f64,
    pub ridge_ops_per_byte: f64,
    /// (arithmetic intensity, attainable GOPS) samples.
    pub points: Vec<(f64, f64)>,
}

/// Figure 1: roofline for 1/64 of U280 (resources and HBM bandwidth),
/// comparing LUTMUL against DSP-based architectures at several bit-widths.
pub fn figure1_curves(device: &FpgaDevice, denom: u64) -> Vec<RooflineCurve> {
    let slice = device.fraction(denom);
    let f = device.max_freq_mhz * 1e6;
    let bw = slice.bw_gbps * 1e9;
    let intensities: Vec<f64> = (0..=28).map(|i| 2f64.powf(i as f64 * 0.5 - 4.0)).collect();
    let mut curves = Vec::new();
    let mk = |label: String, peak: f64| RooflineCurve {
        label,
        peak_gops: peak / 1e9,
        ridge_ops_per_byte: ridge_point(peak, bw),
        points: intensities
            .iter()
            .map(|&ai| (ai, attainable(peak, bw, ai) / 1e9))
            .collect(),
    };
    curves.push(mk("LUTMUL W4A4".into(), lutmul_peak(&slice, 4, f)));
    for bits in [4u32, 8, 16] {
        curves.push(mk(format!("DSP W{bits}A{bits}"), dsp_peak(&slice, bits, f)));
    }
    curves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::U280;

    #[test]
    fn packing_factors_match_paper() {
        assert_eq!(dsp_packing_factor(16), 1.0);
        assert_eq!(dsp_packing_factor(8), 2.0);
        assert_eq!(dsp_packing_factor(4), 4.0);
    }

    #[test]
    fn eq1_units() {
        // 100 PEs, p=2, 300 MHz -> 120 GOPS
        assert_eq!(peak_performance(2.0, 100.0, 300e6), 1.2e11);
    }

    #[test]
    fn lutmul_beats_dsp_peak_on_u280_slice() {
        // The headline claim: at equal resources, LUT-mapped MACs exceed
        // the DSP-bound peak for 4-bit ops.
        let slice = U280.fraction(64);
        let f = 333e6;
        let lut = lutmul_peak(&slice, 4, f);
        let dsp = dsp_peak(&slice, 4, f);
        assert!(
            lut > dsp,
            "LUTMUL {:.1} GOPS must exceed DSP {:.1} GOPS",
            lut / 1e9,
            dsp / 1e9
        );
        // and by a sane factor (the paper's Figure 1 shows ~2-4x)
        let ratio = lut / dsp;
        assert!(ratio > 1.5 && ratio < 8.0, "ratio {ratio}");
    }

    #[test]
    fn memory_bound_region() {
        // at tiny intensity the roof is the bandwidth line
        let slice = U280.fraction(64);
        let f = 333e6;
        let peak = lutmul_peak(&slice, 4, f);
        let bw = slice.bw_gbps * 1e9;
        let low = attainable(peak, bw, 0.1);
        assert!((low - bw * 0.1).abs() < 1e-6 * bw);
        let high = attainable(peak, bw, 1e6);
        assert_eq!(high, peak);
    }

    #[test]
    fn ridge_point_monotone_in_peak() {
        let bw = 7.2e9;
        assert!(ridge_point(2e12, bw) > ridge_point(1e12, bw));
    }

    #[test]
    fn figure1_has_lutmul_on_top() {
        let curves = figure1_curves(&U280, 64);
        assert_eq!(curves.len(), 4);
        let lut_peak = curves[0].peak_gops;
        for c in &curves[1..] {
            assert!(lut_peak > c.peak_gops, "{} >= LUTMUL", c.label);
        }
        // every curve saturates at its own peak
        for c in &curves {
            let max = c.points.iter().map(|p| p.1).fold(0.0, f64::max);
            assert!((max - c.peak_gops).abs() / c.peak_gops < 1e-9);
        }
    }

    #[test]
    fn pruned_peak_scales_inverse_with_density() {
        let slice = U280.fraction(64);
        let f = 333e6;
        let dense = lutmul_peak(&slice, 4, f);
        assert_eq!(lutmul_peak_pruned(&slice, 4, f, 1.0), dense);
        let half = lutmul_peak_pruned(&slice, 4, f, 0.5);
        assert!((half - 2.0 * dense).abs() < 1e-6 * dense, "50% density doubles the peak");
        // degenerate densities stay finite and never fall below dense
        assert!(lutmul_peak_pruned(&slice, 4, f, 0.0).is_finite());
        assert!(lutmul_peak_pruned(&slice, 4, f, 2.0) >= dense);
    }

    #[test]
    fn approx_peak_scales_inverse_with_mac_fraction() {
        let slice = U280.fraction(64);
        let f = 333e6;
        let dense = lutmul_peak(&slice, 4, f);
        assert_eq!(lutmul_peak_approx(&slice, 4, f, 1.0), dense);
        // default chunking (4 cols per codebook) quarters the per-pixel work
        let quarter = lutmul_peak_approx(&slice, 4, f, 0.25);
        assert!((quarter - 4.0 * dense).abs() < 1e-6 * dense, "4x at 1/4 MACs");
        assert!(lutmul_peak_approx(&slice, 4, f, 0.0).is_finite());
        assert!(lutmul_peak_approx(&slice, 4, f, 2.0) >= dense);
    }

    #[test]
    fn luts_per_mac_all_in_cost() {
        let c = lutmul_luts_per_mac(4);
        assert!(c > 3.0 && c < 10.0, "4-bit MAC all-in {c} LUTs");
    }
}
