//! `lutmul` CLI — leader entrypoint for the LUTMUL reproduction.
//!
//! Subcommands map onto the experiment index of DESIGN.md and are thin
//! flag-parsing shims over the engine (DESIGN.md S19): every run
//! surface is constructed through `Engine::builder()` and driven
//! through the uniform `InferenceBackend` contract.
//!
//!   * `verify`   — run the test set through the dataflow simulator and
//!     check bit-exactness against the PJRT golden model + accuracy.
//!   * `serve`    — start the serving coordinator and push a synthetic
//!     request load through it, reporting latency/throughput; with
//!     `--listen` it exposes the coordinator on a TCP socket (binary
//!     protocol + HTTP fallback, DESIGN.md S21).
//!   * `loadgen`  — open-loop bursty multi-tenant load generator against
//!     a running server (or a self-hosted one), printing a throughput /
//!     tail-latency table; `--smoke` gates the result for CI
//!     (EXPERIMENTS.md E14).
//!   * `bench`    — run every available backend on the same inputs and
//!     print a bit-exactness + throughput comparison (EXPERIMENTS.md
//!     E12).
//!   * `synth`    — synthesize an architecture on a device and print the
//!     design report (resources, FPS, GOPS, power).
//!   * `report`   — print Table 1 / Figure 1 / Figure 2 / Figure 6 /
//!     Table 2 reproductions.
//!
//! (Hand-rolled arg parsing: the offline vendored crate set has no clap.
//! Malformed flag values and unknown flags are hard errors.)

use anyhow::Result;

use lutmul::coordinator::{Coordinator, FleetConfig, PoolScale, RequestClass, ServeConfig};
use lutmul::dataflow::FoldConfig;
use lutmul::engine::{Arch, BackendKind, Engine, ExecutorBackend, Folding, InferenceBackend};
use lutmul::loadgen::{self, LoadgenConfig};
use lutmul::serve::{Server, ServerConfig};
use lutmul::fabric::device::U280;
use lutmul::graph::plan::{Datapath, NetworkPlan};
use lutmul::graph::{mobilenet_v2_full, mobilenet_v2_small};
use lutmul::runtime::Artifacts;
use lutmul::synth::fold::{optimize_folding, Budget};
use lutmul::synth::synthesize;

const USAGE: &str = "\
lutmul — LUTMUL accelerator generator & runtime

USAGE:
  lutmul [--artifacts DIR] <command> [options]

COMMANDS:
  verify [--n N] [--lut-fabric]      simulate the test set; verify vs PJRT
  serve  [--requests N] [--workers N] [--max-batch N] [--devices N]
         [--listen ADDR] [--duration-ms MS]
         [--fleet [--min-workers N] [--max-workers N]]
         in-process load by default; --listen ADDR (e.g. 127.0.0.1:7700,
         port 0 = ephemeral) serves the length-prefixed binary protocol
         with an HTTP/1.1 fallback (POST /infer, GET /metrics) instead,
         for --duration-ms (0 = until killed). --listen --fleet serves
         the class-routed heterogeneous fleet (DESIGN.md S25): latency-
         class requests (wire class byte 0 / X-Request-Class: latency)
         hit executor replicas, throughput-class sharded chains, each
         pool autoscaled between --min-workers and --max-workers
  loadgen [--addr HOST:PORT] [--tenants N] [--rate RPS] [--duration-ms MS]
         [--deadline-us US] [--seed S] [--workers N] [--max-batch N]
         [--class-mix F] [--smoke] [--fleet-smoke]
         open-loop bursty multi-tenant traffic against --addr (or a
         self-hosted server when absent) printing a throughput /
         tail-latency table; --class-mix F marks fraction F of requests
         throughput-class; --smoke runs calibrated steady/burst/shed
         phases and fails on lost requests, reordering, missing deadline
         sheds, or a blown p99 (EXPERIMENTS.md E14); --fleet-smoke
         self-hosts the heterogeneous fleet, kills a shard chain
         mid-phase, and fails unless every request resolves, ordering
         holds, both classes complete, and the chain rebuilds
         (EXPERIMENTS.md E18)
  bench  [--backends all|LIST] [--n N] [--devices N] [--json] [--sparsity S]
         run every available engine backend (executor, pipeline, sharded
         chains, PJRT when loadable) on the same inputs and print a
         bit-exactness + throughput comparison; LIST is comma-joined
         reference|pipeline|sharded|pjrt. --json emits one machine-
         readable {backend, datapath, images_per_s, ns_per_image,
         bit_exact} row per backend on stdout (human table moves to
         stderr) — `make bench-json` writes it to BENCH_kernels.json.
         --sparsity S adds a structurally pruned compile at channel
         sparsity S plus its masked-dense witness (rows carry a
         \"sparsity\" field in the JSON)
  eval   [--n N] [--seed S] [--sparsity S] [--pareto] [--json] [--floor F]
         [--saturated]
         score every datapath's top-1/top-5 on a labeled test set (the
         trained artifact set when built, a labeled synthetic set
         otherwise — seeded images labeled by the exact datapath's own
         argmax, so exact rows score 100% by construction) next to
         throughput and LUT area. --pareto adds the mac-major witness
         and the saturated-approx anchor; --sparsity S adds a pruned row
         (its top-1 delta is the pruning accuracy cost); --saturated
         evaluates the saturated (bit-exact) approx config; --floor F
         fails unless the approx row's top-1 >= F (`make eval-smoke`);
         --json emits the Pareto front with the bench --json schema
         (rows carry top1/top5/lut6, approx rows \"approx\": true)
  synth  [--arch full|small] [--fraction D]
  util   [--arch full|small]          Vivado-style utilization report
  netlist [--layer NAME]              structural Verilog for a trained layer
  multi  [--devices N] [--run [--n N]]
         analytic multi-FPGA plan; --run executes the sharded chain on the
         small network (trained artifacts when built, its synthetic twin
         otherwise) and prints measured-vs-modeled FPS
  report <table1|fig1|fig2|fig6|table2|multi|prune|approx|fleet>
         prune [--sparsity S] [--fold F] [--n N]: per-layer LUT-area and
         cycle savings of a structurally pruned compile, with the
         simulated pruned pipeline cross-checked against the analytic
         steady-state FPS and the masked-dense executor (DESIGN.md S23)
         approx [--cols C] [--depth D] [--n N]: per-layer LUT-area and
         accumulation savings of a Maddness-approximate compile, with
         the saturated config cross-checked bit-exact against the exact
         executor (DESIGN.md S24; accuracy lives in `lutmul eval`)
         fleet [--requests N] [--devices N]: drive the heterogeneous
         fleet through mixed-class serving, a chaos kill + rebuild, a
         burst-driven scale-up and the idle drain back to the floor,
         gating each invariant (DESIGN.md S25, `make fleet-smoke`)

Malformed flag values and unknown flags are hard errors.
";

/// Minimal flag parser: `--key value` and bare flags.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if takes_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    /// Parse `--key`'s value, defaulting when the flag is absent. A
    /// malformed value is a hard error, not a silent default (`--workers
    /// abc` must not quietly serve with 2 workers).
    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("invalid value '{v}' for --{key}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Reject flags the subcommand does not understand — a typo'd flag
    /// must not silently fall back to the default behaviour.
    fn check_flags(&self, cmd: &str, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            anyhow::ensure!(
                allowed.contains(&k.as_str()),
                "unknown flag --{k} for '{cmd}' (allowed: {})",
                allowed
                    .iter()
                    .map(|a| format!("--{a}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
        Ok(())
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let artifacts = Artifacts::new(args.get::<String>("artifacts", "artifacts".into())?);
    match args.positional.first().map(String::as_str) {
        Some("verify") => {
            args.check_flags("verify", &["artifacts", "n", "lut-fabric"])?;
            verify(&artifacts, args.get("n", 64usize)?, args.has("lut-fabric"))
        }
        Some("serve") => {
            args.check_flags(
                "serve",
                &[
                    "artifacts", "requests", "workers", "max-batch", "devices", "listen",
                    "duration-ms", "fleet", "min-workers", "max-workers",
                ],
            )?;
            if args.has("fleet") {
                anyhow::ensure!(
                    args.has("listen"),
                    "--fleet needs --listen (for in-process fleet load use `lutmul report fleet`)"
                );
                serve_listen_fleet(
                    &artifacts,
                    &args.get::<String>("listen", "127.0.0.1:0".into())?,
                    args.get("min-workers", 1usize)?,
                    args.get("max-workers", 4usize)?,
                    args.get("devices", 2usize)?,
                    args.get("duration-ms", 0u64)?,
                )
            } else if args.has("listen") {
                serve_listen(
                    &artifacts,
                    &args.get::<String>("listen", "127.0.0.1:0".into())?,
                    args.get("workers", 2usize)?,
                    args.get("max-batch", 8usize)?,
                    args.get("devices", 0usize)?,
                    args.get("duration-ms", 0u64)?,
                )
            } else {
                serve(
                    &artifacts,
                    args.get("requests", 512usize)?,
                    args.get("workers", 2usize)?,
                    args.get("max-batch", 8usize)?,
                    args.get("devices", 0usize)?,
                )
            }
        }
        Some("loadgen") => {
            args.check_flags(
                "loadgen",
                &[
                    "artifacts", "addr", "tenants", "rate", "duration-ms", "deadline-us",
                    "seed", "workers", "max-batch", "class-mix", "smoke", "fleet-smoke",
                ],
            )?;
            loadgen_cmd(&artifacts, &args)
        }
        Some("bench") => {
            args.check_flags(
                "bench",
                &["artifacts", "backends", "n", "devices", "json", "sparsity"],
            )?;
            bench_backends(
                &artifacts,
                &args.get::<String>("backends", "all".into())?,
                args.get("n", 8usize)?,
                args.get("devices", 2usize)?,
                args.has("json"),
                args.get("sparsity", 0.0f64)?,
            )
        }
        Some("eval") => {
            args.check_flags(
                "eval",
                &["artifacts", "n", "seed", "sparsity", "pareto", "json", "floor", "saturated"],
            )?;
            eval_cmd(&artifacts, &args)
        }
        Some("synth") => {
            args.check_flags("synth", &["artifacts", "arch", "fraction"])?;
            synth(&args.get::<String>("arch", "full".into())?, args.get("fraction", 1u64)?)
        }
        Some("util") => {
            args.check_flags("util", &["artifacts", "arch"])?;
            util(&args.get::<String>("arch", "full".into())?)
        }
        Some("netlist") => {
            args.check_flags("netlist", &["artifacts", "layer"])?;
            netlist(&artifacts, &args.get::<String>("layer", "ir0_exp".into())?)
        }
        Some("multi") => {
            args.check_flags("multi", &["artifacts", "devices", "run", "n"])?;
            if args.has("run") {
                multi_run(&artifacts, args.get("devices", 2usize)?, args.get("n", 12usize)?)
            } else {
                multi(args.get("devices", 2usize)?)
            }
        }
        Some("report") => {
            args.check_flags(
                "report",
                &["artifacts", "sparsity", "fold", "n", "cols", "depth", "requests", "devices"],
            )?;
            let what = args.positional.get(1).cloned().unwrap_or_default();
            report(&artifacts, &what, &args)
        }
        Some(other) => {
            print!("{USAGE}");
            anyhow::bail!("unknown command '{other}'")
        }
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn verify(artifacts: &Artifacts, n: usize, lut_fabric: bool) -> Result<()> {
    // trained artifacts only (no synthetic fallback): accuracy against
    // labels is the point of this subcommand
    let mut engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .backend(BackendKind::Pipeline)
        .build()
        .map_err(|e| e.context("verify needs the trained artifacts (run `make artifacts`)"))?;
    let (images, labels) = engine.labeled_test_set()?;
    let n = if n == 0 { images.len() } else { n.min(images.len()) };
    println!("loaded network ({} ops) + {n} test images", engine.net().ops.len());

    // dataflow simulator through the uniform backend contract
    let t0 = std::time::Instant::now();
    let out = engine.infer_batch(&images[..n])?;
    let sim_elapsed = t0.elapsed();
    let correct = out
        .logits
        .iter()
        .zip(&labels[..n])
        .filter(|(l, &y)| lutmul::coordinator::argmax(l) == y as usize)
        .count();
    let steady = engine
        .backend()
        .steady_cycles()
        .unwrap_or(out.cycles / n.max(1) as u64);
    println!(
        "simulator: {n} images in {:.2?} | {} cycles | steady-state {steady} cycles/img | {:.0} FPS @333MHz | acc {:.2}%",
        sim_elapsed,
        out.cycles,
        333.0e6 / steady.max(1) as f64,
        100.0 * correct as f64 / n as f64,
    );

    // PJRT golden model cross-check (batch-1 artifact); the runtime is
    // just another InferenceBackend over the same plan geometry
    match engine.make_backend(BackendKind::Pjrt { batch: 1 }) {
        Ok(mut rt) => {
            let check = n.min(16);
            let mut mismatches = 0;
            for i in 0..check {
                let golden = rt.infer_batch(std::slice::from_ref(&images[i]))?;
                if golden.logits[0] != out.logits[i] {
                    mismatches += 1;
                }
            }
            println!("PJRT golden cross-check: {}/{check} bit-exact", check - mismatches);
            anyhow::ensure!(mismatches == 0, "simulator diverged from the golden model");
        }
        // real PJRT bindings present: a load failure is a broken artifact
        Err(e) if cfg!(feature = "xla") => return Err(e),
        // stub runtime (no `xla` feature): the simulator/executor checks
        // still run, only the HLO leg is skipped
        Err(e) => println!("PJRT golden cross-check skipped ({e})"),
    }

    if lut_fabric {
        // a second engine compiles the same network for the LUT6-fabric
        // datapath; its executor must agree bit-for-bit
        let mut lf = Engine::builder()
            .arch(Arch::Small)
            .artifacts(artifacts)
            .datapath(Datapath::LutFabric)
            .backend(BackendKind::Reference)
            .build()?;
        let m = n.min(8);
        let got = lf.infer_batch(&images[..m])?;
        let ok = got.logits[..] == out.logits[..m];
        println!("LUT6-fabric datapath: {}/{m} bit-exact", if ok { m } else { 0 });
        anyhow::ensure!(ok, "LUT fabric datapath diverged");
    }
    Ok(())
}

fn serve(
    artifacts: &Artifacts,
    requests: usize,
    workers: usize,
    max_batch: usize,
    devices: usize,
) -> Result<()> {
    // --devices N > 0 serves from the sharded chain backend (DESIGN.md
    // S18); the default stays the whole-network reference executor
    let kind = if devices > 0 {
        BackendKind::Sharded { devices }
    } else {
        BackendKind::Reference
    };
    let engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .backend(kind)
        .build()?;
    let (images, _) = engine.labeled_test_set()?;
    let coord =
        Coordinator::start(&engine, ServeConfig { workers, max_batch, ..Default::default() })?;
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    for i in 0..requests {
        let img = images[i % images.len()].clone();
        match coord.submit(img) {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }
    let mut ok = 0usize;
    for t in tickets {
        if t.wait().is_ok() {
            ok += 1;
        }
    }
    println!(
        "served {ok}/{requests} requests ({rejected} rejected) in {:.2?} | {}",
        t0.elapsed(),
        coord.metrics()
    );
    coord.shutdown();
    Ok(())
}

/// `lutmul serve --listen ADDR`: expose the coordinator on a TCP socket
/// (DESIGN.md S21) — length-prefixed binary protocol with an HTTP/1.1
/// fallback on the same port — for `--duration-ms` (0 = until killed).
fn serve_listen(
    artifacts: &Artifacts,
    listen: &str,
    workers: usize,
    max_batch: usize,
    devices: usize,
    duration_ms: u64,
) -> Result<()> {
    let kind = if devices > 0 {
        BackendKind::Sharded { devices }
    } else {
        BackendKind::Reference
    };
    // trained artifacts when built, the synthetic twin otherwise — a
    // network endpoint must come up either way
    let engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .or_synthetic(0x5EED)
        .backend(kind)
        .build()?;
    let io = engine.io();
    let server = Server::start(
        &engine,
        ServeConfig { workers, max_batch, ..Default::default() },
        ServerConfig { addr: listen.to_string(), ..Default::default() },
    )?;
    println!(
        "lutmul serve: listening on {} | {} | image {}x{}x{} codes ({} bytes/request) | {workers} workers, max batch {max_batch}",
        server.local_addr(),
        engine.source().label(),
        io.image_size,
        io.image_size,
        io.in_ch,
        io.image_size * io.image_size * io.in_ch,
    );
    if duration_ms == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_millis(duration_ms));
    let m = server.metrics();
    let stats = server.stats();
    println!(
        "{m} | conns {} (refused {}) | frames {} | http {} | malformed {}",
        stats.connections.load(std::sync::atomic::Ordering::Relaxed),
        stats.refused_conns.load(std::sync::atomic::Ordering::Relaxed),
        stats.frames.load(std::sync::atomic::Ordering::Relaxed),
        stats.http_requests.load(std::sync::atomic::Ordering::Relaxed),
        stats.malformed.load(std::sync::atomic::Ordering::Relaxed),
    );
    server.shutdown();
    Ok(())
}

/// `lutmul serve --listen ADDR --fleet`: expose the class-routed
/// heterogeneous fleet (DESIGN.md S25) on a TCP socket. Latency-class
/// requests (wire class byte 0 / `X-Request-Class: latency`) serve from
/// executor replicas, throughput-class from `--devices`-way sharded
/// chains; each pool autoscales between `--min-workers` and
/// `--max-workers`.
fn serve_listen_fleet(
    artifacts: &Artifacts,
    listen: &str,
    min_workers: usize,
    max_workers: usize,
    devices: usize,
    duration_ms: u64,
) -> Result<()> {
    let engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .or_synthetic(0x5EED)
        .backend(BackendKind::Reference)
        .build()?;
    let io = engine.io();
    let scale = PoolScale { min_workers, max_workers: max_workers.max(min_workers) };
    let fleet_cfg = FleetConfig { latency: scale, throughput: scale, ..Default::default() };
    let server = Server::start_fleet(
        &engine,
        devices.max(2),
        fleet_cfg,
        ServerConfig { addr: listen.to_string(), ..Default::default() },
    )?;
    println!(
        "lutmul serve --fleet: listening on {} | {} | image {}x{}x{} codes | \
         latency = executor replicas, throughput = sharded x{} chains | \
         {min_workers}..{} workers/pool",
        server.local_addr(),
        engine.source().label(),
        io.image_size,
        io.image_size,
        io.in_ch,
        devices.max(2),
        max_workers.max(min_workers),
    );
    if duration_ms == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_millis(duration_ms));
    println!("{}", server.metrics());
    if let Some(summary) = server.fleet_summary() {
        println!("{summary}");
    }
    server.shutdown();
    Ok(())
}

/// `lutmul loadgen`: open-loop bursty multi-tenant traffic (EXPERIMENTS.md
/// E14). Self-hosts a server on an ephemeral port unless `--addr` points
/// at a running one; `--smoke` runs calibrated steady/burst/shed phases
/// and gates the invariants CI cares about.
fn loadgen_cmd(artifacts: &Artifacts, args: &Args) -> Result<()> {
    use std::time::Duration;

    // local engine: serves as the self-hosted backend, and fixes the
    // image geometry (a remote --addr server must serve the same arch)
    let mut engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .or_synthetic(0x5EED)
        .backend(BackendKind::Reference)
        .build()?;
    let io = engine.io();
    let image_px = io.image_size * io.image_size * io.in_ch;

    let workers = args.get("workers", 2usize)?;
    let max_batch = args.get("max-batch", 8usize)?;
    let deadline_us = args.get("deadline-us", 0u64)?;
    let class_mix = args.get("class-mix", 0.0f64)?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&class_mix),
        "--class-mix must be in [0, 1], got {class_mix}"
    );
    let cfg = LoadgenConfig {
        tenants: args.get("tenants", 4usize)?,
        rate_rps: args.get("rate", 400.0f64)?,
        duration: Duration::from_millis(args.get("duration-ms", 1000u64)?),
        deadline: (deadline_us > 0).then(|| Duration::from_micros(deadline_us)),
        class_mix,
        seed: args.get("seed", 0x10ADu64)?,
        ..Default::default()
    };

    if args.has("fleet-smoke") {
        anyhow::ensure!(
            !args.has("addr"),
            "--fleet-smoke self-hosts its fleet server; drop --addr"
        );
        return loadgen_fleet_smoke(&mut engine, image_px, max_batch, &cfg);
    }

    // target: remote --addr, or a self-hosted ephemeral server
    let (addr, hosted) = match args.flags.get("addr") {
        Some(a) => {
            let addr = a
                .parse()
                .map_err(|e| anyhow::anyhow!("invalid --addr '{a}': {e}"))?;
            (addr, None)
        }
        None => {
            let server = Server::start(
                &engine,
                ServeConfig { workers, max_batch, ..Default::default() },
                ServerConfig::default(),
            )?;
            println!("loadgen: self-hosted server on {}", server.local_addr());
            (server.local_addr(), Some(server))
        }
    };

    if args.has("smoke") {
        // calibrate the offered rate to what the backend can actually
        // sustain, so the gate passes on slow CI machines and still
        // exercises the batcher on fast ones (the local engine's own
        // backend is idle — the server's workers built their own)
        let probe = engine.images(max_batch.max(1))?;
        let t0 = std::time::Instant::now();
        engine.infer_batch(&probe)?;
        let direct_ips = probe.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        // half of one worker's direct throughput, kept inside what
        // sleep-paced senders can offer
        let rate = (direct_ips * 0.5).clamp(50.0, 2000.0);
        println!("loadgen --smoke: direct {direct_ips:.0} img/s -> offering {rate:.0} rps");

        let steady = loadgen::run(
            addr,
            image_px,
            &LoadgenConfig { rate_rps: rate, burst_mult: 1.0, ..cfg.clone() },
        )?;
        let burst = loadgen::run(
            addr,
            image_px,
            &LoadgenConfig {
                rate_rps: rate,
                burst_mult: 4.0,
                seed: cfg.seed ^ 1,
                ..cfg.clone()
            },
        )?;
        // 1 us relative deadlines are expired by the time the batch
        // window dispatches, so the shed path must fire
        let shed = loadgen::run(
            addr,
            image_px,
            &LoadgenConfig {
                rate_rps: rate,
                burst_mult: 1.0,
                duration: Duration::from_millis(300),
                deadline: Some(Duration::from_micros(1)),
                seed: cfg.seed ^ 2,
                ..cfg.clone()
            },
        )?;
        print!(
            "{}",
            loadgen::table(&[("steady", &steady), ("burst", &burst), ("shed", &shed)])
        );

        // the gates: every request accounted, ordering intact, the
        // deadline path sheds, throughput sustained, tail bounded
        for (name, r) in [("steady", &steady), ("burst", &burst), ("shed", &shed)] {
            anyhow::ensure!(r.accounted(), "{name}: requests unaccounted for ({r:?})");
            anyhow::ensure!(r.order_violations == 0, "{name}: responses reordered");
            anyhow::ensure!(r.lost == 0, "{name}: {} requests lost", r.lost);
        }
        anyhow::ensure!(steady.ok > 0 && burst.ok > 0, "no request completed");
        anyhow::ensure!(
            steady.ok as f64 >= 0.5 * steady.offered as f64,
            "steady goodput collapsed: {}/{} ok",
            steady.ok,
            steady.offered
        );
        anyhow::ensure!(
            steady.latency_p99_us() < 2_000_000,
            "steady p99 {} us blew the 2 s bound",
            steady.latency_p99_us()
        );
        anyhow::ensure!(
            shed.deadline_exceeded > 0,
            "1 us deadlines were never shed (shed path dead)"
        );
        if let Some(server) = &hosted {
            let m = server.metrics();
            anyhow::ensure!(
                m.shed_deadline > 0,
                "server metrics never counted a deadline shed"
            );
            println!("server metrics: {m}");
        }
        println!("loadgen --smoke: OK");
    } else {
        let report = loadgen::run(addr, image_px, &cfg)?;
        print!("{}", loadgen::table(&[("total", &report)]));
        if let Some(server) = &hosted {
            println!("server metrics: {}", server.metrics());
        }
    }

    if let Some(server) = hosted {
        server.shutdown();
    }
    Ok(())
}

/// `lutmul loadgen --fleet-smoke` (EXPERIMENTS.md E18): self-host the
/// heterogeneous fleet, push a mixed-class bursty phase through the
/// real socket, kill a shard chain mid-phase, and gate the elastic
/// serving invariants — every request accounted, responses in order,
/// zero lost, zero failed (the retry budget absorbs the kill), both
/// classes completing, and the chain rebuilt.
fn loadgen_fleet_smoke(
    engine: &mut Engine,
    image_px: usize,
    max_batch: usize,
    cfg: &LoadgenConfig,
) -> Result<()> {
    use std::time::Duration;

    // responsive elasticity: the phase is short, so the supervisor ticks
    // tight and the retire threshold is tens of ms, not seconds
    let fleet_cfg = FleetConfig {
        latency: PoolScale { min_workers: 1, max_workers: 3 },
        throughput: PoolScale { min_workers: 1, max_workers: 2 },
        max_batch,
        scale_tick: Duration::from_millis(2),
        high_water: 4,
        up_ticks: 2,
        idle_ticks: 25,
        rebuild_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let server = Server::start_fleet(engine, 2, fleet_cfg, ServerConfig::default())?;
    println!("loadgen --fleet-smoke: self-hosted fleet server on {}", server.local_addr());

    // calibrate the offered rate to the backend (same discipline as
    // --smoke), and default to a 30% throughput-class mix unless the
    // user picked one
    let probe = engine.images(max_batch.max(1))?;
    let t0 = std::time::Instant::now();
    engine.infer_batch(&probe)?;
    let direct_ips = probe.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let rate = (direct_ips * 0.5).clamp(50.0, 2000.0);
    let mix = if cfg.class_mix > 0.0 { cfg.class_mix } else { 0.3 };
    println!(
        "loadgen --fleet-smoke: direct {direct_ips:.0} img/s -> offering {rate:.0} rps \
         ({:.0}% throughput-class)",
        100.0 * mix
    );

    // arm the chaos kill before opening the tap: it fires on the first
    // throughput batch dispatched mid-phase, draining the in-flight
    // requests back into the queue and rebuilding the chain under load
    anyhow::ensure!(
        server.chaos_kill(RequestClass::Throughput),
        "the fleet server refused the chaos kill"
    );
    let mixed = loadgen::run(
        server.local_addr(),
        image_px,
        &LoadgenConfig { rate_rps: rate, burst_mult: 4.0, class_mix: mix, ..cfg.clone() },
    )?;
    print!("{}", loadgen::table(&[("mixed", &mixed)]));
    let summary = server.fleet_summary().expect("fleet front end");
    println!("{summary}");

    anyhow::ensure!(mixed.accounted(), "requests unaccounted for ({mixed:?})");
    anyhow::ensure!(mixed.order_violations == 0, "responses reordered");
    anyhow::ensure!(mixed.lost == 0, "{} requests lost", mixed.lost);
    anyhow::ensure!(
        mixed.failed == 0,
        "{} requests failed (the retry budget should absorb the kill)",
        mixed.failed
    );
    anyhow::ensure!(
        mixed.class_ok[RequestClass::Latency.index()] > 0
            && mixed.class_ok[RequestClass::Throughput.index()] > 0,
        "both classes must complete (latency {}, throughput {})",
        mixed.class_ok[RequestClass::Latency.index()],
        mixed.class_ok[RequestClass::Throughput.index()],
    );
    anyhow::ensure!(summary.rebuilds() >= 1, "the killed shard chain never rebuilt");
    server.shutdown();
    println!("loadgen --fleet-smoke: OK");
    Ok(())
}

/// `lutmul bench --backends all` (EXPERIMENTS.md E12): run every
/// available backend on the same inputs through the uniform
/// `InferenceBackend` contract and print a bit-exactness + throughput
/// comparison table. Exits nonzero when any executed backend diverges
/// from the reference executor, so CI gates on it (`make engine-smoke`).
///
/// With `--json` the human table moves to stderr and stdout carries one
/// JSON document with a `{backend, datapath, images_per_s, ns_per_image,
/// bit_exact}` row per executed backend — `make bench-json` overwrites
/// `BENCH_kernels.json` with it, and the trajectory is the sequence of
/// committed versions of that file (EXPERIMENTS.md E13). The document is
/// emitted even when a backend diverged: its row then carries
/// `bit_exact: false`, so a broken run can never masquerade as a
/// plausible trajectory point.
fn bench_backends(
    artifacts: &Artifacts,
    which: &str,
    n: usize,
    devices: usize,
    json: bool,
    sparsity: f64,
) -> Result<()> {
    anyhow::ensure!(
        (0.0..1.0).contains(&sparsity),
        "--sparsity must be in [0, 1), got {sparsity}"
    );
    // human-readable lines: stdout normally, stderr under --json so the
    // JSON document is the only thing on stdout
    macro_rules! say {
        ($($t:tt)*) => {
            if json { eprintln!($($t)*) } else { println!($($t)*) }
        };
    }
    let mut engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .or_synthetic(0x5EED)
        .backend(BackendKind::Reference)
        .build()?;
    let n = n.max(1);
    let images = engine.images(n)?;
    let io = engine.io();
    say!(
        "backend comparison: {} | {n} images ({}x{}x{} codes)",
        engine.source().label(),
        io.image_size,
        io.image_size,
        io.in_ch
    );

    // machine-readable rows: (backend, datapath, img/s, bit-exact,
    // sparsity — 0.0 for the dense rows, S for the pruned pair)
    let mut rows: Vec<(String, String, f64, bool, f64)> = Vec::new();

    // the reference logits every other backend must reproduce
    let t0 = std::time::Instant::now();
    let reference = engine.infer_batch(&images)?;
    let ref_ips = n as f64 / t0.elapsed().as_secs_f64();
    say!("  {:<22} {ref_ips:>9.0} img/s | reference", engine.backend_name());
    rows.push((engine.backend_name().to_string(), "arithmetic".into(), ref_ips, true, 0.0));

    // the user's device count is used as given — out of range is a hard
    // error, not a silent clamp (same contract as the flag parser), but
    // only when a sharded backend actually consumes the flag
    let sharded = |devices: usize| -> Result<BackendKind> {
        anyhow::ensure!(devices >= 1, "--devices must be at least 1, got {devices}");
        Ok(BackendKind::Sharded { devices })
    };
    let kinds: Vec<BackendKind> = match which {
        "all" => vec![
            BackendKind::Pipeline,
            sharded(devices)?,
            sharded(devices + 1)?,
            BackendKind::Pjrt { batch: 1 },
        ],
        list => list
            .split(',')
            .map(|s| match s.trim() {
                "reference" => Ok(BackendKind::Reference),
                "pipeline" => Ok(BackendKind::Pipeline),
                "sharded" => sharded(devices),
                "pjrt" => Ok(BackendKind::Pjrt { batch: 1 }),
                other => Err(anyhow::anyhow!(
                    "unknown backend '{other}' for --backends (try all, or a comma list of \
                     reference|pipeline|sharded|pjrt)"
                )),
            })
            .collect::<Result<Vec<_>>>()?,
    };

    // one row per backend: time it, compare against the reference
    // logits, account divergence — shared by the kind loop and the
    // cross-datapath witnesses below so the format cannot drift
    let mut diverged = 0usize;
    let mut compared = 0usize;
    let mut ran = 0usize; // requested backends that executed at all
    // `display` overrides the backend's own name when several backends
    // share one (the three LUT-fabric executors would otherwise print
    // three indistinguishable "executor/lut-fabric" rows)
    let mut row =
        |b: &mut dyn InferenceBackend, datapath: &str, display: Option<&str>| -> Result<()> {
            let t0 = std::time::Instant::now();
            let out = b.infer_batch(&images)?;
            let ips = n as f64 / t0.elapsed().as_secs_f64();
            let exact = out.logits == reference.logits;
            compared += 1;
            if !exact {
                diverged += 1;
            }
            let cycles = if out.cycles > 0 {
                format!(" | {} sim cycles", out.cycles)
            } else {
                String::new()
            };
            let shown = display.unwrap_or(b.name());
            say!(
                "  {shown:<22} {ips:>9.0} img/s | {}{cycles}",
                if exact { format!("bit-exact {n}/{n}") } else { "DIVERGED".into() },
            );
            rows.push((shown.to_string(), datapath.to_string(), ips, exact, 0.0));
            Ok(())
        };

    for kind in kinds {
        // the reference executor is already the baseline row; a second
        // copy would compare trivially against itself and count as a
        // hollow pass toward the `compared` guard below
        if kind == BackendKind::Reference {
            ran += 1; // explicitly requested, and the baseline did run
            continue;
        }
        let datapath = match kind {
            BackendKind::Pjrt { .. } => "hlo",
            _ => "arithmetic",
        };
        match engine.make_backend(kind) {
            Ok(mut b) => {
                row(b.as_mut(), datapath, None)?;
                ran += 1;
            }
            // an unavailable backend (PJRT without the `xla` feature or
            // without artifacts) is reported, not silently dropped
            Err(e) => say!("  {:<22} unavailable ({e})", kind.label()),
        }
    }

    if which == "all" {
        // cross-datapath witness: the same network compiled for the
        // LUT6-fabric datapath must agree bit-for-bit too
        let mut lf = Engine::builder()
            .arch(Arch::Small)
            .artifacts(artifacts)
            .or_synthetic(0x5EED)
            .datapath(Datapath::LutFabric)
            .backend(BackendKind::Reference)
            .build()?;
        row(lf.backend(), "lut-fabric", None)?;
        ran += 1;

        // kernel-layout witnesses (DESIGN.md S20 perf trajectory): the
        // same LUT-fabric network with the MAC-major table layout and
        // the per-MAC LUT6_2 readout — both must stay bit-identical,
        // and their rows chart the activation-major speedup over time
        let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        for (datapath, display, plan) in [
            (
                "lut-fabric/mac-major",
                "executor/lut-mac-major",
                NetworkPlan::compile_mac_major(lf.net(), Datapath::LutFabric),
            ),
            (
                "lut-fabric/direct",
                "executor/lut-direct",
                NetworkPlan::compile_direct(lf.net(), Datapath::LutFabric),
            ),
        ] {
            let mut b = ExecutorBackend::new(std::sync::Arc::new(plan), threads);
            row(&mut b, datapath, Some(display))?;
            ran += 1;
        }

        // batch-driver witness (DESIGN.md S22): the same act-major plan
        // through the image-major per-image driver — the baseline row
        // the batch-major sweep's speedup is charted against
        // (EXPERIMENTS.md E15); the plain "lut-fabric" row above runs
        // batch-major
        let mut b = ExecutorBackend::image_major(
            std::sync::Arc::new(NetworkPlan::compile(lf.net(), Datapath::LutFabric)),
            threads,
        );
        row(&mut b, "lut-fabric/image-major", Some("executor/lut-image-major"))?;
        ran += 1;
    }

    // structurally pruned pair (DESIGN.md S23 / EXPERIMENTS.md E16): the
    // pruned compile's logits are compared against a DENSE compile of the
    // same network with the mask zeroed into its weights — not the
    // unpruned reference, whose logits legitimately differ once channels
    // are dropped. Both rows carry the sparsity so the regression
    // tracker keys them apart from the dense trajectory.
    if sparsity > 0.0 {
        use lutmul::graph::PruneSpec;
        let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let spec = PruneSpec::channels(sparsity);
        let masked_plan =
            NetworkPlan::compile(&spec.masked_network(engine.net()), Datapath::LutFabric);
        let pruned_plan = NetworkPlan::compile_pruned(engine.net(), Datapath::LutFabric, &spec);
        let density = pruned_plan.convs().map(|c| c.macs()).sum::<u64>() as f64
            / pruned_plan.convs().map(|c| c.dense_macs()).sum::<u64>().max(1) as f64;

        let mut mb = ExecutorBackend::new(std::sync::Arc::new(masked_plan), threads);
        let t0 = std::time::Instant::now();
        let masked_out = mb.infer_batch(&images)?;
        let masked_ips = n as f64 / t0.elapsed().as_secs_f64();
        say!(
            "  {:<22} {masked_ips:>9.0} img/s | masked-dense witness (sparsity {sparsity:.2})",
            "executor/lut-masked"
        );
        rows.push(("executor/lut-masked".into(), "lut-fabric".into(), masked_ips, true, sparsity));

        let mut pb = ExecutorBackend::new(std::sync::Arc::new(pruned_plan), threads);
        let t0 = std::time::Instant::now();
        let pruned_out = pb.infer_batch(&images)?;
        let pruned_ips = n as f64 / t0.elapsed().as_secs_f64();
        let exact = pruned_out.logits == masked_out.logits;
        compared += 1;
        if !exact {
            diverged += 1;
        }
        say!(
            "  {:<22} {pruned_ips:>9.0} img/s | {} | {:.2}x vs masked-dense at density {density:.3}",
            "executor/lut-sparse",
            if exact { format!("bit-exact {n}/{n} vs masked-dense") } else { "DIVERGED".into() },
            pruned_ips / masked_ips.max(1e-9),
        );
        rows.push(("executor/lut-sparse".into(), "lut-fabric".into(), pruned_ips, exact, sparsity));
        ran += 1;
    }

    if json {
        let body: Vec<String> = rows
            .iter()
            .map(|(backend, datapath, ips, exact, sp)| {
                // dense rows omit the field so historical BENCH_kernels
                // baselines keep matching key-for-key
                let sparse = if *sp > 0.0 { format!(", \"sparsity\": {sp:.2}") } else { String::new() };
                format!(
                    "    {{\"backend\": {backend:?}, \"datapath\": {datapath:?}, \
                     \"images_per_s\": {ips:.1}, \"ns_per_image\": {:.0}, \
                     \"bit_exact\": {exact}{sparse}}}",
                    1e9 / ips.max(1e-9)
                )
            })
            .collect();
        println!(
            "{{\n  \"bench\": \"lutmul bench --backends {which} --n {n} --json\",\n  \
             \"source\": {:?},\n  \"n_images\": {n},\n  \"rows\": [\n{}\n  ]\n}}",
            engine.source().label(),
            body.join(",\n")
        );
    }

    anyhow::ensure!(
        diverged == 0,
        "{diverged} backend(s) diverged from the reference executor"
    );
    anyhow::ensure!(ran > 0, "none of the requested backends could run");
    if compared > 0 {
        say!("OK: {compared} backend(s) bit-exact vs the reference executor");
    } else {
        // e.g. `--backends reference`: the baseline ran and is healthy,
        // but nothing was compared — say so instead of claiming a
        // comparison that never happened
        say!("OK: reference executor only (no comparison backends ran)");
    }
    Ok(())
}

/// `lutmul eval` (EXPERIMENTS.md E17): the accuracy half of the
/// Maddness trade. Scores every datapath's top-1/top-5 on a labeled
/// test set next to measured throughput and the plan's LUT6 estimate —
/// the accuracy–speed–area Pareto front `lutmul report approx`'s area
/// story is incomplete without. Labels come from the trained artifact
/// test set when built; otherwise from `Network::synthetic_labeled`
/// (seeded images labeled by the exact datapath's own argmax), so the
/// exact rows score 100% by construction and every other row reads as
/// agreement with the exact model. `--floor F` turns the approx row's
/// top-1 into a CI gate (`make eval-smoke`).
fn eval_cmd(artifacts: &Artifacts, args: &Args) -> Result<()> {
    use lutmul::eval;
    use lutmul::graph::ApproxSpec;

    let json = args.has("json");
    macro_rules! say {
        ($($t:tt)*) => {
            if json { eprintln!($($t)*) } else { println!($($t)*) }
        };
    }
    let engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .or_synthetic(0x5EED)
        .backend(BackendKind::Reference)
        .build()?;
    let n = args.get("n", 32usize)?.max(1);
    let seed = args.get("seed", 0xE7A1u64)?;
    let sparsity = args.get("sparsity", 0.0f64)?;
    let floor = args.get("floor", -1.0f64)?;
    let spec =
        if args.has("saturated") { ApproxSpec::saturated() } else { ApproxSpec::default() };

    // labeled inputs: the artifact test set for a trained network, the
    // exact-datapath-labeled synthetic set otherwise
    let (images, labels, label_src) = match engine.labeled_test_set() {
        Ok((imgs, labs)) => {
            let n = n.min(imgs.len());
            (imgs[..n].to_vec(), labs[..n].to_vec(), "artifact test set")
        }
        Err(_) => {
            let (imgs, labs) = engine.net().synthetic_labeled(n, seed);
            (imgs, labs, "synthetic, exact-datapath argmax")
        }
    };
    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let cfg = eval::ParetoConfig { sparsity, spec, full: args.has("pareto"), threads };
    say!(
        "eval: {} | {} images (labels: {label_src}) | approx {} col(s)/codebook depth {}",
        engine.source().label(),
        images.len(),
        spec.cols_per_codebook,
        spec.depth,
    );
    let rows = eval::pareto(engine.net(), &images, &labels, &cfg)?;
    if json {
        eprint!("{}", eval::table(&rows));
        let invocation = format!(
            "lutmul eval --n {n}{}{} --json",
            if cfg.full { " --pareto" } else { "" },
            if sparsity > 0.0 { format!(" --sparsity {sparsity}") } else { String::new() },
        );
        println!(
            "{}",
            eval::json(&rows, &invocation, engine.source().label(), images.len())
        );
    } else {
        print!("{}", eval::table(&rows));
    }

    // accuracy deltas vs the exact row — the numbers the trade is about
    let exact = rows
        .iter()
        .find(|r| r.backend == "executor/lut-exact")
        .expect("pareto always emits the exact row");
    let approx_row = rows
        .iter()
        .find(|r| r.approx)
        .expect("pareto always emits the approx row");
    say!(
        "approx top-1 delta vs exact: {:+.1} pts ({:.1}% -> {:.1}%) at {:.2}x LUT area",
        100.0 * (approx_row.score.top1 - exact.score.top1),
        100.0 * exact.score.top1,
        100.0 * approx_row.score.top1,
        approx_row.lut6 as f64 / exact.lut6.max(1) as f64,
    );
    if let Some(pruned) = rows.iter().find(|r| r.sparsity > 0.0) {
        say!(
            "pruned top-1 delta vs exact (sparsity {:.2}): {:+.1} pts ({:.1}% -> {:.1}%)",
            pruned.sparsity,
            100.0 * (pruned.score.top1 - exact.score.top1),
            100.0 * exact.score.top1,
            100.0 * pruned.score.top1,
        );
    }
    if floor >= 0.0 {
        anyhow::ensure!(
            approx_row.score.top1 >= floor,
            "approx top-1 {:.4} fell below the --floor {floor:.4} gate",
            approx_row.score.top1
        );
        say!("approx top-1 {:.4} >= floor {floor:.4}: OK", approx_row.score.top1);
    }
    Ok(())
}

fn synth(arch: &str, fraction: u64) -> Result<()> {
    let spec = match arch {
        "small" => mobilenet_v2_small(),
        "full" => mobilenet_v2_full(),
        other => anyhow::bail!("unknown --arch '{other}' (try full|small)"),
    };
    let budget =
        if fraction <= 1 { Budget::whole(&U280) } else { Budget::fraction(&U280, fraction) };
    let (folds, cycles) = optimize_folding(&spec, &budget);
    let d = synthesize(&spec, &U280, &folds);
    println!("design: {} on {} (budget 1/{fraction})", d.arch_name, d.device);
    println!(
        "  LUT {} | FF {} | BRAM36 {} | DSP {} | {:.0} MHz",
        d.luts, d.ffs, d.bram36, d.dsps, d.freq_mhz
    );
    println!(
        "  {} cycles/img (target {cycles}) | {:.0} FPS | {:.1} GOPS | {:.1} W | {:.2} GOPS/W",
        d.cycles_per_image,
        d.fps(),
        d.gops(),
        d.power_w,
        d.gops_per_watt()
    );
    println!("  per-stage (name mode fold II luts slr):");
    for s in &d.stages {
        println!(
            "    {:12} {:?} fold={} II={} luts={:.0} slr={}",
            s.name, s.mode, s.fold, s.ii, s.luts, s.slr
        );
    }
    Ok(())
}

fn util(arch: &str) -> Result<()> {
    let spec = match arch {
        "small" => mobilenet_v2_small(),
        "full" => mobilenet_v2_full(),
        other => anyhow::bail!("unknown --arch '{other}' (try full|small)"),
    };
    let (folds, _) = optimize_folding(&spec, &Budget::whole(&U280));
    let d = synthesize(&spec, &U280, &folds);
    print!("{}", lutmul::synth::utilization_report(&d, &U280));
    Ok(())
}

fn netlist(artifacts: &Artifacts, layer: &str) -> Result<()> {
    let net = lutmul::graph::network::Network::load(artifacts.network_json())?;
    for op in net.ops.iter() {
        if let lutmul::graph::network::Op::Conv { name, w_codes, w_bits, .. } = op {
            if name == layer {
                anyhow::ensure!(*w_bits <= 4, "netlist emission needs <= 4-bit weights");
                print!("{}", lutmul::fabric::netlist::emit_layer(name, w_codes, *w_bits));
                return Ok(());
            }
        }
    }
    anyhow::bail!("layer '{layer}' not found (try ir0_exp, ir1_dw, head, ...)")
}

fn multi(devices: usize) -> Result<()> {
    use lutmul::dataflow::multi::{partition, LinkModel};
    let arch = mobilenet_v2_full();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    let plan = partition(&arch, &U280, devices, &folds, LinkModel::gbe100());
    println!("multi-FPGA plan: {} x {} over 100 GbE", devices, U280.name);
    for (i, p) in plan.partitions.iter().enumerate() {
        println!(
            "  dev{i}: layers {:>2}..{:>2} | {:>9.0} LUT | bound {:>6} cycles | egress {:>7} B/img",
            p.first_layer, p.last_layer, p.luts, p.bound_cycles, p.egress_bytes
        );
    }
    println!(
        "  -> {:.0} FPS steady-state ({}-bound), +{:.1} us pipeline latency",
        plan.fps(),
        if plan.is_link_bound() { "link" } else { "compute" },
        plan.added_latency_s() * 1e6
    );
    Ok(())
}

/// `multi --run`: execute the analytic partition as a sharded chain on
/// real inputs and check the simulation against the analytic model
/// (EXPERIMENTS.md E11). The engine owns the load-or-synthetic network
/// fallback, the fold/budget optimization and the plan compile; the
/// analytic `multi::partition` overlay drives where the chain is cut.
fn multi_run(artifacts: &Artifacts, devices: usize, n: usize) -> Result<()> {
    use lutmul::dataflow::multi::{partition, LinkModel};
    use lutmul::dataflow::ShardChain;

    // optimize folding ONCE at the arch level; the same vector drives
    // the analytic partition and (truncated to the plan's convs) the
    // engine's executed pipeline/chain, so the two legs of the
    // measured-vs-analytic check cannot drift apart
    let arch = mobilenet_v2_small();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    let mut engine = Engine::builder()
        .arch(Arch::Small)
        .artifacts(artifacts)
        .or_synthetic(0x5EED)
        .folding(Folding::Explicit(FoldConfig { folds: folds.clone() }))
        .backend(BackendKind::Pipeline)
        .build()?;
    let n = n.max(1);
    let images = engine.images(n)?;

    let mplan = partition(&arch, &U280, devices, &folds, LinkModel::gbe100());
    let shards = mplan.to_shards(engine.plan())?;
    let a_bits = engine.net().meta.a_bits.max(1);
    println!(
        "sharded chain: {} device(s) over 100 GbE | {} | {} images",
        shards.len(),
        engine.source().label(),
        n
    );
    for (i, s) in shards.iter().enumerate() {
        println!(
            "  dev{i}: ops {:>2}..{:>2} | {:>2} convs | in {:>4} px x {:>3} ch | egress {:>6} B/img",
            s.start,
            s.end,
            s.plan.n_convs(),
            s.in_pixels,
            s.in_ch,
            if s.is_tail() { 0 } else { s.egress_bytes(a_bits) }
        );
    }

    // single-device reference run (the engine's pipeline backend, same
    // optimized folds): the chain must be bit-exact with it
    let want = engine.infer_batch(&images)?;
    let mut chain = ShardChain::new(
        &shards,
        engine.folds(),
        16,
        &LinkModel::gbe100(),
        U280.max_freq_mhz,
        a_bits,
    )?;
    let got = chain.run(&images)?;
    anyhow::ensure!(
        got.logits == want.logits,
        "sharded chain diverged from the single-device pipeline"
    );
    println!("  bit-exact vs single-device pipeline: {n}/{n} images");

    for (i, l) in got.links.iter().enumerate() {
        println!(
            "  link{i}: {:>6} tokens | {:>3} cycles/token | latency {} cycles | stalled {} cycles",
            l.tokens, l.cycles_per_token, l.latency_cycles, l.stalled_cycles
        );
    }
    let f = U280.max_freq_mhz;
    let measured = got.measured_steady_fps(f);
    let modeled = mplan.fps();
    println!(
        "  measured {:.0} FPS steady-state (interval {} cycles) vs modeled {:.0} FPS ({}-bound) | ratio {:.3}",
        measured,
        got.incremental_cycles_per_image(),
        modeled,
        if mplan.is_link_bound() { "link" } else { "compute" },
        measured / modeled
    );
    // the steady-state comparison needs a warm chain (a couple of images
    // in flight) and a compute-bound plan to be meaningful
    if !mplan.is_link_bound() && n >= 4 {
        anyhow::ensure!(
            (measured / modeled - 1.0).abs() <= 0.15,
            "measured FPS {measured:.0} deviates more than 15% from the analytic {modeled:.0}"
        );
        println!("  within 15% of the analytic model: OK");
    }
    Ok(())
}

fn report(artifacts: &Artifacts, what: &str, args: &Args) -> Result<()> {
    match what {
        "table1" => lutmul::reports::table1(),
        "fig1" => lutmul::reports::fig1(),
        "fig2" => lutmul::reports::fig2(&artifacts.fig2_json()),
        "fig6" => lutmul::reports::fig6(),
        "table2" => lutmul::reports::table2(),
        "multi" => lutmul::reports::multi_scaling(),
        "prune" => {
            return lutmul::reports::prune(
                args.get("sparsity", 0.5f64)?,
                args.get("fold", 8usize)?,
                args.get("n", 6usize)?,
            )
        }
        "approx" => {
            return lutmul::reports::approx(
                args.get("cols", 4usize)?,
                args.get("depth", 4usize)?,
                args.get("n", 6usize)?,
            )
        }
        "fleet" => {
            return lutmul::reports::fleet(
                args.get("requests", 160usize)?,
                args.get("devices", 2usize)?,
            )
        }
        other => {
            anyhow::bail!(
                "unknown report '{other}'; try table1|fig1|fig2|fig6|table2|multi|prune|approx|fleet"
            )
        }
    }
    Ok(())
}
