//! `lutmul` CLI — leader entrypoint for the LUTMUL reproduction.
//!
//! Subcommands map onto the experiment index of DESIGN.md:
//!   * `verify`   — run the test set through the dataflow simulator and
//!     check bit-exactness against the PJRT golden model + accuracy.
//!   * `serve`    — start the serving coordinator and push a synthetic
//!     request load through it, reporting latency/throughput.
//!   * `synth`    — synthesize an architecture on a device and print the
//!     design report (resources, FPS, GOPS, power).
//!   * `report`   — print Table 1 / Figure 1 / Figure 2 / Figure 6 /
//!     Table 2 reproductions.
//!
//! (Hand-rolled arg parsing: the offline vendored crate set has no clap.)

use anyhow::Result;
use std::sync::Arc;

use lutmul::coordinator::{Backend, Coordinator, ServeConfig};
use lutmul::dataflow::{FoldConfig, Pipeline};
use lutmul::fabric::device::U280;
use lutmul::graph::network::Network;
use lutmul::graph::{mobilenet_v2_full, mobilenet_v2_small};
use lutmul::runtime::{Artifacts, Runtime};
use lutmul::synth::fold::{optimize_folding, Budget};
use lutmul::synth::synthesize;

const USAGE: &str = "\
lutmul — LUTMUL accelerator generator & runtime

USAGE:
  lutmul [--artifacts DIR] <command> [options]

COMMANDS:
  verify [--n N] [--lut-fabric]      simulate the test set; verify vs PJRT
  serve  [--requests N] [--workers N] [--max-batch N] [--devices N]
  synth  [--arch full|small] [--fraction D]
  util   [--arch full|small]          Vivado-style utilization report
  netlist [--layer NAME]              structural Verilog for a trained layer
  multi  [--devices N] [--run [--n N]]
         analytic multi-FPGA plan; --run executes the sharded chain on the
         small network (trained artifacts when built, its synthetic twin
         otherwise) and prints measured-vs-modeled FPS
  report <table1|fig1|fig2|fig6|table2|multi>
";

/// Minimal flag parser: `--key value` and bare flags.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = i + 1 < argv.len() && !argv[i + 1].starts_with("--");
                if takes_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Self { positional, flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let artifacts = Artifacts::new(args.get::<String>("artifacts", "artifacts".into()));
    match args.positional.first().map(String::as_str) {
        Some("verify") => verify(&artifacts, args.get("n", 64usize), args.has("lut-fabric")),
        Some("serve") => serve(
            &artifacts,
            args.get("requests", 512usize),
            args.get("workers", 2usize),
            args.get("max-batch", 8usize),
            args.get("devices", 0usize),
        ),
        Some("synth") => synth(&args.get::<String>("arch", "full".into()), args.get("fraction", 1u64)),
        Some("util") => util(&args.get::<String>("arch", "full".into())),
        Some("netlist") => netlist(&artifacts, &args.get::<String>("layer", "ir0_exp".into())),
        Some("multi") => {
            if args.has("run") {
                multi_run(&artifacts, args.get("devices", 2usize), args.get("n", 12usize))
            } else {
                multi(args.get("devices", 2usize))
            }
        }
        Some("report") => {
            let what = args.positional.get(1).cloned().unwrap_or_default();
            report(&artifacts, &what)
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn load_network(artifacts: &Artifacts) -> Result<Network> {
    Network::load(artifacts.network_json())
}

fn verify(artifacts: &Artifacts, n: usize, lut_fabric: bool) -> Result<()> {
    let net = load_network(artifacts)?;
    let io = net.io();
    let (images, labels) = artifacts.load_test_set_for(&io)?;
    let n = if n == 0 { images.len() } else { n.min(images.len()) };
    println!("loaded network ({} ops) + {} test images", net.ops.len(), n);

    // dataflow simulator
    let folds = FoldConfig::fully_parallel(net.convs().count());
    let mut pipe = Pipeline::build(&net, &folds, 16);
    let t0 = std::time::Instant::now();
    let report = pipe.run(&images[..n])?;
    let sim_elapsed = t0.elapsed();
    let correct = report
        .logits
        .iter()
        .zip(&labels[..n])
        .filter(|(l, &y)| lutmul::coordinator::argmax(l) == y as usize)
        .count();
    println!(
        "simulator: {n} images in {:.2?} | {} cycles | steady-state {} cycles/img | {:.0} FPS @333MHz | acc {:.2}%",
        sim_elapsed,
        report.cycles,
        report.steady_state_cycles_per_image,
        report.steady_state_fps(333.0),
        100.0 * correct as f64 / n as f64,
    );

    // PJRT golden model cross-check (batch 1 artifact); the runtime
    // shares the executor/simulator geometry via the plan-level IoGeom
    match Runtime::load_for(artifacts.model_hlo(1), 1, &io) {
        Ok(rt) => {
            let mut mismatches = 0;
            let check = n.min(16);
            for i in 0..check {
                let golden = rt.run(&images[i])?;
                if golden[0] != report.logits[i] {
                    mismatches += 1;
                }
            }
            println!("PJRT golden cross-check: {}/{check} bit-exact", check - mismatches);
            anyhow::ensure!(mismatches == 0, "simulator diverged from the golden model");
        }
        // stub runtime (no `xla` feature): the simulator/executor checks
        // below still run, only the HLO leg is skipped
        #[cfg(not(feature = "xla"))]
        Err(e) => println!("PJRT golden cross-check skipped ({e})"),
        // real PJRT bindings present: a load failure is a broken artifact
        #[cfg(feature = "xla")]
        Err(e) => return Err(e),
    }

    if lut_fabric {
        use lutmul::graph::executor::{Datapath, Executor, Tensor};
        let ex = Executor::new(&net, Datapath::LutFabric);
        let m = n.min(8);
        let ok = (0..m).all(|i| {
            let t = Tensor::from_hwc(io.image_size, io.image_size, io.in_ch, images[i].clone());
            ex.execute(&t) == report.logits[i]
        });
        println!("LUT6-fabric datapath: {}/{m} bit-exact", if ok { m } else { 0 });
        anyhow::ensure!(ok, "LUT fabric datapath diverged");
    }
    Ok(())
}

fn serve(
    artifacts: &Artifacts,
    requests: usize,
    workers: usize,
    max_batch: usize,
    devices: usize,
) -> Result<()> {
    let net = Arc::new(load_network(artifacts)?);
    let (images, _) = artifacts.load_test_set_for(&net.io())?;
    // --devices N > 0 serves from the sharded chain backend (DESIGN.md
    // S18); the default stays the whole-network reference executor
    let backend =
        if devices > 0 { Backend::Sharded { devices } } else { Backend::Reference };
    let coord = Coordinator::start(
        net,
        ServeConfig { backend, workers, max_batch, ..Default::default() },
    );
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(requests);
    let mut rejected = 0usize;
    for i in 0..requests {
        let img = images[i % images.len()].clone();
        match coord.submit(img) {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }
    let mut ok = 0usize;
    for t in tickets {
        if t.wait().is_ok() {
            ok += 1;
        }
    }
    println!(
        "served {ok}/{requests} requests ({rejected} rejected) in {:.2?} | {}",
        t0.elapsed(),
        coord.metrics()
    );
    coord.shutdown();
    Ok(())
}

fn synth(arch: &str, fraction: u64) -> Result<()> {
    let spec = match arch {
        "small" => mobilenet_v2_small(),
        _ => mobilenet_v2_full(),
    };
    let budget =
        if fraction <= 1 { Budget::whole(&U280) } else { Budget::fraction(&U280, fraction) };
    let (folds, cycles) = optimize_folding(&spec, &budget);
    let d = synthesize(&spec, &U280, &folds);
    println!("design: {} on {} (budget 1/{fraction})", d.arch_name, d.device);
    println!(
        "  LUT {} | FF {} | BRAM36 {} | DSP {} | {:.0} MHz",
        d.luts, d.ffs, d.bram36, d.dsps, d.freq_mhz
    );
    println!(
        "  {} cycles/img (target {cycles}) | {:.0} FPS | {:.1} GOPS | {:.1} W | {:.2} GOPS/W",
        d.cycles_per_image,
        d.fps(),
        d.gops(),
        d.power_w,
        d.gops_per_watt()
    );
    println!("  per-stage (name mode fold II luts slr):");
    for s in &d.stages {
        println!(
            "    {:12} {:?} fold={} II={} luts={:.0} slr={}",
            s.name, s.mode, s.fold, s.ii, s.luts, s.slr
        );
    }
    Ok(())
}

fn util(arch: &str) -> Result<()> {
    let spec = match arch {
        "small" => mobilenet_v2_small(),
        _ => mobilenet_v2_full(),
    };
    let (folds, _) = optimize_folding(&spec, &Budget::whole(&U280));
    let d = synthesize(&spec, &U280, &folds);
    print!("{}", lutmul::synth::utilization_report(&d, &U280));
    Ok(())
}

fn netlist(artifacts: &Artifacts, layer: &str) -> Result<()> {
    let net = load_network(artifacts)?;
    for op in net.ops.iter() {
        if let lutmul::graph::network::Op::Conv { name, w_codes, w_bits, .. } = op {
            if name == layer {
                anyhow::ensure!(*w_bits <= 4, "netlist emission needs <= 4-bit weights");
                print!("{}", lutmul::fabric::netlist::emit_layer(name, w_codes, *w_bits));
                return Ok(());
            }
        }
    }
    anyhow::bail!("layer '{layer}' not found (try ir0_exp, ir1_dw, head, ...)")
}

fn multi(devices: usize) -> Result<()> {
    use lutmul::dataflow::multi::{partition, LinkModel};
    let arch = mobilenet_v2_full();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    let plan = partition(&arch, &U280, devices, &folds, LinkModel::gbe100());
    println!("multi-FPGA plan: {} x {} over 100 GbE", devices, U280.name);
    for (i, p) in plan.partitions.iter().enumerate() {
        println!(
            "  dev{i}: layers {:>2}..{:>2} | {:>9.0} LUT | bound {:>6} cycles | egress {:>7} B/img",
            p.first_layer, p.last_layer, p.luts, p.bound_cycles, p.egress_bytes
        );
    }
    println!(
        "  -> {:.0} FPS steady-state ({}-bound), +{:.1} us pipeline latency",
        plan.fps(),
        if plan.is_link_bound() { "link" } else { "compute" },
        plan.added_latency_s() * 1e6
    );
    Ok(())
}

/// `multi --run`: execute the partition as a sharded chain
/// (`lutmul::dataflow::ShardChain`) on real inputs and check the
/// simulation against the analytic model (EXPERIMENTS.md E11). Uses the
/// trained artifacts when built, the synthetic twin of the same
/// architecture otherwise, so the smoke check runs on a fresh checkout.
fn multi_run(artifacts: &Artifacts, devices: usize, n: usize) -> Result<()> {
    use lutmul::dataflow::multi::{partition, LinkModel};
    use lutmul::dataflow::ShardChain;
    use lutmul::graph::executor::Datapath;
    use lutmul::graph::plan::NetworkPlan;

    let arch = mobilenet_v2_small();
    let (folds, _) = optimize_folding(&arch, &Budget::whole(&U280));
    let mplan = partition(&arch, &U280, devices, &folds, LinkModel::gbe100());

    let (net, images, source) = match load_network(artifacts) {
        Ok(net) => {
            let (images, _) = artifacts.load_test_set_for(&net.io())?;
            (net, images, "trained artifacts")
        }
        Err(_) => {
            let net = Network::synthetic(&arch, 0x5EED);
            let io = net.io();
            let mut rng = lutmul::util::prop::Rng::new(0x1234_5678);
            let px = io.image_size * io.image_size * io.in_ch;
            let images: Vec<Vec<i32>> =
                (0..n.max(1)).map(|_| rng.vec_i32(px, 0, 15)).collect();
            (net, images, "synthetic network (artifacts not built)")
        }
    };
    let n = n.max(1).min(images.len());
    let images = &images[..n];

    let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
    anyhow::ensure!(
        folds.len() >= plan.n_convs(),
        "network has {} conv layers but the {} architecture folds only cover {} — \
         the artifacts were built from a different model",
        plan.n_convs(),
        arch.name,
        folds.len()
    );
    let shards = mplan.to_shards(&plan)?;
    let conv_folds = FoldConfig { folds: folds[..plan.n_convs()].to_vec() };
    println!(
        "sharded chain: {} device(s) over 100 GbE | {} | {} images",
        shards.len(),
        source,
        n
    );
    for (i, s) in shards.iter().enumerate() {
        println!(
            "  dev{i}: ops {:>2}..{:>2} | {:>2} convs | in {:>4} px x {:>3} ch | egress {:>6} B/img",
            s.start,
            s.end,
            s.plan.n_convs(),
            s.in_pixels,
            s.in_ch,
            if s.is_tail() { 0 } else { s.egress_bytes(net.meta.a_bits.max(1)) }
        );
    }

    // single-device reference run: the chain must be bit-exact with it
    let mut single = Pipeline::from_plan(&plan, &conv_folds, 16);
    let want = single.run(images)?;
    let mut chain = ShardChain::new(
        &shards,
        &conv_folds,
        16,
        &LinkModel::gbe100(),
        U280.max_freq_mhz,
        net.meta.a_bits.max(1),
    )?;
    let got = chain.run(images)?;
    anyhow::ensure!(
        got.logits == want.logits,
        "sharded chain diverged from the single-device pipeline"
    );
    println!("  bit-exact vs single-device pipeline: {n}/{n} images");

    for (i, l) in got.links.iter().enumerate() {
        println!(
            "  link{i}: {:>6} tokens | {:>3} cycles/token | latency {} cycles | stalled {} cycles",
            l.tokens, l.cycles_per_token, l.latency_cycles, l.stalled_cycles
        );
    }
    let f = U280.max_freq_mhz;
    let measured = got.measured_steady_fps(f);
    let modeled = mplan.fps();
    println!(
        "  measured {:.0} FPS steady-state (interval {} cycles) vs modeled {:.0} FPS ({}-bound) | ratio {:.3}",
        measured,
        got.incremental_cycles_per_image(),
        modeled,
        if mplan.is_link_bound() { "link" } else { "compute" },
        measured / modeled
    );
    // the steady-state comparison needs a warm chain (a couple of images
    // in flight) and a compute-bound plan to be meaningful
    if !mplan.is_link_bound() && n >= 4 {
        anyhow::ensure!(
            (measured / modeled - 1.0).abs() <= 0.15,
            "measured FPS {measured:.0} deviates more than 15% from the analytic {modeled:.0}"
        );
        println!("  within 15% of the analytic model: OK");
    }
    Ok(())
}

fn report(artifacts: &Artifacts, what: &str) -> Result<()> {
    match what {
        "table1" => lutmul::reports::table1(),
        "fig1" => lutmul::reports::fig1(),
        "fig2" => lutmul::reports::fig2(&artifacts.fig2_json()),
        "fig6" => lutmul::reports::fig6(),
        "table2" => lutmul::reports::table2(),
        "multi" => lutmul::reports::multi_scaling(),
        other => anyhow::bail!("unknown report '{other}'; try table1|fig1|fig2|fig6|table2|multi"),
    }
    Ok(())
}
