//! Baseline accelerator models (DESIGN.md S12) and the published Table 2
//! comparison rows.
//!
//! Two analytic baseline predictors exercise the same graph/roofline
//! substrate as LUTMUL:
//!  * [`dsp_packing_accelerator`] — a FILM-QNN/FPL'19-style PE-array
//!    design: all MACs on DSP slices with bit-packing, weights in BRAM,
//!    performance = min(Eq. 1 compute roof, Eq. 2 memory roof) x
//!    utilization efficiency.
//!  * [`gemm_overlay_accelerator`] — a Light-OPU-style instruction-driven
//!    overlay: same compute but an instruction/scheduling overhead factor
//!    and lower achievable frequency.


use crate::fabric::device::FpgaDevice;
use crate::fabric::power::estimate_power_w;
use crate::graph::arch::ArchSpec;
use crate::roofline;

/// Performance estimate for a baseline design.
#[derive(Debug, Clone)]
pub struct PerfEstimate {
    pub label: String,
    pub fps: f64,
    pub gops: f64,
    pub power_w: f64,
    pub gops_per_watt: f64,
    pub luts: u64,
    pub dsps: u64,
    pub bram36: u64,
    pub freq_mhz: f64,
}

/// Sustained-over-peak efficiency of a well-tuned PE-array accelerator on
/// MobileNet-class workloads (depthwise layers under-utilize the array;
/// published designs reach 20-45% of peak).
pub const PE_ARRAY_EFFICIENCY: f64 = 0.35;

/// Instruction-overlay efficiency (Light-OPU-class: generic ISA overhead
/// on top of array under-utilization).
pub const OVERLAY_EFFICIENCY: f64 = 0.22;

/// DSP-packing PE-array baseline on a device at a bit-width.
pub fn dsp_packing_accelerator(
    arch: &ArchSpec,
    device: &FpgaDevice,
    bits: u32,
    freq_mhz: f64,
) -> PerfEstimate {
    let slice = device.fraction(1);
    let peak = roofline::dsp_peak(&slice, bits, freq_mhz * 1e6);
    // memory roof: weights re-streamed per image (PE arrays reuse the
    // array across layers; activations+weights traffic per inference)
    let bytes_per_image =
        (arch.total_weights() as f64 * bits as f64 / 8.0) + 4.0 * arch.ops_per_image() as f64 / 100.0;
    let ai = arch.ops_per_image() as f64 / bytes_per_image;
    let bw = device.total_bw_gbps() * 1e9;
    let attainable = roofline::attainable(peak, bw, ai) * PE_ARRAY_EFFICIENCY;
    let fps = attainable / arch.ops_per_image() as f64;
    // typical PE-array resource footprint: most DSPs + control fabric
    let luts = (device.luts as f64 * 0.45) as u64;
    let dsps = (device.dsps as f64 * 0.9) as u64;
    let bram = (device.bram36 as f64 * 0.6) as u64;
    let power = estimate_power_w(device, luts, bram, dsps, freq_mhz);
    PerfEstimate {
        label: format!("DSP-packing W{bits} @ {}", device.name),
        fps,
        gops: attainable / 1e9,
        power_w: power,
        gops_per_watt: attainable / 1e9 / power,
        luts,
        dsps,
        bram36: bram,
        freq_mhz,
    }
}

/// Instruction-overlay (Light-OPU-style) baseline.
pub fn gemm_overlay_accelerator(
    arch: &ArchSpec,
    device: &FpgaDevice,
    bits: u32,
    freq_mhz: f64,
) -> PerfEstimate {
    let mut est = dsp_packing_accelerator(arch, device, bits, freq_mhz);
    let scale = OVERLAY_EFFICIENCY / PE_ARRAY_EFFICIENCY;
    est.label = format!("GEMM-overlay W{bits} @ {}", device.name);
    est.fps *= scale;
    est.gops *= scale;
    est.gops_per_watt *= scale;
    est
}

/// A published Table 2 row (from the cited papers).
#[derive(Debug, Clone)]
pub struct PublishedRow {
    pub name: &'static str,
    pub network: &'static str,
    pub bit_width: &'static str,
    pub top1_acc: f64,
    pub platform: &'static str,
    pub freq_mhz: f64,
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsps: u64,
    pub power_w: Option<f64>,
    pub fps: f64,
    pub gops: f64,
    pub gops_per_watt: Option<f64>,
}

/// The published comparison rows of Table 2 (excluding LUTMUL itself,
/// which this repository regenerates).
pub fn table2_published() -> Vec<PublishedRow> {
    vec![
        PublishedRow {
            name: "FINN",
            network: "MobileNetV1",
            bit_width: "W4A4",
            top1_acc: 70.4,
            platform: "Alveo U280",
            freq_mhz: 333.0,
            luts: 501_363,
            ffs: 476_316,
            bram36: 898.0,
            dsps: 106,
            power_w: Some(41.69),
            fps: 925.0,
            gops: 556.4,
            gops_per_watt: Some(13.35),
        },
        PublishedRow {
            name: "FPL'19",
            network: "MobileNetV2",
            bit_width: "W8A8",
            top1_acc: 68.1,
            platform: "ZU9EG",
            freq_mhz: 333.0,
            luts: 161_944,
            ffs: 301_416,
            bram36: 771.0,
            dsps: 2070,
            power_w: None,
            fps: 809.8,
            gops: 487.1,
            gops_per_watt: None,
        },
        PublishedRow {
            name: "Light-OPU",
            network: "MobileNetV3",
            bit_width: "W8A8",
            top1_acc: 66.7,
            platform: "XC7K325T",
            freq_mhz: 200.0,
            luts: 173_522,
            ffs: 241_175,
            bram36: 193.5,
            dsps: 704,
            power_w: Some(8.5),
            fps: 332.6,
            gops: 84.48,
            gops_per_watt: Some(9.9),
        },
        PublishedRow {
            name: "FPL'21",
            network: "MobileNetV2",
            bit_width: "W8A8",
            top1_acc: 70.8,
            platform: "XC7V690T",
            freq_mhz: 150.0,
            luts: 308_449,
            ffs: 278_926,
            bram36: 941.5,
            dsps: 2160,
            power_w: Some(11.35),
            fps: 302.3,
            gops: 181.8,
            gops_per_watt: Some(16.02),
        },
        PublishedRow {
            name: "Mix&Match",
            network: "MobileNetV2",
            bit_width: "W4A4",
            top1_acc: 65.6,
            platform: "XC7Z045",
            freq_mhz: 100.0,
            luts: 145_049,
            ffs: 111_575,
            bram36: 225.5,
            dsps: 900,
            power_w: None,
            fps: 549.3,
            gops: 326.9,
            gops_per_watt: None,
        },
        PublishedRow {
            name: "FILM-QNN",
            network: "MobileNetV2",
            bit_width: "W8A5&W4A5",
            top1_acc: 65.7,
            platform: "ZU9EG",
            freq_mhz: 150.0,
            luts: 180_100,
            ffs: 0,
            bram36: 440.5,
            dsps: 2092,
            power_w: Some(12.9),
            fps: 537.9,
            gops: 320.1,
            gops_per_watt: Some(24.8),
        },
    ]
}

/// LUTMUL's own published row (validation target for the regenerated one).
pub fn lutmul_published() -> PublishedRow {
    PublishedRow {
        name: "LUTMUL (paper)",
        network: "MobileNetV2",
        bit_width: "W4A4",
        top1_acc: 70.95,
        platform: "Alveo U280",
        freq_mhz: 333.0,
        luts: 529_242,
        ffs: 503_192,
        bram36: 1119.0,
        dsps: 106,
        power_w: Some(42.12),
        fps: 1627.0,
        gops: 978.6,
        gops_per_watt: Some(23.23),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::{U280, ZU9EG};
    use crate::graph::arch::mobilenet_v2_full;

    #[test]
    fn published_rows_complete() {
        let rows = table2_published();
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().all(|r| r.fps > 0.0 && r.gops > 0.0));
    }

    #[test]
    fn dsp_baseline_in_published_regime() {
        // A W8 DSP-packing design on ZU9EG should land in the few-hundred
        // GOPS / several-hundred FPS regime of FPL'19 and FILM-QNN.
        let arch = mobilenet_v2_full();
        let est = dsp_packing_accelerator(&arch, &ZU9EG, 8, 333.0);
        assert!(est.fps > 200.0 && est.fps < 3000.0, "fps {}", est.fps);
        assert!(est.gops > 100.0 && est.gops < 1500.0, "gops {}", est.gops);
    }

    #[test]
    fn overlay_slower_than_pe_array() {
        let arch = mobilenet_v2_full();
        let pe = dsp_packing_accelerator(&arch, &U280, 8, 300.0);
        let ov = gemm_overlay_accelerator(&arch, &U280, 8, 300.0);
        assert!(ov.fps < pe.fps);
        assert!(ov.gops < pe.gops);
    }

    #[test]
    fn paper_lutmul_beats_all_published_fps() {
        // the Table 2 ordering the harness must reproduce
        let lut = lutmul_published();
        for r in table2_published() {
            assert!(lut.fps > r.fps, "{} {} >= LUTMUL {}", r.name, r.fps, lut.fps);
        }
    }
}
