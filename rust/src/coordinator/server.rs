//! Serving coordinator (DESIGN.md S10): request router + dynamic batcher
//! + worker pool over the engine's inference backends.
//!
//! The request path is pure Rust (Python never runs here): images arrive
//! as uint8 code vectors, the batcher groups them (size- or timeout-
//! triggered, vLLM-router style), and a pool of OS-thread workers
//! executes batches. Each worker owns a persistent boxed
//! [`InferenceBackend`](crate::engine::InferenceBackend) built by the
//! engine's [`BackendFactory`](crate::engine::BackendFactory) — the
//! coordinator never matches on backend kinds; the reference executor,
//! the batch-pipelined dataflow simulator, the LUT-fabric datapath and
//! the multi-device shard chain (DESIGN.md S18) are all the same trait
//! object here, and any future backend serves without touching this
//! file.
//!
//! Batches are executed *batch-major* end to end: each worker keeps its
//! backend across batches (compiled layer plans, memoized LUT product
//! tables, pipeline line buffers are built once at startup) and hands
//! whole batches to `infer_batch`, so a dispatch of N images amortizes
//! per-layer state and parallelizes across cores instead of unrolling
//! image by image (EXPERIMENTS.md E9). Sharded backends report their
//! cumulative per-shard occupancy counters through
//! [`BatchOutput::counters`](crate::engine::BatchOutput) into the
//! metrics.
//!
//! All backends are bit-exact w.r.t. the JAX golden model
//! (`rust/tests/engine.rs` is the cross-backend conformance suite; the
//! PJRT runtime provides the golden check at startup via
//! `lutmul verify`).
//!
//! (The offline vendored crate set has no tokio, so concurrency is
//! std::thread + channels; the API is synchronous with a non-blocking
//! `submit` / blocking `wait` split.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::engine::Engine;

use super::metrics::{Metrics, MetricsSummary, ShardOccupancy};

/// Coordinator configuration. The backend itself is the engine's
/// (`EngineBuilder::backend`); the coordinator only sizes the pool.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    /// Batching window: dispatch a partial batch after this long.
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
        }
    }
}

/// One queued request.
struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    resp: SyncSender<InferenceResult>,
}

/// Inference response.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub class: usize,
    pub latency: Duration,
}

/// A pending response handle.
pub struct Ticket {
    rx: Receiver<InferenceResult>,
}

impl Ticket {
    /// Block until the result is ready.
    pub fn wait(self) -> anyhow::Result<InferenceResult> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("worker dropped request"))
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: SyncSender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    rejected: Arc<AtomicU64>,
    /// Expected codes per image (`H*W*C` from the engine's plan): a
    /// malformed request is bounced at `submit` instead of failing a
    /// whole dispatched batch (and forcing a backend rebuild) deep
    /// inside a worker.
    image_px: usize,
    /// joined on drop
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the router, batcher and worker pool over `engine`'s backend
    /// kind. Every worker gets an independent backend from the engine's
    /// factory (built eagerly, so a misconfigured backend — e.g. PJRT
    /// without the `xla` feature — fails here rather than inside a
    /// worker thread).
    pub fn start(engine: &Engine, cfg: ServeConfig) -> anyhow::Result<Self> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        // GOPS denominator from the network actually being served
        let metrics = Arc::new(Mutex::new(Metrics::new(engine.net().ops_per_image())));
        let rejected = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();

        // worker pool: one queue per worker (a shared Mutex<Receiver>
        // would serialize the pool — the lock is held across the blocking
        // recv); the batcher round-robins across the queues.
        let n_workers = cfg.workers.max(1);
        let factory = engine.backend_factory(n_workers);
        let mut worker_txs = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let (wtx, wrx) = sync_channel::<Vec<Request>>(2);
            worker_txs.push(wtx);
            let metrics = metrics.clone();
            let factory = factory.clone();
            // per-worker persistent backend, built once: compiled layer
            // plans (flattened weights, memoized LUT product tables) and
            // pipeline/chain state are reused across every batch
            let mut backend = factory()
                .map_err(|e| e.context(format!("building the backend for lutmul-worker-{wi}")))?;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("lutmul-worker-{wi}"))
                    .spawn(move || {
                        // counters of backends this worker already retired
                        // (rebuilt after a failed batch): folded into every
                        // later snapshot so the worker's recorded shard
                        // metrics never roll backwards
                        let mut shard_base: Vec<ShardOccupancy> = Vec::new();
                        while let Ok(batch) = wrx.recv() {
                            // move images out of the requests, keep the
                            // response halves
                            let mut images = Vec::with_capacity(batch.len());
                            let mut reqs = Vec::with_capacity(batch.len());
                            for r in batch {
                                images.push(r.image);
                                reqs.push((r.enqueued, r.resp));
                            }
                            let t_exec = Instant::now();
                            let out = match backend.infer_batch(&images) {
                                Ok(out) if out.logits.len() == reqs.len() => out,
                                res => {
                                    // a structured sim failure, or a backend
                                    // that miscounted its results (as broken
                                    // as one that errors): fail the waiting
                                    // requests (their response channels
                                    // drop) and rebuild the backend — a
                                    // failed pipeline/chain still holds the
                                    // dead batch's partial-image tokens, so
                                    // reusing it would corrupt later
                                    // results. Bank the dying backend's
                                    // counters first: the rebuilt one
                                    // restarts from zero.
                                    match &res {
                                        Ok(out) => eprintln!(
                                            "lutmul-worker-{wi}: backend returned {} \
                                             results for {} requests; discarding batch",
                                            out.logits.len(),
                                            reqs.len()
                                        ),
                                        Err(e) => eprintln!(
                                            "lutmul-worker-{wi}: batch failed: {e}"
                                        ),
                                    }
                                    let snap = backend.shard_occupancy();
                                    if !snap.is_empty() {
                                        if shard_base.len() < snap.len() {
                                            shard_base
                                                .resize(snap.len(), ShardOccupancy::default());
                                        }
                                        for (b, s) in shard_base.iter_mut().zip(&snap) {
                                            b.absorb(s);
                                        }
                                    }
                                    match factory() {
                                        Ok(b) => backend = b,
                                        Err(e) => {
                                            eprintln!(
                                                "lutmul-worker-{wi}: backend rebuild \
                                                 failed, worker exiting: {e}"
                                            );
                                            return;
                                        }
                                    }
                                    continue;
                                }
                            };
                            let service = t_exec.elapsed();
                            let results = out.logits;
                            // one latency sample per request, shared by the
                            // metrics and the client-visible result
                            let latencies: Vec<Duration> =
                                reqs.iter().map(|(enq, _)| enq.elapsed()).collect();
                            // one lock per batch, not per request; a
                            // poisoned lock (another worker panicked
                            // mid-record) still yields usable counters
                            {
                                let mut m = metrics
                                    .lock()
                                    .unwrap_or_else(|e| e.into_inner());
                                m.record_batch(reqs.len(), service);
                                for &l in &latencies {
                                    m.record(l);
                                }
                                if !out.counters.is_empty() {
                                    // fold in retired-backend counters so
                                    // snapshots stay monotonic per worker
                                    let mut snap = out.counters;
                                    for (s, b) in snap.iter_mut().zip(&shard_base) {
                                        s.absorb(b);
                                    }
                                    m.record_shards(wi, snap);
                                }
                            }
                            for (((_, resp), logits), latency) in
                                reqs.into_iter().zip(results).zip(latencies)
                            {
                                let class = argmax(&logits);
                                let _ = resp.send(InferenceResult { logits, class, latency });
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // batcher: size- or timeout-triggered dispatch
        let max_batch = cfg.max_batch.max(1);
        let max_wait = cfg.max_wait;
        threads.push(
            std::thread::Builder::new()
                .name("lutmul-batcher".into())
                .spawn(move || {
                    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
                    let mut next_worker = 0usize;
                    let dispatch = |batch: Vec<Request>, next_worker: &mut usize| -> bool {
                        // round-robin over the worker queues
                        let tx = &worker_txs[*next_worker % worker_txs.len()];
                        *next_worker += 1;
                        tx.send(batch).is_ok()
                    };
                    'outer: loop {
                        // block for the first item of a batch
                        match rx.recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break,
                        }
                        let window_end = Instant::now() + max_wait;
                        while pending.len() < max_batch {
                            let now = Instant::now();
                            if now >= window_end {
                                break;
                            }
                            match rx.recv_timeout(window_end - now) {
                                Ok(r) => pending.push(r),
                                Err(RecvTimeoutError::Timeout) => break,
                                Err(RecvTimeoutError::Disconnected) => {
                                    if !pending.is_empty() {
                                        let b = std::mem::take(&mut pending);
                                        let _ = dispatch(b, &mut next_worker);
                                    }
                                    break 'outer;
                                }
                            }
                        }
                        let batch = std::mem::take(&mut pending);
                        if !dispatch(batch, &mut next_worker) {
                            break;
                        }
                    }
                })
                .expect("spawn batcher"),
        );

        let io = engine.io();
        let image_px = io.image_size * io.image_size * io.in_ch;
        Ok(Self { tx, metrics, rejected, image_px, threads })
    }

    /// Submit one image without blocking; returns a ticket to wait on.
    /// Misshapen images are rejected here, before they can poison a
    /// batch of well-formed co-submitted requests.
    pub fn submit(&self, image: Vec<i32>) -> anyhow::Result<Ticket> {
        anyhow::ensure!(
            image.len() == self.image_px,
            "image has {} codes, the served network expects {}",
            image.len(),
            self.image_px
        );
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request { image, enqueued: Instant::now(), resp: resp_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(Ticket { rx: resp_rx }),
            Err(TrySendError::Full(_)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("queue full (backpressure)")
            }
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("coordinator stopped"),
        }
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Vec<i32>) -> anyhow::Result<InferenceResult> {
        self.submit(image)?.wait()
    }

    pub fn metrics(&self) -> MetricsSummary {
        // recover from poisoning: one panicked worker must not wedge the
        // operator's ability to read the summary
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).summary()
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Stop accepting requests and join all threads.
    pub fn shutdown(mut self) {
        drop(self.tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Index of the max logit.
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert!(c.workers >= 1 && c.max_batch >= 1);
    }

    // Coordinator round-trips are in rust/tests/{engine,batch,multi}.rs
    // (they need a full network + engine).
}
