//! Serving coordinator (DESIGN.md S10/S21): request router + dynamic
//! batcher + worker pool over the engine's inference backends.
//!
//! The request path is pure Rust (Python never runs here): images arrive
//! as uint8 code vectors, the batcher groups them (size- or timeout-
//! triggered, vLLM-router style), and a pool of OS-thread workers
//! executes batches. Each worker owns a persistent boxed
//! [`InferenceBackend`](crate::engine::InferenceBackend) built by the
//! engine's [`BackendFactory`](crate::engine::BackendFactory) — the
//! coordinator never matches on backend kinds; the reference executor,
//! the batch-pipelined dataflow simulator, the LUT-fabric datapath and
//! the multi-device shard chain (DESIGN.md S18) are all the same trait
//! object here, and any future backend serves without touching this
//! file.
//!
//! Batches are executed *batch-major* end to end: each worker keeps its
//! backend across batches (compiled layer plans, memoized LUT product
//! tables, pipeline line buffers are built once at startup) and hands
//! whole batches to `infer_batch`, so a dispatch of N images amortizes
//! per-layer state and parallelizes across cores instead of unrolling
//! image by image (EXPERIMENTS.md E9). Sharded backends report their
//! cumulative per-shard occupancy counters through
//! [`BatchOutput::counters`](crate::engine::BatchOutput) into the
//! metrics.
//!
//! Every in-flight request resolves to a result or a structured
//! [`ServeError`] — a worker whose backend dies mid-batch fails the
//! batch's tickets with [`ServeError::WorkerFailed`] and rebuilds its
//! backend through the factory; nothing is silently dropped. Requests
//! carry an optional deadline: a request whose deadline has already
//! expired when its batch is dispatched is shed *before* compute
//! ([`ServeError::DeadlineExceeded`]), so an overloaded queue spends no
//! backend cycles on answers nobody is waiting for (DESIGN.md S21).
//!
//! All backends are bit-exact w.r.t. the JAX golden model
//! (`rust/tests/engine.rs` is the cross-backend conformance suite; the
//! PJRT runtime provides the golden check at startup via
//! `lutmul verify`).
//!
//! (The offline vendored crate set has no tokio, so concurrency is
//! std::thread + channels; the API is synchronous with a non-blocking
//! `submit` / blocking `wait` split.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{BackendFactory, Engine};

use super::metrics::{Metrics, MetricsSummary, ShardOccupancy};

/// Coordinator configuration. The backend itself is the engine's
/// (`EngineBuilder::backend`); the coordinator only sizes the pool.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    /// Batching window: dispatch a partial batch after this long.
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
        }
    }
}

/// Structured failure of one in-flight request. Every ticket resolves to
/// `Ok(InferenceResult)` or one of these — the serving tier maps them
/// onto wire statuses (`serve::proto::Status`), and the chaos suite
/// asserts no request ever just vanishes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Shed before compute: the deadline had already expired when the
    /// batch was dispatched (`waited_us` is the time spent queued).
    DeadlineExceeded { waited_us: u64 },
    /// The worker's backend failed mid-batch; the backend was rebuilt
    /// through the engine's factory, this request was not retried.
    WorkerFailed(String),
    /// The pool shut down (or every worker died) after this request was
    /// admitted but before any backend ran it — the typed resolution of
    /// the admission/retirement race, so callers never hang.
    Shutdown,
    /// The fleet drained this request from failed batches until its
    /// retry budget ran out; `attempts` counts the failed executions
    /// (DESIGN.md S25).
    RetriesExhausted { attempts: u32 },
    /// The coordinator shut down with the request in flight.
    Disconnected,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineExceeded { waited_us } => {
                write!(f, "deadline expired before compute (queued {waited_us} us)")
            }
            ServeError::WorkerFailed(msg) => write!(f, "worker backend failed: {msg}"),
            ServeError::Shutdown => {
                write!(f, "pool shut down before the request reached a backend")
            }
            ServeError::RetriesExhausted { attempts } => {
                write!(f, "retry budget exhausted after {attempts} failed executions")
            }
            ServeError::Disconnected => write!(f, "coordinator stopped with request in flight"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Typed admission failure of [`Coordinator::try_submit`] — the serving
/// tier matches on it to pick a wire status instead of parsing strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full: backpressure. Counted in [`Coordinator::rejected`].
    Rejected,
    /// The request's image does not match the served network's geometry.
    BadShape { got: usize, want: usize },
    /// The coordinator has shut down.
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Rejected => write!(f, "queue full (backpressure)"),
            SubmitError::BadShape { got, want } => {
                write!(f, "image has {got} codes, the served network expects {want}")
            }
            SubmitError::Shutdown => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One queued request.
struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    /// Absolute shed point: expired requests are dropped at dispatch,
    /// before any backend cycles are spent on them.
    deadline: Option<Instant>,
    resp: SyncSender<Result<InferenceResult, ServeError>>,
}

/// Inference response.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub class: usize,
    pub latency: Duration,
}

/// A pending response handle.
pub struct Ticket {
    rx: Receiver<Result<InferenceResult, ServeError>>,
}

impl Ticket {
    /// Wrap a pending response channel — how the fleet (and any future
    /// front end) mints tickets over the same waiting contract.
    pub(crate) fn new(rx: Receiver<Result<InferenceResult, ServeError>>) -> Self {
        Self { rx }
    }

    /// Block until the result is ready: the inference output, or the
    /// structured reason it will never come.
    pub fn wait(self) -> Result<InferenceResult, ServeError> {
        match self.rx.recv() {
            Ok(res) => res,
            // the worker/coordinator dropped the channel without a
            // verdict (pool shut down mid-flight)
            Err(_) => Err(ServeError::Disconnected),
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: SyncSender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    rejected: Arc<AtomicU64>,
    /// Expected codes per image (`H*W*C` from the engine's plan): a
    /// malformed request is bounced at `submit` instead of failing a
    /// whole dispatched batch (and forcing a backend rebuild) deep
    /// inside a worker.
    image_px: usize,
    /// joined on drop
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the router, batcher and worker pool over `engine`'s backend
    /// kind. Every worker gets an independent backend from the engine's
    /// factory (built eagerly, so a misconfigured backend — e.g. PJRT
    /// without the `xla` feature — fails here rather than inside a
    /// worker thread).
    pub fn start(engine: &Engine, cfg: ServeConfig) -> anyhow::Result<Self> {
        let io = engine.io();
        Self::start_with(
            engine.backend_factory(cfg.workers.max(1)),
            io.image_size * io.image_size * io.in_ch,
            engine.net().ops_per_image(),
            cfg,
        )
    }

    /// Start the pool over an explicit backend factory. This is the
    /// seam the chaos suite injects flaky/slow backends through
    /// (`rust/tests/chaos.rs`); `start` is the engine-shaped wrapper.
    /// `image_px` is the expected codes per image and `ops_per_image`
    /// the GOPS denominator of the served network.
    pub fn start_with(
        factory: BackendFactory,
        image_px: usize,
        ops_per_image: u64,
        cfg: ServeConfig,
    ) -> anyhow::Result<Self> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let metrics = Arc::new(Mutex::new(Metrics::new(ops_per_image)));
        let rejected = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();

        // worker pool: one queue per worker (a shared Mutex<Receiver>
        // would serialize the pool — the lock is held across the blocking
        // recv); the batcher round-robins across the queues.
        let n_workers = cfg.workers.max(1);
        let mut worker_txs = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let (wtx, wrx) = sync_channel::<Vec<Request>>(2);
            worker_txs.push(wtx);
            let metrics = metrics.clone();
            let factory = factory.clone();
            // per-worker persistent backend, built once: compiled layer
            // plans (flattened weights, memoized LUT product tables) and
            // pipeline/chain state are reused across every batch
            let mut backend = factory()
                .map_err(|e| e.context(format!("building the backend for lutmul-worker-{wi}")))?;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("lutmul-worker-{wi}"))
                    .spawn(move || {
                        // counters of backends this worker already retired
                        // (rebuilt after a failed batch): folded into every
                        // later snapshot so the worker's recorded shard
                        // metrics never roll backwards
                        let mut shard_base: Vec<ShardOccupancy> = Vec::new();
                        while let Ok(batch) = wrx.recv() {
                            // shed expired requests BEFORE compute: their
                            // deadline passed while they sat in the queue /
                            // batch window, so backend cycles on them are
                            // pure waste (DESIGN.md S21)
                            let now = Instant::now();
                            let mut images = Vec::with_capacity(batch.len());
                            let mut reqs = Vec::with_capacity(batch.len());
                            let mut shed = 0usize;
                            for r in batch {
                                match r.deadline {
                                    Some(d) if now >= d => {
                                        let waited_us =
                                            now.duration_since(r.enqueued).as_micros() as u64;
                                        let _ = r.resp.send(Err(
                                            ServeError::DeadlineExceeded { waited_us },
                                        ));
                                        shed += 1;
                                    }
                                    _ => {
                                        images.push(r.image);
                                        reqs.push((r.enqueued, r.resp));
                                    }
                                }
                            }
                            if shed > 0 {
                                metrics
                                    .lock()
                                    .unwrap_or_else(|e| e.into_inner())
                                    .record_shed(shed);
                            }
                            if reqs.is_empty() {
                                continue;
                            }
                            let t_exec = Instant::now();
                            let out = match backend.infer_batch(&images) {
                                Ok(out) if out.logits.len() == reqs.len() => out,
                                res => {
                                    // a structured sim failure, or a backend
                                    // that miscounted its results (as broken
                                    // as one that errors): resolve every
                                    // waiting ticket with a structured error
                                    // and rebuild the backend — a failed
                                    // pipeline/chain still holds the dead
                                    // batch's partial-image tokens, so
                                    // reusing it would corrupt later
                                    // results. Bank the dying backend's
                                    // counters first: the rebuilt one
                                    // restarts from zero.
                                    let msg = match &res {
                                        Ok(out) => format!(
                                            "backend returned {} results for {} requests",
                                            out.logits.len(),
                                            reqs.len()
                                        ),
                                        Err(e) => e.to_string(),
                                    };
                                    eprintln!(
                                        "lutmul-worker-{wi}: batch failed ({msg}); \
                                         rebuilding backend"
                                    );
                                    let n_failed = reqs.len();
                                    for (_, resp) in reqs {
                                        let _ = resp
                                            .send(Err(ServeError::WorkerFailed(msg.clone())));
                                    }
                                    let snap = backend.shard_occupancy();
                                    {
                                        let mut m = metrics
                                            .lock()
                                            .unwrap_or_else(|e| e.into_inner());
                                        m.record_failed(n_failed);
                                        if !snap.is_empty() {
                                            if shard_base.len() < snap.len() {
                                                shard_base.resize(
                                                    snap.len(),
                                                    ShardOccupancy::default(),
                                                );
                                            }
                                            for (b, s) in shard_base.iter_mut().zip(&snap) {
                                                b.absorb(s);
                                            }
                                        }
                                    }
                                    match factory() {
                                        Ok(b) => backend = b,
                                        Err(e) => {
                                            eprintln!(
                                                "lutmul-worker-{wi}: backend rebuild \
                                                 failed, worker exiting: {e}"
                                            );
                                            // batches already queued to
                                            // this worker will never see
                                            // a backend: resolve their
                                            // tickets typed before the
                                            // queue drops
                                            while let Ok(batch) = wrx.try_recv() {
                                                for r in batch {
                                                    let _ = r
                                                        .resp
                                                        .send(Err(ServeError::Shutdown));
                                                }
                                            }
                                            return;
                                        }
                                    }
                                    continue;
                                }
                            };
                            let service = t_exec.elapsed();
                            let results = out.logits;
                            // one latency sample per request, shared by the
                            // metrics and the client-visible result
                            let latencies: Vec<Duration> =
                                reqs.iter().map(|(enq, _)| enq.elapsed()).collect();
                            // one lock per batch, not per request; a
                            // poisoned lock (another worker panicked
                            // mid-record) still yields usable counters
                            {
                                let mut m = metrics
                                    .lock()
                                    .unwrap_or_else(|e| e.into_inner());
                                m.record_batch(reqs.len(), service);
                                for (&l, (enq, _)) in latencies.iter().zip(&reqs) {
                                    // queue share = dispatch minus submit;
                                    // compute share = the batch's backend
                                    // service time (shared by its riders)
                                    m.record_split(
                                        l,
                                        t_exec.duration_since(*enq),
                                        service,
                                    );
                                }
                                if !out.counters.is_empty() {
                                    // fold in retired-backend counters so
                                    // snapshots stay monotonic per worker
                                    let mut snap = out.counters;
                                    for (s, b) in snap.iter_mut().zip(&shard_base) {
                                        s.absorb(b);
                                    }
                                    m.record_shards(wi, snap);
                                }
                            }
                            for (((_, resp), logits), latency) in
                                reqs.into_iter().zip(results).zip(latencies)
                            {
                                let class = argmax(&logits);
                                let _ =
                                    resp.send(Ok(InferenceResult { logits, class, latency }));
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // batcher: size- or timeout-triggered dispatch
        let max_batch = cfg.max_batch.max(1);
        let max_wait = cfg.max_wait;
        threads.push(
            std::thread::Builder::new()
                .name("lutmul-batcher".into())
                .spawn(move || {
                    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
                    let mut next_worker = 0usize;
                    let dispatch = |mut batch: Vec<Request>, next_worker: &mut usize| -> bool {
                        // round-robin over the worker queues, falling
                        // through dead ones: a worker whose rebuild
                        // failed has dropped its queue, and the batch
                        // must land on a live peer instead of killing
                        // the whole pool
                        for _ in 0..worker_txs.len() {
                            let tx = &worker_txs[*next_worker % worker_txs.len()];
                            *next_worker += 1;
                            match tx.send(batch) {
                                Ok(()) => return true,
                                Err(std::sync::mpsc::SendError(b)) => batch = b,
                            }
                        }
                        // every worker is gone: requests that won the
                        // admission race against the dying pool still
                        // resolve typed — never a hang
                        for r in batch {
                            let _ = r.resp.send(Err(ServeError::Shutdown));
                        }
                        false
                    };
                    'outer: loop {
                        // block for the first item of a batch
                        match rx.recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break,
                        }
                        let window_end = Instant::now() + max_wait;
                        while pending.len() < max_batch {
                            let now = Instant::now();
                            if now >= window_end {
                                break;
                            }
                            match rx.recv_timeout(window_end - now) {
                                Ok(r) => pending.push(r),
                                Err(RecvTimeoutError::Timeout) => break,
                                Err(RecvTimeoutError::Disconnected) => {
                                    if !pending.is_empty() {
                                        let b = std::mem::take(&mut pending);
                                        let _ = dispatch(b, &mut next_worker);
                                    }
                                    break 'outer;
                                }
                            }
                        }
                        let batch = std::mem::take(&mut pending);
                        if !dispatch(batch, &mut next_worker) {
                            break;
                        }
                    }
                })
                .expect("spawn batcher"),
        );

        Ok(Self { tx, metrics, rejected, image_px, threads })
    }

    /// Submit one image without blocking; returns a ticket to wait on.
    /// Misshapen images are rejected here, before they can poison a
    /// batch of well-formed co-submitted requests.
    pub fn submit(&self, image: Vec<i32>) -> anyhow::Result<Ticket> {
        self.try_submit(image, None).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Submit with a relative deadline: if it expires before the request
    /// reaches a backend, the request is shed without compute and its
    /// ticket resolves to [`ServeError::DeadlineExceeded`].
    pub fn submit_with_deadline(
        &self,
        image: Vec<i32>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Ticket> {
        self.try_submit(image, deadline).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Typed submission: the serving tier maps [`SubmitError`] variants
    /// onto wire statuses. A full queue counts into
    /// [`rejected`](Self::rejected) (admission control / backpressure).
    pub fn try_submit(
        &self,
        image: Vec<i32>,
        deadline: Option<Duration>,
    ) -> Result<Ticket, SubmitError> {
        if image.len() != self.image_px {
            return Err(SubmitError::BadShape { got: image.len(), want: self.image_px });
        }
        let (resp_tx, resp_rx) = sync_channel(1);
        let now = Instant::now();
        let req = Request {
            image,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            resp: resp_tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => Ok(Ticket { rx: resp_rx }),
            Err(TrySendError::Full(_)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Rejected)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Shutdown),
        }
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Vec<i32>) -> anyhow::Result<InferenceResult> {
        Ok(self.submit(image)?.wait()?)
    }

    /// Expected codes per image of the served network (`H*W*C`).
    pub fn image_px(&self) -> usize {
        self.image_px
    }

    pub fn metrics(&self) -> MetricsSummary {
        // recover from poisoning: one panicked worker must not wedge the
        // operator's ability to read the summary
        let mut s = self.metrics.lock().unwrap_or_else(|e| e.into_inner()).summary();
        // the admission counter lives outside the mutex (submit must not
        // contend with workers); fold it into the snapshot here
        s.rejected = self.rejected.load(Ordering::Relaxed);
        s
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Stop accepting requests and join all threads.
    pub fn shutdown(mut self) {
        drop(self.tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Index of the max logit.
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert!(c.workers >= 1 && c.max_batch >= 1);
    }

    #[test]
    fn error_displays_are_stable() {
        let e = ServeError::DeadlineExceeded { waited_us: 42 };
        assert!(e.to_string().contains("deadline"), "{e}");
        assert!(e.to_string().contains("42"), "{e}");
        let e = ServeError::WorkerFailed("boom".into());
        assert!(e.to_string().contains("boom"), "{e}");
        let e = ServeError::Shutdown;
        assert!(e.to_string().contains("shut down"), "{e}");
        let e = ServeError::RetriesExhausted { attempts: 3 };
        assert!(e.to_string().contains("retry budget"), "{e}");
        assert!(e.to_string().contains('3'), "{e}");
        let e = SubmitError::BadShape { got: 3, want: 768 };
        assert!(e.to_string().contains("expects 768"), "{e}");
        assert!(SubmitError::Rejected.to_string().contains("backpressure"));
    }

    // Coordinator round-trips are in rust/tests/{engine,batch,multi,
    // serve,chaos}.rs (they need a full network + engine).
}
