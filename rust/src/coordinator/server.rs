//! Serving coordinator (DESIGN.md S10): request router + dynamic batcher
//! + worker pool over the accelerator backends.
//!
//! The request path is pure Rust (Python never runs here): images arrive
//! as uint8 code vectors, the batcher groups them (size- or timeout-
//! triggered, vLLM-router style), and a pool of OS-thread workers executes
//! batches on one of three backends:
//!
//!  * `Simulator` — the dataflow pipeline simulator (the paper's
//!    accelerator, cycle-modelled); a dispatched batch streams through the
//!    pipeline back to back, successive images overlapping in flight
//!    rather than draining between images;
//!  * `Reference` — the spec-level integer executor (fast path);
//!  * `LutFabric` — the executor with every 4-bit multiplication
//!    performed by simulated LUT6_2 readout (hardware-true datapath);
//!  * `Sharded` — the network sliced across N simulated devices
//!    (DESIGN.md S18): each worker owns a [`ShardChain`] of shard
//!    pipelines joined by bandwidth/latency-charged links and streams
//!    whole batches through it, reporting per-shard occupancy/stall
//!    counters into the metrics.
//!
//! Batches are executed *batch-major* end to end: each worker keeps a
//! persistent backend (executor or pipeline, built once at spawn) and
//! hands whole batches to [`Executor::run_batch`] / [`Pipeline::run`], so
//! a dispatch of N images amortizes per-layer state and parallelizes
//! across cores instead of unrolling image by image (EXPERIMENTS.md E9).
//!
//! All backends are bit-exact w.r.t. the JAX golden model; the PJRT
//! runtime (`runtime::Runtime`) provides the golden check at startup.
//!
//! (The offline vendored crate set has no tokio, so concurrency is
//! std::thread + channels; the API is synchronous with a non-blocking
//! `submit` / blocking `wait` split.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dataflow::multi::LinkModel;
use crate::dataflow::{FoldConfig, Pipeline, ShardChain};
use crate::fabric::device::U280;
use crate::graph::executor::{Datapath, Executor, Tensor};
use crate::graph::network::Network;
use crate::graph::plan::NetworkPlan;

use super::metrics::{Metrics, MetricsSummary, ShardOccupancy};

/// Inference backend selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Simulator,
    Reference,
    LutFabric,
    /// The network sliced across `devices` simulated FPGAs joined by
    /// 100 GbE links; batches stream through a [`ShardChain`]
    /// (DESIGN.md S18).
    Sharded { devices: usize },
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub backend: Backend,
    pub workers: usize,
    pub max_batch: usize,
    /// Batching window: dispatch a partial batch after this long.
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            backend: Backend::Reference,
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
        }
    }
}

/// One queued request.
struct Request {
    image: Vec<i32>,
    enqueued: Instant,
    resp: SyncSender<InferenceResult>,
}

/// Inference response.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub class: usize,
    pub latency: Duration,
}

/// A pending response handle.
pub struct Ticket {
    rx: Receiver<InferenceResult>,
}

impl Ticket {
    /// Block until the result is ready.
    pub fn wait(self) -> anyhow::Result<InferenceResult> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("worker dropped request"))
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: SyncSender<Request>,
    metrics: Arc<Mutex<Metrics>>,
    rejected: Arc<AtomicU64>,
    /// joined on drop
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the router, batcher and worker pool.
    pub fn start(net: Arc<Network>, cfg: ServeConfig) -> Self {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        // GOPS denominator from the network actually being served
        let metrics = Arc::new(Mutex::new(Metrics::new(net.ops_per_image())));
        let rejected = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();

        // worker pool: one queue per worker (a shared Mutex<Receiver>
        // would serialize the pool — the lock is held across the blocking
        // recv); the batcher round-robins across the queues.
        let n_workers = cfg.workers.max(1);
        let mut worker_txs = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let (wtx, wrx) = sync_channel::<Vec<Request>>(2);
            worker_txs.push(wtx);
            let net = net.clone();
            let metrics = metrics.clone();
            let backend = cfg.backend;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("lutmul-worker-{wi}"))
                    .spawn(move || {
                        // per-worker persistent backend state, built once:
                        // the compiled layer plans (flattened weights,
                        // memoized LUT product tables) and the pipeline
                        // are reused across every batch
                        let mut worker = WorkerBackend::new(&net, backend, n_workers);
                        // counters of backends this worker already retired
                        // (rebuilt after a failed batch): folded into every
                        // later snapshot so the worker's recorded shard
                        // metrics never roll backwards
                        let mut shard_base: Vec<ShardOccupancy> = Vec::new();
                        while let Ok(batch) = wrx.recv() {
                            // move images out of the requests (no copies on
                            // the hot path), keep the response halves
                            let mut images = Vec::with_capacity(batch.len());
                            let mut reqs = Vec::with_capacity(batch.len());
                            for r in batch {
                                images.push(r.image);
                                reqs.push((r.enqueued, r.resp));
                            }
                            let t_exec = Instant::now();
                            let results = match worker.run(images) {
                                Ok(r) => r,
                                Err(e) => {
                                    // structured sim failure: fail the
                                    // waiting requests (their response
                                    // channels drop) and rebuild the
                                    // backend — a failed pipeline/chain
                                    // still holds the dead batch's
                                    // partial-image tokens, so reusing
                                    // it would corrupt later results.
                                    // Bank the dying chain's counters
                                    // first: the rebuilt chain restarts
                                    // from zero.
                                    eprintln!("lutmul-worker-{wi}: batch failed: {e}");
                                    if let Some(snap) = worker.shard_occupancy() {
                                        if shard_base.len() < snap.len() {
                                            shard_base
                                                .resize(snap.len(), ShardOccupancy::default());
                                        }
                                        for (b, s) in shard_base.iter_mut().zip(&snap) {
                                            b.absorb(s);
                                        }
                                    }
                                    worker = WorkerBackend::new(&net, backend, n_workers);
                                    continue;
                                }
                            };
                            let service = t_exec.elapsed();
                            // one latency sample per request, shared by the
                            // metrics and the client-visible result
                            let latencies: Vec<Duration> =
                                reqs.iter().map(|(enq, _)| enq.elapsed()).collect();
                            // one lock per batch, not per request; a
                            // poisoned lock (another worker panicked
                            // mid-record) still yields usable counters
                            {
                                let mut m = metrics
                                    .lock()
                                    .unwrap_or_else(|e| e.into_inner());
                                m.record_batch(reqs.len(), service);
                                for &l in &latencies {
                                    m.record(l);
                                }
                                if let Some(mut snap) = worker.shard_occupancy() {
                                    // fold in retired-backend counters so
                                    // snapshots stay monotonic per worker
                                    for (s, b) in snap.iter_mut().zip(&shard_base) {
                                        s.absorb(b);
                                    }
                                    m.record_shards(wi, snap);
                                }
                            }
                            for (((_, resp), logits), latency) in
                                reqs.into_iter().zip(results).zip(latencies)
                            {
                                let class = argmax(&logits);
                                let _ = resp.send(InferenceResult { logits, class, latency });
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // batcher: size- or timeout-triggered dispatch
        let max_batch = cfg.max_batch.max(1);
        let max_wait = cfg.max_wait;
        threads.push(
            std::thread::Builder::new()
                .name("lutmul-batcher".into())
                .spawn(move || {
                    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
                    let mut next_worker = 0usize;
                    let dispatch = |batch: Vec<Request>, next_worker: &mut usize| -> bool {
                        // round-robin over the worker queues
                        let tx = &worker_txs[*next_worker % worker_txs.len()];
                        *next_worker += 1;
                        tx.send(batch).is_ok()
                    };
                    'outer: loop {
                        // block for the first item of a batch
                        match rx.recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break,
                        }
                        let window_end = Instant::now() + max_wait;
                        while pending.len() < max_batch {
                            let now = Instant::now();
                            if now >= window_end {
                                break;
                            }
                            match rx.recv_timeout(window_end - now) {
                                Ok(r) => pending.push(r),
                                Err(RecvTimeoutError::Timeout) => break,
                                Err(RecvTimeoutError::Disconnected) => {
                                    if !pending.is_empty() {
                                        let b = std::mem::take(&mut pending);
                                        let _ = dispatch(b, &mut next_worker);
                                    }
                                    break 'outer;
                                }
                            }
                        }
                        let batch = std::mem::take(&mut pending);
                        if !dispatch(batch, &mut next_worker) {
                            break;
                        }
                    }
                })
                .expect("spawn batcher"),
        );

        Self { tx, metrics, rejected, threads }
    }

    /// Submit one image without blocking; returns a ticket to wait on.
    pub fn submit(&self, image: Vec<i32>) -> anyhow::Result<Ticket> {
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request { image, enqueued: Instant::now(), resp: resp_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(Ticket { rx: resp_rx }),
            Err(TrySendError::Full(_)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("queue full (backpressure)")
            }
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("coordinator stopped"),
        }
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Vec<i32>) -> anyhow::Result<InferenceResult> {
        self.submit(image)?.wait()
    }

    pub fn metrics(&self) -> MetricsSummary {
        // recover from poisoning: one panicked worker must not wedge the
        // operator's ability to read the summary
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).summary()
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Stop accepting requests and join all threads.
    pub fn shutdown(mut self) {
        drop(self.tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Per-worker backend state, persistent across batches: the network is
/// compiled once per worker into owned plans (flattened weights,
/// memoized LUT product tables), not once per batch.
enum WorkerBackend {
    Pipeline(Box<Pipeline>),
    /// Sharded chain of shard pipelines joined by cycle-charged links
    /// (DESIGN.md S18), built once per worker like the pipeline.
    Chain(Box<ShardChain>),
    Exec { ex: Executor, size: usize, ch: usize, threads: usize },
}

impl WorkerBackend {
    /// `pool_size` is the number of concurrent workers sharing the
    /// machine: each backend gets an equal share of the cores so the pool
    /// never oversubscribes the CPU.
    fn new(net: &Network, backend: Backend, pool_size: usize) -> Self {
        let cores =
            std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1);
        let threads = (cores / pool_size.max(1)).max(1);
        match backend {
            Backend::Simulator => {
                // compile once; the pipeline consumes the plan's geometry
                let plan = NetworkPlan::compile(net, Datapath::Arithmetic);
                let folds = FoldConfig::fully_parallel(plan.n_convs());
                WorkerBackend::Pipeline(Box::new(Pipeline::from_plan(&plan, &folds, 16)))
            }
            Backend::Sharded { devices } => {
                // slice the compiled plan into MAC-balanced shards and
                // join them with the default 100 GbE link model at the
                // device clock the analytic multi-FPGA plan uses
                let plan = NetworkPlan::compile(net, Datapath::Arithmetic);
                let shards = plan.shard_evenly(devices.max(1));
                let folds = FoldConfig::fully_parallel(plan.n_convs());
                let chain = ShardChain::new(
                    &shards,
                    &folds,
                    16,
                    &LinkModel::gbe100(),
                    U280.max_freq_mhz,
                    net.meta.a_bits.max(1),
                )
                .expect("shard_evenly yields a contiguous dense-tailed chain");
                WorkerBackend::Chain(Box::new(chain))
            }
            Backend::Reference => Self::exec(net, Datapath::Arithmetic, threads),
            Backend::LutFabric => Self::exec(net, Datapath::LutFabric, threads),
        }
    }

    /// Executor-backed worker; image geometry comes from the compiled
    /// plan rather than `Network::meta` (DESIGN.md S17).
    fn exec(net: &Network, datapath: Datapath, threads: usize) -> Self {
        let ex = Executor::new(net, datapath);
        let io = ex.plan().io;
        WorkerBackend::Exec { ex, size: io.image_size, ch: io.in_ch, threads }
    }

    /// Execute one dispatched batch, batch-major. Takes the images by
    /// value so the executor path can move them into tensors copy-free.
    /// Simulator/sharded backends surface structured `dataflow::SimError`
    /// failures instead of panicking the worker.
    fn run(&mut self, images: Vec<Vec<i32>>) -> anyhow::Result<Vec<Vec<f32>>> {
        match self {
            // the pipeline streams the whole batch back to back: image i+1
            // enters the first stage while image i is still in flight
            WorkerBackend::Pipeline(pipe) => Ok(pipe.run(&images)?.logits),
            // the chain streams the batch across every simulated device
            WorkerBackend::Chain(chain) => Ok(chain.run(&images)?.logits),
            WorkerBackend::Exec { ex, size, ch, threads } => {
                let tensors: Vec<Tensor> = images
                    .into_iter()
                    .map(|img| Tensor::from_hwc(*size, *size, *ch, img))
                    .collect();
                Ok(ex.run_batch_with_threads(&tensors, *threads))
            }
        }
    }

    /// Cumulative per-shard occupancy/stall counters (sharded backend
    /// only), polled after each batch for the metrics —
    /// `ShardChain::occupancy` sums counters in place, so the hot loop
    /// never materializes the per-stage stat vectors. `ShardOccupancy`
    /// IS the chain's own `ShardCounters`, re-exported.
    fn shard_occupancy(&self) -> Option<Vec<ShardOccupancy>> {
        let WorkerBackend::Chain(chain) = self else { return None };
        Some(chain.occupancy())
    }
}

/// Execute a batch on a chosen backend (one-shot convenience; builds the
/// backend, runs the batch batch-major with all cores, and tears it down).
pub fn run_batch(
    net: &Network,
    backend: Backend,
    images: &[Vec<i32>],
) -> anyhow::Result<Vec<Vec<f32>>> {
    WorkerBackend::new(net, backend, 1).run(images.to_vec())
}

/// Index of the max logit.
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert!(c.workers >= 1 && c.max_batch >= 1);
    }

    // Coordinator round-trips are in rust/tests/integration.rs (they need
    // a full network).
}
