//! Elastic heterogeneous fleet serving (DESIGN.md S25): class-routed
//! worker pools over *different* backend kinds, autoscaled on queue
//! depth, with supervised drain-and-rebuild recovery.
//!
//! The single-pool [`Coordinator`](super::Coordinator) (S21) drives one
//! backend kind through a fixed worker count. The [`Fleet`] generalizes
//! it along the axis the multi-FPGA story needs (S18/S19): requests
//! carry a [`RequestClass`], and each class owns an independent pool —
//! latency-class traffic routes to executor replicas (cheap per-image
//! latency, no pipeline fill), throughput-class traffic to
//! `ShardChainBackend` chains (highest steady-state images/s once the
//! pipeline is full). Both pools are built from the engine's
//! [`BackendFactory`](crate::engine::BackendFactory), so the fleet never
//! matches on backend kinds — any [`InferenceBackend`] serves.
//!
//! Architecture per pool (deliberately different from the S21
//! batcher+channels shape, because elasticity changes the requirements):
//!
//! * **Shared work deque, worker pull.** Requests land in one
//!   `Mutex<VecDeque>` + `Condvar` per pool. Workers pull the first
//!   request, then form their own batch inside the `max_wait` window.
//!   A shared deque is what makes the other three features cheap: queue
//!   *depth* is observable (autoscaling signal), a retiring worker
//!   simply stops pulling (drain-then-retire needs no channel surgery),
//!   and failed requests re-enqueue at the *front* (retry keeps order).
//! * **Autoscaling.** A supervisor thread per pool samples queue depth
//!   every `scale_tick`: depth above `high_water` for `up_ticks`
//!   consecutive ticks spawns a worker (up to `max_workers`); a queue
//!   idle for `idle_ticks` ticks posts a *retire order* that the next
//!   idle worker honors (down to `min_workers`). Scale-down never
//!   interrupts a batch in flight — retirement happens only between
//!   batches, when the worker observes an empty queue.
//! * **Supervised recovery.** A backend that errors (or miscounts) a
//!   batch is *drained*: its in-flight requests are pushed back to the
//!   front of the queue with a bounded per-request retry budget;
//!   requests over budget resolve to the typed
//!   [`ServeError::RetriesExhausted`]. The worker then rebuilds its
//!   backend through the factory under exponential backoff, banks the
//!   dead backend's shard counters into a per-worker base so occupancy
//!   stays monotonic across the rebuild, and resumes pulling. A worker
//!   whose rebuild fails permanently exits; the supervisor respawns
//!   below `min_workers`.
//!
//! The chaos seam is [`Fleet::chaos_kill`]: it arms the next batch of a
//! class's pool to fail as if the device died mid-batch, which is what
//! `tests/fleet.rs` uses to prove the kill-a-ShardChain-mid-batch
//! invariants (zero lost, zero reordered, `rebuilds` exactly one,
//! occupancy monotonic).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::engine::{BackendFactory, BackendKind, Engine, InferenceBackend};

use super::metrics::{Metrics, MetricsSummary, ShardOccupancy};
use super::server::{argmax, InferenceResult, ServeError, SubmitError, Ticket};

/// Which pool a request routes to. Carried as one byte on the wire
/// (`serve::proto` v2) and as the `X-Request-Class` HTTP header.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequestClass {
    /// Latency-sensitive: routed to executor replicas (no pipeline fill
    /// cost, smallest per-image latency).
    Latency = 0,
    /// Throughput-oriented: routed to sharded chain workers (highest
    /// steady-state images/s once the pipeline is full).
    Throughput = 1,
}

impl RequestClass {
    pub const ALL: [RequestClass; 2] = [RequestClass::Latency, RequestClass::Throughput];

    /// Wire decoding (`serve::proto` request byte 13). Unknown values
    /// are a malformed request, not a default.
    pub fn from_u8(b: u8) -> Option<RequestClass> {
        match b {
            0 => Some(RequestClass::Latency),
            1 => Some(RequestClass::Throughput),
            _ => None,
        }
    }

    /// Stable human label (HTTP header values, report tables, flags).
    pub fn label(self) -> &'static str {
        match self {
            RequestClass::Latency => "latency",
            RequestClass::Throughput => "throughput",
        }
    }

    /// Parse a label or its wire byte ("latency"/"0", "throughput"/"1"),
    /// case-insensitive.
    pub fn parse(s: &str) -> Option<RequestClass> {
        match s.trim().to_ascii_lowercase().as_str() {
            "latency" | "lat" | "0" => Some(RequestClass::Latency),
            "throughput" | "thr" | "1" => Some(RequestClass::Throughput),
            _ => None,
        }
    }

    /// Pool index (`Fleet` stores pools in `ALL` order).
    pub fn index(self) -> usize {
        self as usize
    }
}

impl std::fmt::Display for RequestClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Per-pool elasticity bounds.
#[derive(Debug, Clone, Copy)]
pub struct PoolScale {
    /// Workers kept alive even when idle (also the eager-build count at
    /// startup, so factory misconfiguration fails in `start`).
    pub min_workers: usize,
    /// Autoscaling ceiling.
    pub max_workers: usize,
}

/// Fleet configuration: per-class scale bounds plus the batching,
/// retry, and autoscaling knobs shared by both pools.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub latency: PoolScale,
    pub throughput: PoolScale,
    /// Batch ceiling per worker dispatch.
    pub max_batch: usize,
    /// Batch-forming window: a worker holding a partial batch waits at
    /// most this long for riders.
    pub max_wait: Duration,
    /// Per-pool admission bound: submissions beyond this depth are
    /// rejected (backpressure), mirroring the S21 coordinator.
    pub queue_depth: usize,
    /// How many times a request drained from a failed batch is re-run
    /// before it sheds with [`ServeError::RetriesExhausted`].
    pub retry_budget: u32,
    /// Base delay of the rebuild backoff; doubles per consecutive
    /// rebuild failure, capped at 64x.
    pub rebuild_backoff: Duration,
    /// Supervisor sampling period.
    pub scale_tick: Duration,
    /// Queue depth that counts a tick as "hot".
    pub high_water: usize,
    /// Consecutive hot ticks before a scale-up.
    pub up_ticks: u32,
    /// Consecutive empty-queue ticks before a retire order.
    pub idle_ticks: u32,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            latency: PoolScale { min_workers: 1, max_workers: 4 },
            throughput: PoolScale { min_workers: 1, max_workers: 2 },
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            retry_budget: 2,
            rebuild_backoff: Duration::from_millis(1),
            scale_tick: Duration::from_millis(10),
            high_water: 16,
            up_ticks: 3,
            idle_ticks: 50,
        }
    }
}

/// One queued request (the fleet's analog of the coordinator's private
/// `Request`, plus the retry ledger).
struct FleetRequest {
    image: Vec<i32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    /// Failed executions so far; compared against the retry budget when
    /// the request is drained from a failed batch.
    attempts: u32,
    resp: SyncSender<Result<InferenceResult, ServeError>>,
}

/// Mutable pool state behind the queue mutex.
struct PoolState {
    queue: VecDeque<FleetRequest>,
    /// False once shutdown starts: submissions bounce, idle workers
    /// exit after draining the queue.
    open: bool,
    /// Outstanding retire orders; the next worker that observes an
    /// empty queue consumes one and exits.
    retire: usize,
    /// Workers currently running (spawned minus exited).
    live_workers: usize,
    /// Monotonic worker id; also the metrics key, so a respawned
    /// worker's shard snapshot never clobbers a retired one's.
    next_worker_id: usize,
}

/// Cumulative per-pool event counters (lock-free; read by summaries).
#[derive(Default)]
struct PoolCounters {
    rejected: AtomicU64,
    /// Backend rebuilds after a failed batch.
    rebuilds: AtomicU64,
    /// Requests re-enqueued from a failed batch (within budget).
    retried: AtomicU64,
    /// Requests shed with `RetriesExhausted`.
    shed_retry: AtomicU64,
    /// Autoscale events.
    scale_up: AtomicU64,
    scale_down: AtomicU64,
    /// Workers ever spawned (initial + scale-up + respawn).
    spawned: AtomicU64,
    /// Chaos seam: each armed count fails one upcoming batch as if the
    /// device died mid-batch.
    kill_next: AtomicU64,
}

/// Everything a pool's workers, supervisor and the `Fleet` handle share.
struct PoolShared {
    class: RequestClass,
    state: Mutex<PoolState>,
    cv: Condvar,
    metrics: Mutex<Metrics>,
    counters: PoolCounters,
    /// Backend name reported by the first built backend (display only).
    label: Mutex<String>,
    max_batch: usize,
    max_wait: Duration,
    queue_depth: usize,
    retry_budget: u32,
    rebuild_backoff: Duration,
}

impl PoolShared {
    fn lock_state(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_metrics(&self) -> MutexGuard<'_, Metrics> {
        self.metrics.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// One class's pool: shared state plus the thread handles the fleet
/// joins at shutdown.
struct Pool {
    shared: Arc<PoolShared>,
    factory: BackendFactory,
    /// Worker handles; the supervisor pushes scale-up spawns here too.
    handles: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    supervisor: Option<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    scale: PoolScale,
}

/// Handle to a running heterogeneous fleet: one pool per
/// [`RequestClass`], each autoscaled and supervised independently.
pub struct Fleet {
    pools: Vec<Pool>,
    image_px: usize,
}

impl Fleet {
    /// Start a fleet over `engine`: latency-class requests serve from
    /// executor-replica workers, throughput-class from `devices`-way
    /// sharded chain workers — both built through the engine's factory
    /// seam, never by matching on backend kinds here.
    pub fn start(engine: &Engine, devices: usize, cfg: FleetConfig) -> anyhow::Result<Fleet> {
        let io = engine.io();
        let latency = engine
            .backend_factory_for(BackendKind::Reference, cfg.latency.max_workers.max(1));
        let throughput = engine.backend_factory_for(
            BackendKind::Sharded { devices: devices.max(2) },
            cfg.throughput.max_workers.max(1),
        );
        Self::start_with(
            latency,
            throughput,
            io.image_size * io.image_size * io.in_ch,
            engine.net().ops_per_image(),
            cfg,
        )
    }

    /// Start the fleet over explicit per-class factories — the seam
    /// `tests/fleet.rs` injects flaky/slow/distinguishable backends
    /// through, exactly like `Coordinator::start_with` for the S21
    /// chaos suite. `min_workers` backends per pool are built eagerly,
    /// so a misconfigured factory fails here, not in a worker thread.
    pub fn start_with(
        latency_factory: BackendFactory,
        throughput_factory: BackendFactory,
        image_px: usize,
        ops_per_image: u64,
        cfg: FleetConfig,
    ) -> anyhow::Result<Fleet> {
        let pools = vec![
            spawn_pool(RequestClass::Latency, latency_factory, ops_per_image, &cfg, cfg.latency)?,
            spawn_pool(
                RequestClass::Throughput,
                throughput_factory,
                ops_per_image,
                &cfg,
                cfg.throughput,
            )?,
        ];
        Ok(Fleet { pools, image_px })
    }

    /// Expected codes per image of the served network (`H*W*C`).
    pub fn image_px(&self) -> usize {
        self.image_px
    }

    /// Typed class-routed submission; the serving tier maps
    /// [`SubmitError`] onto wire statuses. A full class queue counts
    /// into that pool's `rejected`.
    pub fn try_submit(
        &self,
        image: Vec<i32>,
        deadline: Option<Duration>,
        class: RequestClass,
    ) -> Result<Ticket, SubmitError> {
        if image.len() != self.image_px {
            return Err(SubmitError::BadShape { got: image.len(), want: self.image_px });
        }
        let pool = &self.pools[class.index()];
        let (resp_tx, resp_rx) = sync_channel(1);
        let now = Instant::now();
        let req = FleetRequest {
            image,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            attempts: 0,
            resp: resp_tx,
        };
        let mut st = pool.shared.lock_state();
        if !st.open {
            return Err(SubmitError::Shutdown);
        }
        if st.queue.len() >= pool.shared.queue_depth {
            pool.shared.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Rejected);
        }
        st.queue.push_back(req);
        drop(st);
        pool.shared.cv.notify_one();
        Ok(Ticket::new(resp_rx))
    }

    /// Submit one image to `class`'s pool without blocking (convenience
    /// over [`try_submit`](Self::try_submit)).
    pub fn submit(&self, image: Vec<i32>, class: RequestClass) -> anyhow::Result<Ticket> {
        self.try_submit(image, None, class).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, image: Vec<i32>, class: RequestClass) -> anyhow::Result<InferenceResult> {
        Ok(self.submit(image, class)?.wait()?)
    }

    /// Arm one injected mid-batch failure on `class`'s pool: the next
    /// dispatched batch fails as if the device died, draining its
    /// requests back into the queue and rebuilding the backend. The
    /// chaos tests and `make fleet-smoke` drive recovery through this.
    pub fn chaos_kill(&self, class: RequestClass) {
        self.pools[class.index()].shared.counters.kill_next.fetch_add(1, Ordering::SeqCst);
    }

    /// Current queue depth of `class`'s pool.
    pub fn queue_depth(&self, class: RequestClass) -> usize {
        self.pools[class.index()].shared.lock_state().queue.len()
    }

    /// Live worker count of `class`'s pool.
    pub fn workers(&self, class: RequestClass) -> usize {
        self.pools[class.index()].shared.lock_state().live_workers
    }

    /// Backend rebuilds of `class`'s pool so far.
    pub fn rebuilds(&self, class: RequestClass) -> u64 {
        self.pools[class.index()].shared.counters.rebuilds.load(Ordering::SeqCst)
    }

    /// Per-class snapshot: pool shape, event counters and the pool's
    /// serving metrics (admission rejects folded in).
    pub fn class_summary(&self, class: RequestClass) -> ClassSummary {
        let pool = &self.pools[class.index()];
        let sh = &pool.shared;
        let (workers, queue_depth) = {
            let st = sh.lock_state();
            (st.live_workers, st.queue.len())
        };
        let mut summary = sh.lock_metrics().summary();
        summary.rejected = sh.counters.rejected.load(Ordering::Relaxed);
        ClassSummary {
            class,
            backend: sh.label.lock().unwrap_or_else(|e| e.into_inner()).clone(),
            workers,
            min_workers: pool.scale.min_workers,
            max_workers: pool.scale.max_workers,
            spawned: sh.counters.spawned.load(Ordering::Relaxed),
            queue_depth,
            rebuilds: sh.counters.rebuilds.load(Ordering::SeqCst),
            retried: sh.counters.retried.load(Ordering::Relaxed),
            shed_retry: sh.counters.shed_retry.load(Ordering::Relaxed),
            scale_up: sh.counters.scale_up.load(Ordering::Relaxed),
            scale_down: sh.counters.scale_down.load(Ordering::Relaxed),
            summary,
        }
    }

    /// Whole-fleet snapshot, one entry per class in `RequestClass::ALL`
    /// order.
    pub fn summary(&self) -> FleetSummary {
        FleetSummary {
            classes: RequestClass::ALL.iter().map(|&c| self.class_summary(c)).collect(),
        }
    }

    /// Fleet-wide metrics merged across both pools — the shape
    /// `Server::metrics` reports regardless of front end.
    pub fn metrics(&self) -> MetricsSummary {
        let parts: Vec<MetricsSummary> =
            RequestClass::ALL.iter().map(|&c| self.class_summary(c).summary).collect();
        MetricsSummary::merged(&parts)
    }

    /// Total admission rejects across both pools.
    pub fn rejected(&self) -> u64 {
        self.pools
            .iter()
            .map(|p| p.shared.counters.rejected.load(Ordering::Relaxed))
            .sum()
    }

    /// Stop both pools: supervisors first (no new scale events), then
    /// close the queues — workers drain what's already enqueued, then
    /// exit — and finally resolve anything still queued (all workers
    /// dead) as [`ServeError::Shutdown`], so no ticket ever hangs.
    pub fn shutdown(mut self) {
        for pool in &mut self.pools {
            pool.stop.store(true, Ordering::SeqCst);
            if let Some(s) = pool.supervisor.take() {
                let _ = s.join();
            }
        }
        for pool in &self.pools {
            let mut st = pool.shared.lock_state();
            st.open = false;
            drop(st);
            pool.shared.cv.notify_all();
        }
        for pool in &self.pools {
            let handles: Vec<_> = {
                let mut h = pool.handles.lock().unwrap_or_else(|e| e.into_inner());
                h.drain(..).collect()
            };
            for h in handles {
                let _ = h.join();
            }
            // every worker may have died (rebuild failure): requests
            // still queued must resolve, not hang their callers
            let mut st = pool.shared.lock_state();
            while let Some(r) = st.queue.pop_front() {
                let _ = r.resp.send(Err(ServeError::Shutdown));
            }
        }
    }
}

/// Build one pool: eager backends for the `min_workers` floor (factory
/// errors surface here), worker threads, and the supervisor.
fn spawn_pool(
    class: RequestClass,
    factory: BackendFactory,
    ops_per_image: u64,
    cfg: &FleetConfig,
    scale: PoolScale,
) -> anyhow::Result<Pool> {
    let scale = PoolScale {
        min_workers: scale.min_workers.max(1),
        max_workers: scale.max_workers.max(scale.min_workers.max(1)),
    };
    let shared = Arc::new(PoolShared {
        class,
        state: Mutex::new(PoolState {
            queue: VecDeque::new(),
            open: true,
            retire: 0,
            live_workers: 0,
            next_worker_id: 0,
        }),
        cv: Condvar::new(),
        metrics: Mutex::new(Metrics::new(ops_per_image)),
        counters: PoolCounters::default(),
        label: Mutex::new(String::new()),
        max_batch: cfg.max_batch.max(1),
        max_wait: cfg.max_wait,
        queue_depth: cfg.queue_depth.max(1),
        retry_budget: cfg.retry_budget,
        rebuild_backoff: cfg.rebuild_backoff.max(Duration::from_micros(100)),
    });
    let handles = Arc::new(Mutex::new(Vec::new()));

    for i in 0..scale.min_workers {
        let backend = factory().map_err(|e| {
            e.context(format!("building the {} backend for fleet worker {i}", class.label()))
        })?;
        if i == 0 {
            *shared.label.lock().unwrap_or_else(|e| e.into_inner()) = backend.name().to_string();
        }
        let h = spawn_worker(&shared, &factory, backend);
        handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
    }

    let stop = Arc::new(AtomicBool::new(false));
    let supervisor = {
        let shared = shared.clone();
        let factory = factory.clone();
        let handles = handles.clone();
        let stop = stop.clone();
        let knobs = (cfg.scale_tick, cfg.high_water.max(1), cfg.up_ticks.max(1), cfg.idle_ticks.max(1));
        std::thread::Builder::new()
            .name(format!("lutmul-fleet-{}-supervisor", class.label()))
            .spawn(move || supervisor_loop(shared, factory, handles, stop, scale, knobs))
            .expect("spawn fleet supervisor")
    };

    Ok(Pool { shared, factory, handles, supervisor: Some(supervisor), stop, scale })
}

/// Register a new worker under the state lock and start its thread.
fn spawn_worker(
    shared: &Arc<PoolShared>,
    factory: &BackendFactory,
    backend: Box<dyn InferenceBackend>,
) -> std::thread::JoinHandle<()> {
    let wid = {
        let mut st = shared.lock_state();
        let wid = st.next_worker_id;
        st.next_worker_id += 1;
        st.live_workers += 1;
        wid
    };
    shared.counters.spawned.fetch_add(1, Ordering::Relaxed);
    let shared = shared.clone();
    let factory = factory.clone();
    std::thread::Builder::new()
        .name(format!("lutmul-fleet-{}-{wid}", shared.class.label()))
        .spawn(move || worker_loop(shared, factory, backend, wid))
        .expect("spawn fleet worker")
}

/// Worker body: pull → window-batch → shed → execute → resolve, with
/// the drain/retry/rebuild failure path. Mirrors the S21 worker's
/// metrics discipline (one lock per batch, banked shard counters) over
/// the pull-based queue.
fn worker_loop(
    shared: Arc<PoolShared>,
    factory: BackendFactory,
    mut backend: Box<dyn InferenceBackend>,
    wid: usize,
) {
    // counters of backends this worker already retired (rebuilt after a
    // failed batch): folded into every later snapshot so this worker's
    // recorded shard metrics never roll backwards
    let mut shard_base: Vec<ShardOccupancy> = Vec::new();

    // banks the dying/retiring backend's counters and records the
    // worker's final/current cumulative snapshot
    let bank = |shard_base: &mut Vec<ShardOccupancy>, backend: &dyn InferenceBackend| {
        let snap = backend.shard_occupancy();
        if shard_base.len() < snap.len() {
            shard_base.resize(snap.len(), ShardOccupancy::default());
        }
        for (b, s) in shard_base.iter_mut().zip(&snap) {
            b.absorb(s);
        }
    };

    'serve: loop {
        // ---- pull the first request (or exit on retire/close) ----
        let mut batch: Vec<FleetRequest> = Vec::new();
        {
            let mut st = shared.lock_state();
            loop {
                if let Some(r) = st.queue.pop_front() {
                    batch.push(r);
                    break;
                }
                if st.retire > 0 {
                    // drain-then-retire: only ever honored on an empty
                    // queue, so retirement never abandons traffic
                    st.retire -= 1;
                    st.live_workers -= 1;
                    drop(st);
                    bank(&mut shard_base, backend.as_ref());
                    if !shard_base.is_empty() {
                        shared.lock_metrics().record_shards(wid, shard_base.clone());
                    }
                    return;
                }
                if !st.open {
                    st.live_workers -= 1;
                    drop(st);
                    bank(&mut shard_base, backend.as_ref());
                    if !shard_base.is_empty() {
                        shared.lock_metrics().record_shards(wid, shard_base.clone());
                    }
                    return;
                }
                st = shared.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }

            // ---- batch window: ride along until full or timed out ----
            let window_end = Instant::now() + shared.max_wait;
            while batch.len() < shared.max_batch {
                if let Some(r) = st.queue.pop_front() {
                    batch.push(r);
                    continue;
                }
                if !st.open || st.retire > 0 {
                    // don't hold the window open through a shutdown or
                    // a pending retire order
                    break;
                }
                let now = Instant::now();
                if now >= window_end {
                    break;
                }
                let (g, timeout) = shared
                    .cv
                    .wait_timeout(st, window_end - now)
                    .unwrap_or_else(|e| e.into_inner());
                st = g;
                if timeout.timed_out() {
                    if let Some(r) = st.queue.pop_front() {
                        batch.push(r);
                    }
                    break;
                }
            }
        }

        // ---- shed expired deadlines before compute (S21 semantics) ----
        let now = Instant::now();
        let mut reqs = Vec::with_capacity(batch.len());
        let mut shed = 0usize;
        for r in batch {
            match r.deadline {
                Some(d) if now >= d => {
                    let waited_us = now.duration_since(r.enqueued).as_micros() as u64;
                    let _ = r.resp.send(Err(ServeError::DeadlineExceeded { waited_us }));
                    shed += 1;
                }
                _ => reqs.push(r),
            }
        }
        if shed > 0 {
            shared.lock_metrics().record_shed(shed);
        }
        if reqs.is_empty() {
            continue;
        }

        // ---- execute (with the chaos seam armed as a device death) ----
        let images: Vec<Vec<i32>> = reqs.iter().map(|r| r.image.clone()).collect();
        let killed = shared
            .counters
            .kill_next
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
            .is_ok();
        let t_exec = Instant::now();
        let res = if killed {
            Err(anyhow::anyhow!("injected chaos kill (device died mid-batch)"))
        } else {
            backend.infer_batch(&images)
        };
        let out = match res {
            Ok(out) if out.logits.len() == reqs.len() => out,
            res => {
                // drain: bank the dead backend's counters, re-enqueue
                // within budget (front, original order), shed the rest
                // with the typed status, then rebuild under backoff
                let msg = match &res {
                    Ok(out) => format!(
                        "backend returned {} results for {} requests",
                        out.logits.len(),
                        reqs.len()
                    ),
                    Err(e) => e.to_string(),
                };
                eprintln!(
                    "lutmul-fleet-{}-{wid}: batch failed ({msg}); draining and rebuilding",
                    shared.class.label()
                );
                bank(&mut shard_base, backend.as_ref());
                if !shard_base.is_empty() {
                    shared.lock_metrics().record_shards(wid, shard_base.clone());
                }

                let mut retry: Vec<FleetRequest> = Vec::new();
                let mut exhausted = 0usize;
                for mut r in reqs {
                    let failures = r.attempts + 1;
                    if failures <= shared.retry_budget {
                        r.attempts = failures;
                        retry.push(r);
                    } else {
                        let _ = r
                            .resp
                            .send(Err(ServeError::RetriesExhausted { attempts: failures }));
                        exhausted += 1;
                    }
                }
                if !retry.is_empty() {
                    shared.counters.retried.fetch_add(retry.len() as u64, Ordering::Relaxed);
                    let mut st = shared.lock_state();
                    for r in retry.into_iter().rev() {
                        st.queue.push_front(r);
                    }
                    drop(st);
                    shared.cv.notify_all();
                }
                if exhausted > 0 {
                    shared.counters.shed_retry.fetch_add(exhausted as u64, Ordering::Relaxed);
                    shared.lock_metrics().record_failed(exhausted);
                }

                shared.counters.rebuilds.fetch_add(1, Ordering::SeqCst);
                let mut delay = shared.rebuild_backoff;
                let mut tries = 0u32;
                loop {
                    match factory() {
                        Ok(b) => {
                            backend = b;
                            continue 'serve;
                        }
                        Err(e) => {
                            tries += 1;
                            let open = shared.lock_state().open;
                            if tries >= 8 || !open {
                                eprintln!(
                                    "lutmul-fleet-{}-{wid}: backend rebuild failed \
                                     ({e}); worker exiting",
                                    shared.class.label()
                                );
                                let mut st = shared.lock_state();
                                st.live_workers -= 1;
                                drop(st);
                                // wake peers/shutdown waiting on this pool
                                shared.cv.notify_all();
                                return;
                            }
                            std::thread::sleep(delay);
                            delay = (delay * 2).min(shared.rebuild_backoff * 64);
                        }
                    }
                }
            }
        };

        // ---- success: metrics then resolution, one lock per batch ----
        let service = t_exec.elapsed();
        let latencies: Vec<Duration> = reqs.iter().map(|r| r.enqueued.elapsed()).collect();
        {
            let mut m = shared.lock_metrics();
            m.record_batch(reqs.len(), service);
            for (&l, r) in latencies.iter().zip(&reqs) {
                m.record_split(l, t_exec.duration_since(r.enqueued), service);
            }
            if !out.counters.is_empty() {
                let mut snap = out.counters;
                for (s, b) in snap.iter_mut().zip(&shard_base) {
                    s.absorb(b);
                }
                m.record_shards(wid, snap);
            }
        }
        for ((r, logits), latency) in reqs.into_iter().zip(out.logits).zip(latencies) {
            let class = argmax(&logits);
            let _ = r.resp.send(Ok(InferenceResult { logits, class, latency }));
        }
    }
}

/// Supervisor body: depth-driven scale-up, idle-driven retire orders,
/// and respawn below the floor.
fn supervisor_loop(
    shared: Arc<PoolShared>,
    factory: BackendFactory,
    handles: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
    scale: PoolScale,
    (tick, high_water, up_ticks, idle_ticks): (Duration, usize, u32, u32),
) {
    let mut hot = 0u32;
    let mut idle = 0u32;
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let (depth, live, retiring, open) = {
            let st = shared.lock_state();
            (st.queue.len(), st.live_workers, st.retire, st.open)
        };
        if !open {
            break;
        }

        // supervised recovery: a worker that died permanently (rebuild
        // exhausted) is replaced up to the floor, not counted as an
        // autoscale event
        if live.saturating_sub(retiring) < scale.min_workers {
            match factory() {
                Ok(b) => {
                    let h = spawn_worker(&shared, &factory, b);
                    handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
                }
                Err(e) => eprintln!(
                    "lutmul-fleet-{}-supervisor: respawn build failed: {e}",
                    shared.class.label()
                ),
            }
            continue;
        }

        if depth > high_water {
            idle = 0;
            hot += 1;
            if retiring > 0 {
                // a hot queue cancels pending (unconsumed) retire orders
                shared.lock_state().retire = 0;
            }
            if hot >= up_ticks && live < scale.max_workers {
                hot = 0;
                match factory() {
                    Ok(b) => {
                        let h = spawn_worker(&shared, &factory, b);
                        handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
                        shared.counters.scale_up.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => eprintln!(
                        "lutmul-fleet-{}-supervisor: scale-up build failed: {e}",
                        shared.class.label()
                    ),
                }
            }
        } else if depth == 0 {
            hot = 0;
            idle += 1;
            if idle >= idle_ticks && live.saturating_sub(retiring) > scale.min_workers {
                idle = 0;
                let mut st = shared.lock_state();
                st.retire += 1;
                drop(st);
                shared.cv.notify_all();
                shared.counters.scale_down.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            hot = 0;
            idle = 0;
        }
    }
}

/// Per-class snapshot for reporting: pool shape, event counters and
/// serving metrics.
#[derive(Debug, Clone)]
pub struct ClassSummary {
    pub class: RequestClass,
    /// Backend name of the pool's first built backend (e.g. "executor",
    /// "sharded x2").
    pub backend: String,
    /// Live workers right now.
    pub workers: usize,
    pub min_workers: usize,
    pub max_workers: usize,
    /// Workers ever spawned (initial + scale-up + respawn).
    pub spawned: u64,
    /// Current queue depth.
    pub queue_depth: usize,
    /// Backend rebuilds after failed batches.
    pub rebuilds: u64,
    /// Requests re-enqueued from failed batches (within budget).
    pub retried: u64,
    /// Requests shed with `RetriesExhausted`.
    pub shed_retry: u64,
    pub scale_up: u64,
    pub scale_down: u64,
    /// The pool's serving metrics (admission rejects folded in).
    pub summary: MetricsSummary,
}

impl std::fmt::Display for ClassSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{}] backend {} | workers {}/{}..{} (spawned {}) | queue {} | \
             rebuilds {} retried {} shed_retry {} | scale +{} -{} | {}",
            self.class,
            self.backend,
            self.workers,
            self.min_workers,
            self.max_workers,
            self.spawned,
            self.queue_depth,
            self.rebuilds,
            self.retried,
            self.shed_retry,
            self.scale_up,
            self.scale_down,
            self.summary
        )
    }
}

/// Whole-fleet snapshot, one entry per class.
#[derive(Debug, Clone)]
pub struct FleetSummary {
    pub classes: Vec<ClassSummary>,
}

impl FleetSummary {
    /// Look up one class's entry.
    pub fn class(&self, class: RequestClass) -> Option<&ClassSummary> {
        self.classes.iter().find(|c| c.class == class)
    }

    /// Total autoscale events (up + down) across the fleet.
    pub fn scale_events(&self) -> u64 {
        self.classes.iter().map(|c| c.scale_up + c.scale_down).sum()
    }

    /// Total backend rebuilds across the fleet.
    pub fn rebuilds(&self) -> u64 {
        self.classes.iter().map(|c| c.rebuilds).sum()
    }
}

impl std::fmt::Display for FleetSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, c) in self.classes.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{c}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_class_round_trips() {
        for c in RequestClass::ALL {
            assert_eq!(RequestClass::from_u8(c as u8), Some(c));
            assert_eq!(RequestClass::parse(c.label()), Some(c));
            assert_eq!(RequestClass::parse(&(c as u8).to_string()), Some(c));
        }
        assert_eq!(RequestClass::from_u8(7), None);
        assert_eq!(RequestClass::parse("bulk"), None);
        assert_eq!(RequestClass::parse("LATENCY"), Some(RequestClass::Latency));
    }

    #[test]
    fn default_config_sane() {
        let c = FleetConfig::default();
        assert!(c.latency.min_workers >= 1);
        assert!(c.latency.max_workers >= c.latency.min_workers);
        assert!(c.throughput.max_workers >= c.throughput.min_workers);
        assert!(c.retry_budget >= 1 && c.max_batch >= 1);
    }

    // Fleet round-trips, chaos drains, autoscale traces and the
    // shutdown races live in rust/tests/fleet.rs (they need injected
    // backends and, for routing, a full engine).
}
