//! Serving metrics: throughput, latency percentiles split into queue
//! wait vs backend compute (DESIGN.md S21), GOPS, per-batch dispatch
//! statistics (batch-size histogram + batch service-time percentiles)
//! for the batch-major execution path (EXPERIMENTS.md E9), shed/failed
//! request accounting for deadline-aware admission, and per-shard
//! occupancy/stall counters for the sharded backend (DESIGN.md S18).
//! Workers feed the shard counters from `BatchOutput::counters` —
//! whatever `InferenceBackend` reports them (DESIGN.md S19).
//!
//! Every counter in here is cumulative over the coordinator's lifetime,
//! so successive [`MetricsSummary`] snapshots are monotonic by
//! construction — the chaos suite (`rust/tests/chaos.rs`) asserts that
//! invariant survives worker failures and rebuilds.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Cumulative occupancy/stall counters for one shard of a sharded
/// backend's chain, plus its egress link (zeroed for the tail shard,
/// which has no downstream link) — the same record the chain itself
/// reports (`dataflow::ShardCounters`), re-exported under the serving
/// tier's name.
pub use crate::dataflow::pipeline::ShardCounters as ShardOccupancy;

/// Online latency/throughput recorder shared by the serving workers.
#[derive(Debug)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    /// Per-request time from submit to worker dispatch (queueing +
    /// batch-forming window).
    queue_us: Vec<u64>,
    /// Per-request backend service time of the batch the request rode in
    /// (the `infer_batch` call alone).
    compute_us: Vec<u64>,
    started: Instant,
    completed: u64,
    /// Requests shed before compute because their deadline had already
    /// expired (DESIGN.md S21 admission control).
    shed_deadline: u64,
    /// Requests that resolved with a structured worker/backend failure
    /// (the backend was rebuilt through the factory afterwards).
    failed: u64,
    ops_per_image: u64,
    /// Size of every dispatched batch, in dispatch order.
    batch_sizes: Vec<usize>,
    /// Backend service time per dispatched batch (queueing excluded).
    batch_service_us: Vec<u64>,
    /// Latest cumulative per-shard snapshot of each sharded worker's
    /// chain, keyed by worker index (empty for whole-network backends);
    /// summaries aggregate across workers per shard index.
    shards_by_worker: BTreeMap<usize, Vec<ShardOccupancy>>,
}

impl Metrics {
    pub fn new(ops_per_image: u64) -> Self {
        Self {
            latencies_us: Vec::new(),
            queue_us: Vec::new(),
            compute_us: Vec::new(),
            started: Instant::now(),
            completed: 0,
            shed_deadline: 0,
            failed: 0,
            ops_per_image,
            batch_sizes: Vec::new(),
            batch_service_us: Vec::new(),
            shards_by_worker: BTreeMap::new(),
        }
    }

    pub fn record(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_micros() as u64);
        self.completed += 1;
    }

    /// Record one completed request's latency split: total submit-to-done
    /// `latency`, the queue/window share `queue`, and the backend service
    /// share `compute` (the batch's `infer_batch` time).
    pub fn record_split(&mut self, latency: Duration, queue: Duration, compute: Duration) {
        self.record(latency);
        self.queue_us.push(queue.as_micros() as u64);
        self.compute_us.push(compute.as_micros() as u64);
    }

    /// Record `n` requests shed before compute on an expired deadline.
    pub fn record_shed(&mut self, n: usize) {
        self.shed_deadline += n as u64;
    }

    /// Record `n` requests that resolved with a worker/backend failure.
    pub fn record_failed(&mut self, n: usize) {
        self.failed += n as u64;
    }

    /// Record one dispatched batch: its size and the backend service time
    /// (the `run_batch` call alone, not the queueing ahead of it).
    pub fn record_batch(&mut self, size: usize, service: Duration) {
        self.batch_sizes.push(size);
        self.batch_service_us.push(service.as_micros() as u64);
    }

    /// Replace worker `worker`'s per-shard snapshot with its latest
    /// cumulative counters. Counters grow over a worker's lifetime, so
    /// the newest snapshot subsumes that worker's older ones; snapshots
    /// are keyed per worker so a pool of sharded workers aggregates
    /// instead of clobbering each other.
    pub fn record_shards(&mut self, worker: usize, snapshot: Vec<ShardOccupancy>) {
        self.shards_by_worker.insert(worker, snapshot);
    }

    /// Per-shard occupancy aggregated across the worker pool (empty
    /// without a sharded backend): counters sum, high-water marks max.
    pub fn shards(&self) -> Vec<ShardOccupancy> {
        let n = self.shards_by_worker.values().map(Vec::len).max().unwrap_or(0);
        let mut agg = vec![ShardOccupancy::default(); n];
        for snapshot in self.shards_by_worker.values() {
            for (a, s) in agg.iter_mut().zip(snapshot) {
                a.absorb(s);
            }
        }
        agg
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Requests shed before compute on an expired deadline.
    pub fn shed_deadline(&self) -> u64 {
        self.shed_deadline
    }

    /// Requests resolved with a structured worker failure.
    pub fn failed(&self) -> u64 {
        self.failed
    }

    /// Number of batches dispatched to workers.
    pub fn batches(&self) -> u64 {
        self.batch_sizes.len() as u64
    }

    /// Mean images per dispatched batch (0 if none yet).
    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Histogram of dispatched batch sizes: `(size, count)` ascending.
    pub fn batch_histogram(&self) -> Vec<(usize, u64)> {
        let mut hist: std::collections::BTreeMap<usize, u64> = std::collections::BTreeMap::new();
        for &s in &self.batch_sizes {
            *hist.entry(s).or_insert(0) += 1;
        }
        hist.into_iter().collect()
    }

    /// Log2-bucketed histogram of end-to-end latencies:
    /// `(bucket_upper_us, count)` ascending, empty buckets skipped. The
    /// loadgen table prints the same shape client-side, so server- and
    /// client-observed tails compare bucket for bucket.
    pub fn latency_histogram(&self) -> Vec<(u64, u64)> {
        log2_histogram(&self.latencies_us)
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Achieved GOPS (model ops x images / wall time).
    pub fn gops(&self) -> f64 {
        self.throughput_rps() * self.ops_per_image as f64 / 1e9
    }

    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile(&self.latencies_us, p)
    }

    /// Percentile over per-request queue/window wait times.
    pub fn queue_percentile_us(&self, p: f64) -> u64 {
        percentile(&self.queue_us, p)
    }

    /// Percentile over per-request backend compute times.
    pub fn compute_percentile_us(&self, p: f64) -> u64 {
        percentile(&self.compute_us, p)
    }

    /// Percentile over per-batch backend service times.
    pub fn batch_service_percentile_us(&self, p: f64) -> u64 {
        percentile(&self.batch_service_us, p)
    }

    pub fn summary(&self) -> MetricsSummary {
        // single elapsed sample so gops/throughput stay consistent
        let thr = self.throughput_rps();
        MetricsSummary {
            completed: self.completed,
            shed_deadline: self.shed_deadline,
            failed: self.failed,
            rejected: 0, // the coordinator owns the admission counter
            throughput_rps: thr,
            gops: thr * self.ops_per_image as f64 / 1e9,
            p50_us: self.percentile_us(50.0),
            p99_us: self.percentile_us(99.0),
            queue_p50_us: self.queue_percentile_us(50.0),
            queue_p99_us: self.queue_percentile_us(99.0),
            compute_p50_us: self.compute_percentile_us(50.0),
            compute_p99_us: self.compute_percentile_us(99.0),
            batches: self.batches(),
            mean_batch: self.mean_batch(),
            batch_p50_us: self.batch_service_percentile_us(50.0),
            batch_p99_us: self.batch_service_percentile_us(99.0),
            shards: self.shards(),
        }
    }
}

/// Nearest-rank percentile of an unsorted sample (0 when empty).
fn percentile(samples: &[u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Log2 buckets over microsecond samples: `(bucket_upper_us, count)`
/// ascending with empty buckets skipped. Shared by the server metrics
/// and the loadgen's client-side table.
pub fn log2_histogram(samples_us: &[u64]) -> Vec<(u64, u64)> {
    let mut hist: BTreeMap<u64, u64> = BTreeMap::new();
    for &s in samples_us {
        // bucket upper bound: the next power of two at or above s (1 us
        // minimum so zero-latency samples land in a real bucket)
        let upper = s.max(1).next_power_of_two();
        *hist.entry(upper).or_insert(0) += 1;
    }
    hist.into_iter().collect()
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub completed: u64,
    /// Requests shed before compute on an expired deadline.
    pub shed_deadline: u64,
    /// Requests resolved with a structured worker/backend failure.
    pub failed: u64,
    /// Requests bounced at admission (queue full) — filled in by the
    /// coordinator, which owns the atomic counter.
    pub rejected: u64,
    pub throughput_rps: f64,
    pub gops: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// p50 of per-request queue/batch-window wait.
    pub queue_p50_us: u64,
    /// p99 of per-request queue/batch-window wait.
    pub queue_p99_us: u64,
    /// p50 of per-request backend compute share.
    pub compute_p50_us: u64,
    /// p99 of per-request backend compute share.
    pub compute_p99_us: u64,
    /// Batches dispatched to workers.
    pub batches: u64,
    /// Mean images per dispatched batch.
    pub mean_batch: f64,
    /// p50 of per-batch backend service time.
    pub batch_p50_us: u64,
    /// p99 of per-batch backend service time.
    pub batch_p99_us: u64,
    /// Per-shard occupancy/stall counters aggregated across the worker
    /// pool (sharded backend only).
    pub shards: Vec<ShardOccupancy>,
}

impl MetricsSummary {
    /// Merge per-pool snapshots into one fleet-wide view (DESIGN.md
    /// S25): counters and rates sum, latency percentiles take the
    /// conservative max across pools (a true merged percentile would
    /// need the raw samples), mean batch size weights by batch count,
    /// and shard lists concatenate so every chain stays visible.
    pub fn merged(parts: &[MetricsSummary]) -> MetricsSummary {
        let mut out = MetricsSummary {
            completed: 0,
            shed_deadline: 0,
            failed: 0,
            rejected: 0,
            throughput_rps: 0.0,
            gops: 0.0,
            p50_us: 0,
            p99_us: 0,
            queue_p50_us: 0,
            queue_p99_us: 0,
            compute_p50_us: 0,
            compute_p99_us: 0,
            batches: 0,
            mean_batch: 0.0,
            batch_p50_us: 0,
            batch_p99_us: 0,
            shards: Vec::new(),
        };
        let mut weighted_batch = 0.0;
        for p in parts {
            out.completed += p.completed;
            out.shed_deadline += p.shed_deadline;
            out.failed += p.failed;
            out.rejected += p.rejected;
            out.throughput_rps += p.throughput_rps;
            out.gops += p.gops;
            out.p50_us = out.p50_us.max(p.p50_us);
            out.p99_us = out.p99_us.max(p.p99_us);
            out.queue_p50_us = out.queue_p50_us.max(p.queue_p50_us);
            out.queue_p99_us = out.queue_p99_us.max(p.queue_p99_us);
            out.compute_p50_us = out.compute_p50_us.max(p.compute_p50_us);
            out.compute_p99_us = out.compute_p99_us.max(p.compute_p99_us);
            out.batches += p.batches;
            weighted_batch += p.mean_batch * p.batches as f64;
            out.batch_p50_us = out.batch_p50_us.max(p.batch_p50_us);
            out.batch_p99_us = out.batch_p99_us.max(p.batch_p99_us);
            out.shards.extend(p.shards.iter().cloned());
        }
        if out.batches > 0 {
            out.mean_batch = weighted_batch / out.batches as f64;
        }
        out
    }
}

impl std::fmt::Display for MetricsSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} reqs | {:.1} req/s | {:.2} GOPS | p50 {} us | p99 {} us (queue {}/{} us, compute {}/{} us) | {} batches (mean {:.1} img) | batch service p50 {} us p99 {} us",
            self.completed,
            self.throughput_rps,
            self.gops,
            self.p50_us,
            self.p99_us,
            self.queue_p50_us,
            self.queue_p99_us,
            self.compute_p50_us,
            self.compute_p99_us,
            self.batches,
            self.mean_batch,
            self.batch_p50_us,
            self.batch_p99_us
        )?;
        if self.shed_deadline > 0 || self.rejected > 0 || self.failed > 0 {
            write!(
                f,
                " | shed {} | rejected {} | failed {}",
                self.shed_deadline, self.rejected, self.failed
            )?;
        }
        for (i, s) in self.shards.iter().enumerate() {
            write!(
                f,
                " | shard{i} {} fires, {} stall cy, fifo hw {}, link busy {} cy stall {} cy",
                s.fires, s.stalled_cycles, s.fifo_high_water, s.link_busy_cycles,
                s.link_stalled_cycles
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new(1000);
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i));
        }
        assert_eq!(m.completed(), 100);
        let p50 = m.percentile_us(50.0);
        assert!((49..=51).contains(&p50), "p50 {p50}");
        let p99 = m.percentile_us(99.0);
        assert!((98..=100).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new(1);
        assert_eq!(m.percentile_us(99.0), 0);
        assert_eq!(m.completed(), 0);
        assert_eq!(m.batches(), 0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.batch_service_percentile_us(99.0), 0);
        assert_eq!(m.queue_percentile_us(99.0), 0);
        assert_eq!(m.compute_percentile_us(99.0), 0);
        assert_eq!(m.shed_deadline(), 0);
        assert_eq!(m.failed(), 0);
        assert!(m.batch_histogram().is_empty());
        assert!(m.latency_histogram().is_empty());
    }

    #[test]
    fn gops_proportional_to_ops() {
        let mut a = Metrics::new(1_000_000);
        let mut b = Metrics::new(2_000_000);
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(5));
        // gops/throughput is exactly ops/1e9 within one summary snapshot
        let sa = a.summary();
        let sb = b.summary();
        let ra = sa.gops / sa.throughput_rps;
        let rb = sb.gops / sb.throughput_rps;
        assert!((rb / ra - 2.0).abs() < 1e-9);
    }

    #[test]
    fn batch_stats_track_dispatches() {
        let mut m = Metrics::new(1);
        m.record_batch(8, Duration::from_micros(400));
        m.record_batch(8, Duration::from_micros(600));
        m.record_batch(4, Duration::from_micros(100));
        assert_eq!(m.batches(), 3);
        assert!((m.mean_batch() - 20.0 / 3.0).abs() < 1e-9);
        assert_eq!(m.batch_histogram(), vec![(4, 1), (8, 2)]);
        let s = m.summary();
        assert_eq!(s.batches, 3);
        assert_eq!(s.batch_p50_us, 400);
        assert_eq!(s.batch_p99_us, 600);
        // summary line mentions the batch stats
        assert!(s.to_string().contains("3 batches"));
    }

    #[test]
    fn split_and_shed_counters() {
        let mut m = Metrics::new(1);
        m.record_split(
            Duration::from_micros(300),
            Duration::from_micros(200),
            Duration::from_micros(100),
        );
        m.record_split(
            Duration::from_micros(500),
            Duration::from_micros(440),
            Duration::from_micros(60),
        );
        m.record_shed(3);
        m.record_failed(2);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.shed_deadline(), 3);
        assert_eq!(m.failed(), 2);
        let s = m.summary();
        assert_eq!(s.queue_p99_us, 440);
        assert_eq!(s.compute_p99_us, 100);
        assert_eq!(s.queue_p50_us, 200);
        assert_eq!(s.compute_p50_us, 60);
        assert_eq!(s.shed_deadline, 3);
        assert_eq!(s.failed, 2);
        assert_eq!(s.rejected, 0, "rejected is the coordinator's to fill");
        let line = s.to_string();
        assert!(line.contains("queue 200/440 us"), "{line}");
        assert!(line.contains("shed 3"), "{line}");
        assert!(line.contains("failed 2"), "{line}");
    }

    #[test]
    fn log2_histogram_buckets() {
        assert!(log2_histogram(&[]).is_empty());
        let h = log2_histogram(&[0, 1, 2, 3, 5, 900, 1000, 1024]);
        // 0,1 -> 1; 2 -> 2; 3 -> 4; 5 -> 8; 900,1000,1024 -> 1024
        assert_eq!(h, vec![(1, 2), (2, 1), (4, 1), (8, 1), (1024, 3)]);
        let mut m = Metrics::new(1);
        m.record(Duration::from_micros(3));
        m.record(Duration::from_micros(700));
        assert_eq!(m.latency_histogram(), vec![(4, 1), (1024, 1)]);
    }

    #[test]
    fn merged_summaries_sum_counts_and_max_tails() {
        let mut a = Metrics::new(1);
        a.record_batch(4, Duration::from_micros(100));
        a.record_split(
            Duration::from_micros(300),
            Duration::from_micros(200),
            Duration::from_micros(100),
        );
        a.record_shed(1);
        let mut b = Metrics::new(1);
        b.record_batch(8, Duration::from_micros(900));
        b.record_split(
            Duration::from_micros(1000),
            Duration::from_micros(100),
            Duration::from_micros(900),
        );
        b.record_failed(2);
        b.record_shards(0, vec![ShardOccupancy { fires: 5, ..Default::default() }]);
        let mut sa = a.summary();
        sa.rejected = 3;
        let sb = b.summary();
        let m = MetricsSummary::merged(&[sa, sb]);
        assert_eq!(m.completed, 2);
        assert_eq!(m.shed_deadline, 1);
        assert_eq!(m.failed, 2);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.p99_us, 1000, "tails take the max across pools");
        assert_eq!(m.batch_p99_us, 900);
        assert_eq!(m.batches, 2);
        assert!((m.mean_batch - 6.0).abs() < 1e-9, "mean weights by batches");
        assert_eq!(m.shards.len(), 1, "shard lists concatenate");
        assert!(MetricsSummary::merged(&[]).completed == 0);
    }

    #[test]
    fn shard_snapshots_aggregate_per_worker() {
        let mut m = Metrics::new(1);
        assert!(m.shards().is_empty());
        m.record_shards(0, vec![ShardOccupancy { fires: 10, ..Default::default() }]);
        // chain counters are cumulative, so a worker's newer snapshot
        // subsumes its older one...
        m.record_shards(0, vec![
            ShardOccupancy { fires: 25, stalled_cycles: 3, fifo_high_water: 4, ..Default::default() },
            ShardOccupancy { fires: 7, link_busy_cycles: 40, ..Default::default() },
        ]);
        // ...while a second worker's chain aggregates instead of clobbering
        m.record_shards(1, vec![
            ShardOccupancy { fires: 5, fifo_high_water: 9, ..Default::default() },
            ShardOccupancy { fires: 2, link_busy_cycles: 10, ..Default::default() },
        ]);
        let agg = m.shards();
        assert_eq!(agg.len(), 2);
        assert_eq!(agg[0].fires, 30, "fires sum across workers");
        assert_eq!(agg[0].stalled_cycles, 3);
        assert_eq!(agg[0].fifo_high_water, 9, "high-water takes the max");
        assert_eq!(agg[1].link_busy_cycles, 50);
        let s = m.summary();
        assert_eq!(s.shards.len(), 2);
        assert!(s.to_string().contains("shard0 30 fires"), "{s}");
        assert!(s.to_string().contains("shard1 9 fires"), "{s}");
    }
}
