//! Serving metrics: throughput, latency percentiles, GOPS.

use std::time::{Duration, Instant};

/// Online latency/throughput recorder shared by the serving workers.
#[derive(Debug)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    started: Instant,
    completed: u64,
    ops_per_image: u64,
}

impl Metrics {
    pub fn new(ops_per_image: u64) -> Self {
        Self {
            latencies_us: Vec::new(),
            started: Instant::now(),
            completed: 0,
            ops_per_image,
        }
    }

    pub fn record(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_micros() as u64);
        self.completed += 1;
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Requests per second since construction.
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Achieved GOPS (model ops x images / wall time).
    pub fn gops(&self) -> f64 {
        self.throughput_rps() * self.ops_per_image as f64 / 1e9
    }

    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn summary(&self) -> MetricsSummary {
        // single elapsed sample so gops/throughput stay consistent
        let thr = self.throughput_rps();
        MetricsSummary {
            completed: self.completed,
            throughput_rps: thr,
            gops: thr * self.ops_per_image as f64 / 1e9,
            p50_us: self.percentile_us(50.0),
            p99_us: self.percentile_us(99.0),
        }
    }
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub completed: u64,
    pub throughput_rps: f64,
    pub gops: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl std::fmt::Display for MetricsSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} reqs | {:.1} req/s | {:.2} GOPS | p50 {} us | p99 {} us",
            self.completed, self.throughput_rps, self.gops, self.p50_us, self.p99_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new(1000);
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i));
        }
        assert_eq!(m.completed(), 100);
        let p50 = m.percentile_us(50.0);
        assert!((49..=51).contains(&p50), "p50 {p50}");
        let p99 = m.percentile_us(99.0);
        assert!((98..=100).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new(1);
        assert_eq!(m.percentile_us(99.0), 0);
        assert_eq!(m.completed(), 0);
    }

    #[test]
    fn gops_proportional_to_ops() {
        let mut a = Metrics::new(1_000_000);
        let mut b = Metrics::new(2_000_000);
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(5));
        // gops/throughput is exactly ops/1e9 within one summary snapshot
        let sa = a.summary();
        let sb = b.summary();
        let ra = sa.gops / sa.throughput_rps;
        let rb = sb.gops / sb.throughput_rps;
        assert!((rb / ra - 2.0).abs() < 1e-9);
    }
}
