//! L3 serving coordinator (DESIGN.md S10): request router, dynamic
//! batcher, worker pool, and metrics. Python is never on this path.

pub mod metrics;
pub mod server;

pub use metrics::{Metrics, MetricsSummary, ShardOccupancy};
pub use server::{argmax, run_batch, Backend, Coordinator, InferenceResult, ServeConfig};
