//! L3 serving coordinator (DESIGN.md S10): request router, dynamic
//! batcher, worker pool, and metrics. Python is never on this path.
//!
//! Workers drive boxed [`InferenceBackend`]s built by the engine
//! (DESIGN.md S19) — the coordinator has no backend-specific code of
//! its own.
//!
//! [`InferenceBackend`]: crate::engine::InferenceBackend

pub mod metrics;
pub mod server;

pub use metrics::{log2_histogram, Metrics, MetricsSummary, ShardOccupancy};
pub use server::{
    argmax, Coordinator, InferenceResult, ServeConfig, ServeError, SubmitError, Ticket,
};
