//! L3 serving coordinator (DESIGN.md S10): request router, dynamic
//! batcher, worker pool, and metrics. Python is never on this path.
//!
//! Workers drive boxed [`InferenceBackend`]s built by the engine
//! (DESIGN.md S19) — the coordinator has no backend-specific code of
//! its own. [`fleet`] (DESIGN.md S25) generalizes the single pool into
//! class-routed heterogeneous pools with autoscaling and supervised
//! drain-and-rebuild recovery.
//!
//! [`InferenceBackend`]: crate::engine::InferenceBackend

pub mod fleet;
pub mod metrics;
pub mod server;

pub use fleet::{ClassSummary, Fleet, FleetConfig, FleetSummary, PoolScale, RequestClass};
pub use metrics::{log2_histogram, Metrics, MetricsSummary, ShardOccupancy};
pub use server::{
    argmax, Coordinator, InferenceResult, ServeConfig, ServeError, SubmitError, Ticket,
};
