//! Compiled layer plans (DESIGN.md S17): the streamlined network IR
//! lowered ONCE at executor/pipeline construction time into flat,
//! indirection-free per-layer state.
//!
//! The reference executor used to interpret every conv scalar-by-scalar
//! with per-tap bounds checks and a per-multiply datapath branch. A
//! [`NetworkPlan`] removes all of that from the hot loop:
//!
//!  * weights and thresholds are flattened row-major;
//!  * im2row tap offsets are precomputed, with an **interior/border
//!    split**: output pixels whose whole window is in bounds index the
//!    input directly (no per-tap bounds check), only the border rim pays
//!    the zero-padded gather;
//!  * on the `LutFabric` datapath, every multiplier's product table is
//!    **read out of the simulated LUT6_2 primitives once at plan-build
//!    time** into an activation-major (column-major) layout
//!    ([`Multipliers::LutTables`], DESIGN.md S20) — same hardware-true
//!    INIT semantics as reading the fabric per MAC, memoized and
//!    transposed for contiguous column accumulation. The per-MAC
//!    readout survives as [`Multipliers::LutDirect`] (the
//!    pre-compilation baseline and equivalence witness; see
//!    `benches/bench_batch.rs` and `tests/plan.rs`), and the old
//!    MAC-major table layout as [`Multipliers::LutTablesMacMajor`]
//!    (the perf baseline of `benches/bench_kernels.rs`).
//!
//! The plan is the shared geometry source for the whole stack: the
//! executor runs kernels over it (`graph::kernels`), the dataflow
//! simulator builds its stages from it (`Pipeline::from_plan`), and the
//! runtime/coordinator read [`IoGeom`] instead of re-deriving shapes
//! from `Network::meta`. The engine (DESIGN.md S19) compiles a network
//! into one plan exactly once and constructs every `InferenceBackend`
//! over it, which is what makes cross-backend bit-exactness hold by
//! construction.

use crate::fabric::lutmul::ConstMultiplier;

use super::approx::{layer_seed, ApproxLayer, ApproxSpec};
use super::network::{ConvKind, Network, Op};
use super::prune::PruneSpec;

/// Multiply datapath selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    Arithmetic,
    /// Products come from simulated LUT6_2 fabric (w_bits <= 4 layers).
    LutFabric,
}

/// Input/output geometry of a deployed network — the plan-level view of
/// `Meta` that the runtime, coordinator and benches consume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoGeom {
    pub image_size: usize,
    pub in_ch: usize,
    pub num_classes: usize,
}

/// Spatial geometry of one conv layer, resolved at plan-compile time
/// (the simulator and executor agree on shapes by construction).
#[derive(Debug, Clone, Copy)]
pub struct ConvGeom {
    pub in_h: usize,
    pub in_w: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn in_pixels(&self) -> usize {
        self.in_h * self.in_w
    }

    pub fn out_pixels(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Interior output range `[lo, hi)` along one axis: outputs whose
    /// whole k-tap window lies inside `[0, n_in)`, so kernels can index
    /// the input directly with no per-tap bounds check.
    fn interior(&self, n_out: usize, n_in: usize) -> (usize, usize) {
        // o*stride - pad >= 0  and  o*stride - pad + k - 1 <= n_in - 1
        let lo = self.pad.div_ceil(self.stride);
        let hi = match (n_in + self.pad).checked_sub(self.k) {
            Some(top) => (top / self.stride + 1).min(n_out),
            None => 0,
        };
        (lo.min(hi), hi)
    }
}

/// The multiplier array of one compiled conv layer.
#[derive(Debug, Clone)]
pub enum Multipliers {
    /// Plain integer multiplies against `ConvPlan::wflat` — the
    /// `Arithmetic` datapath, and >4-bit layers on `LutFabric` (the
    /// paper keeps first/last 8-bit layers on DSP packing).
    Weights,
    /// Simulated LUT6_2 primitives read per multiply, one
    /// `ConstMultiplier` per *pair* of weights (Figure 5 packs two
    /// weights per `w_bits` LUT6). The un-memoized hardware-true path,
    /// kept as the plan-compilation baseline and equivalence witness.
    LutDirect { mults: Vec<ConstMultiplier> },
    /// Per-multiplier product tables read out of the same LUT6_2
    /// primitives once at plan-build time, laid out **activation-major
    /// (column-major)**: `products[(col * acts + act) * cout + row]`,
    /// where `cout` is the weight-row count (`ConvPlan::rows()` — the
    /// live rows of a pruned plan, `ConvGeom::cout` otherwise).
    /// Fixing a weight column and an activation code yields
    /// one *contiguous* `cout`-wide product column, so the conv kernels
    /// hoist the activation lookup per (tap, ci) and accumulate the
    /// whole output-channel vector with a vectorizable axpy — the
    /// LUT-GEMM access pattern. Bit-identical to `LutDirect` by
    /// construction — the table IS the memoized readout, transposed.
    LutTables {
        products: Vec<i32>,
        /// Activation codes per table (`2^w_bits`, 16 for 4-bit; the
        /// LUT path is gated on `in_bits <= w_bits` at plan build so
        /// runtime activations always fit the table).
        acts: usize,
        /// Physical LUT6 behind the tables (resource accounting).
        lut6: usize,
    },
    /// The pre-activation-major table layout,
    /// `products[(row * cols + col) * acts + act]`: every MAC does a
    /// strided gather keyed by its own activation, so the inner `cout`
    /// loop never vectorizes. Kept compilable
    /// ([`NetworkPlan::compile_mac_major`]) as the perf baseline the
    /// kernel bench gates against and as a second equivalence witness.
    LutTablesMacMajor {
        products: Vec<i32>,
        acts: usize,
        lut6: usize,
    },
    /// Maddness-style approximate codebook datapath (DESIGN.md S24):
    /// the column space is chunked into codebooks, each chunk's
    /// activation sub-patch hashes through a trained decision tree to a
    /// prototype code, and the precomputed weight-row x prototype dot
    /// products accumulate straight out of row-contiguous codebook
    /// tables — one axpy per *codebook* instead of one per column.
    /// Approximate by construction (bit-exact only in the saturated
    /// [`ApproxSpec`] configuration); compiled by
    /// [`NetworkPlan::compile_approx`] for std/pw `lut_ok` layers,
    /// everything else keeps its exact lowering.
    LutApprox { layer: ApproxLayer },
}

/// Which multiplier representation the plan lowering compiles LUT
/// layers to (see `NetworkPlan::compile` / `compile_direct` /
/// `compile_mac_major`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TableMode {
    /// Per-MAC simulated LUT6_2 readout (`Multipliers::LutDirect`).
    Direct,
    /// Memoized tables, MAC-major layout (the pre-PR baseline).
    MacMajor,
    /// Memoized tables, activation-major layout (the default).
    ActMajor,
}

/// Compaction record of a structurally pruned conv (DESIGN.md S23).
/// When present, the plan's weight matrix, transpose and product tables
/// cover only the **live** rows/columns: `wflat` is
/// `[live_rows.len()][live_cols.len()]` and every kernel index below
/// `rows()`/`cols` is a *compacted* index that this struct maps back to
/// the dense channel/column space.
#[derive(Debug, Clone)]
pub struct PruneInfo {
    /// Surviving output channels, ascending dense indices
    /// (`live_rows[r]` is the dense channel of compacted row `r`).
    pub live_rows: Vec<usize>,
    /// Pruned output channels with their constant output code: a fully
    /// masked channel accumulates 0, so its quantized output is
    /// `threshold(0, ch)` — a per-channel constant the sparse kernels
    /// splat instead of computing.
    pub pruned_rows: Vec<(usize, i32)>,
    /// Surviving weight columns (tap x cin for std/pw, taps for
    /// depthwise), ascending dense indices.
    pub live_cols: Vec<usize>,
    /// Column count of the dense (unpruned) weight matrix.
    pub dense_cols: usize,
}

/// One convolution lowered into flat, hot-loop-ready state.
#[derive(Debug, Clone)]
pub struct ConvPlan {
    pub name: String,
    pub kind: ConvKind,
    pub geom: ConvGeom,
    /// Row-major `[rows][cols]` flattened weight codes
    /// (`[COUT][K*K*CIN]` for std/pw, `[C][K*K]` for depthwise; the
    /// **live** rows/columns only when [`prune`](Self::prune) is set).
    pub wflat: Vec<i32>,
    /// `wflat` transposed, column-major `[cols][rows]`
    /// (`wflat_t[col * rows() + row]`): the batch-major arithmetic conv
    /// body (DESIGN.md S22) reads one contiguous row-count-wide weight
    /// column per (tap, ci) and scales it into every image's
    /// accumulator — the same access shape the activation-major LUT
    /// tables give the LUT datapath.
    pub wflat_t: Vec<i32>,
    /// Weight columns per row — the live column count under pruning
    /// (the dense count is `prune`'s `dense_cols`).
    pub cols: usize,
    pub mults: Multipliers,
    /// Row-major `[cout][levels]` flattened thresholds.
    pub thr_flat: Vec<i32>,
    pub levels: usize,
    pub signs: Vec<i32>,
    pub consts: Vec<i32>,
    /// Per-tap input element offsets for interior windows, relative to
    /// the window-origin pixel `(oy*stride - pad, ox*stride - pad)`:
    /// `tap_offsets[i*k + j] = (i*in_w + j) * cin`.
    pub tap_offsets: Vec<usize>,
    /// Interior output ranges `[lo, hi)` per axis (see
    /// [`ConvGeom::interior`]); outside them the border kernel gathers
    /// with zero padding.
    pub oy_interior: (usize, usize),
    pub ox_interior: (usize, usize),
    /// Images per inner batch tile of the batch-major kernels
    /// (DESIGN.md S22): the largest power of two (≤ 16) whose
    /// `[tile][cout]` i32 output slab fits an 8 KiB L1 budget, so one
    /// looked-up product column is accumulated into every image of the
    /// tile while both stay cache-resident. Always ≥ 1; a power of two
    /// so the widest tile across layers is a multiple of every
    /// layer's tile (worker chunk alignment, `Executor::run_batch_into`).
    pub batch_tile: usize,
    /// Structured-pruning compaction record (DESIGN.md S23). `None` for
    /// a dense plan; when set, the kernels dispatch to the sparse
    /// bodies in `graph::kernels` that sweep only the live rows/columns
    /// and splat the pruned channels' constant codes.
    pub prune: Option<PruneInfo>,
}

/// Batch-tile width for a layer with `cout` output channels (see
/// [`ConvPlan::batch_tile`]).
fn batch_tile_for(cout: usize) -> usize {
    // 8 KiB of i32 accumulator lanes shared between `tile` images.
    let budget = 8 * 1024 / 4;
    let raw = (budget / cout.max(1)).clamp(1, 16);
    let mut tile = 1usize;
    while tile * 2 <= raw {
        tile *= 2;
    }
    tile
}

impl ConvPlan {
    fn build(
        op: &Op,
        in_hw: usize,
        datapath: Datapath,
        mode: TableMode,
        spec: Option<&PruneSpec>,
        approx: Option<&ApproxSpec>,
    ) -> Self {
        let Op::Conv {
            name,
            kind,
            cin,
            cout,
            k,
            stride,
            pad,
            w_bits,
            in_bits,
            w_codes,
            thresholds,
            signs,
            consts,
            ..
        } = op
        else {
            unreachable!("ConvPlan::build on a non-conv op")
        };
        let (k, stride, pad) = (*k, *stride, *pad);
        let geom = ConvGeom { in_h: in_hw, in_w: in_hw, cin: *cin, cout: *cout, k, stride, pad };
        let dense_cols = w_codes[0].len();
        // Structured pruning (DESIGN.md S23): resolve the keep-masks and
        // compact the weight matrix to the live rows/columns BEFORE the
        // multiplier array is built, so the LUT product tables, wflat_t
        // and the batch-major sweeps only ever see live work. Thresholds
        // and geometry stay full-width: pruned channels still occupy
        // their output slot, holding the constant code `threshold(0, ch)`.
        let masks = spec.and_then(|s| s.resolve(op));
        let (live_rows, live_cols): (Vec<usize>, Vec<usize>) = match &masks {
            Some((rm, cm)) => (
                rm.iter().enumerate().filter(|&(_, &b)| b).map(|(i, _)| i).collect(),
                cm.iter().enumerate().filter(|&(_, &b)| b).map(|(i, _)| i).collect(),
            ),
            None => ((0..geom.cout).collect(), (0..dense_cols).collect()),
        };
        let pruned = live_rows.len() < geom.cout || live_cols.len() < dense_cols;
        let compact: Vec<Vec<i32>>;
        let wmat: &[Vec<i32>] = if pruned {
            compact = live_rows
                .iter()
                .map(|&r| live_cols.iter().map(|&c| w_codes[r][c]).collect())
                .collect();
            &compact
        } else {
            w_codes
        };
        let rows = wmat.len();
        let cols = wmat[0].len();
        // The Figure 5 embedding addresses activations with the weight's
        // bit count, so the LUT path additionally needs in_bits <=
        // w_bits: a wider activation code would index past a multiplier's
        // table (the per-MAC readout asserts the same bound). Layers
        // outside the envelope multiply arithmetically, like the paper's
        // DSP-packed 8-bit first/last layers.
        let lut_ok = *w_bits <= 4 && *in_bits <= 4 && *in_bits <= *w_bits;
        // The approximate datapath (DESIGN.md S24) covers std/pw layers
        // inside the LUT envelope: depthwise convs run per-channel
        // patch subspaces a shared codebook cannot quantize (Maddness
        // targets GEMM-shaped layers), and pruned plans keep their
        // exact compacted tables — so those, like the >4-bit layers,
        // fall through to the exact lowering below.
        let approx_ok = datapath == Datapath::LutFabric
            && lut_ok
            && *kind != ConvKind::Dw
            && !pruned;
        let mults = match approx {
            Some(aspec) if approx_ok => Multipliers::LutApprox {
                layer: ApproxLayer::train(
                    wmat,
                    *w_bits,
                    *in_bits,
                    aspec,
                    layer_seed(aspec.seed, name),
                ),
            },
            _ if datapath == Datapath::LutFabric && lut_ok => {
                Self::lut_multipliers(wmat, *w_bits, mode)
            }
            _ => Multipliers::Weights,
        };
        // The count-based quantizer ([`threshold`](Self::threshold)) is a
        // partition point over each channel's threshold row, which is
        // only equal to the per-level compare count when the row is
        // sorted ascending — an unsorted row would silently miscount, so
        // reject it loudly here, once, at plan-compile time.
        for (ch, row) in thresholds.iter().enumerate() {
            assert!(
                row.windows(2).all(|w| w[0] <= w[1]),
                "{name}: threshold row for channel {ch} is not sorted ascending \
                 ({row:?}); the count-based quantizer would silently miscount"
            );
        }
        // Column-major transpose of the (possibly compacted) weight
        // matrix; the weight-row count is the live-row count — geom.cout
        // for a dense plan (C for depthwise).
        let mut wflat_t = vec![0i32; rows * cols];
        for (row, codes) in wmat.iter().enumerate() {
            for (col, &w) in codes.iter().enumerate() {
                wflat_t[col * rows + row] = w;
            }
        }
        let mut plan = Self {
            name: name.clone(),
            kind: *kind,
            geom,
            wflat: wmat.iter().flatten().copied().collect(),
            wflat_t,
            cols,
            mults,
            thr_flat: thresholds.iter().flatten().copied().collect(),
            levels: thresholds[0].len(),
            signs: signs.clone(),
            consts: consts.clone(),
            tap_offsets: (0..k * k).map(|t| ((t / k) * geom.in_w + (t % k)) * geom.cin).collect(),
            oy_interior: geom.interior(geom.out_h(), geom.in_h),
            ox_interior: geom.interior(geom.out_w(), geom.in_w),
            batch_tile: batch_tile_for(geom.cout),
            prune: pruned.then(|| PruneInfo {
                live_rows: live_rows.clone(),
                pruned_rows: Vec::new(), // needs the plan's thresholds; filled below
                live_cols,
                dense_cols,
            }),
        };
        if let Some((row_mask, _)) = &masks {
            let constant_rows: Vec<(usize, i32)> = row_mask
                .iter()
                .enumerate()
                .filter(|&(_, &keep)| !keep)
                .map(|(ch, _)| (ch, plan.threshold(0, ch)))
                .collect();
            if let Some(p) = plan.prune.as_mut() {
                p.pruned_rows = constant_rows;
            }
        }
        plan
    }

    /// Embed the layer's weights into LUT6_2 multipliers (two weights per
    /// `ConstMultiplier`, Figure 5) and, when memoizing, read every
    /// product table out of the simulated fabric once — into the
    /// activation-major layout by default, or the MAC-major baseline
    /// layout for [`NetworkPlan::compile_mac_major`].
    fn lut_multipliers(w_codes: &[Vec<i32>], w_bits: u32, mode: TableMode) -> Multipliers {
        let rows = w_codes.len();
        let cols = w_codes[0].len();
        let n_bits = w_bits.max(1);
        let pairs = cols.div_ceil(2);
        let mut mults = Vec::with_capacity(rows * pairs);
        for row in w_codes {
            for p in 0..pairs {
                let w0 = row[2 * p];
                let w1 = if 2 * p + 1 < cols { row[2 * p + 1] } else { 0 };
                mults.push(ConstMultiplier::new(w0, w1, n_bits));
            }
        }
        if mode == TableMode::Direct {
            return Multipliers::LutDirect { mults };
        }
        let acts = 1usize << n_bits;
        let lut6 = mults.iter().map(ConstMultiplier::lut_count).sum();
        let mut products = vec![0i32; rows * cols * acts];
        for row in 0..rows {
            for col in 0..cols {
                let m = &mults[row * pairs + col / 2];
                for a in 0..acts {
                    let p = m.eval(col % 2 == 1, a as u32);
                    match mode {
                        TableMode::ActMajor => products[(col * acts + a) * rows + row] = p,
                        TableMode::MacMajor => products[(row * cols + col) * acts + a] = p,
                        TableMode::Direct => unreachable!("returned above"),
                    }
                }
            }
        }
        match mode {
            TableMode::ActMajor => Multipliers::LutTables { products, acts, lut6 },
            _ => Multipliers::LutTablesMacMajor { products, acts, lut6 },
        }
    }

    /// Multi-threshold over the flattened levels as a partition point:
    /// plan compilation validates every row is sorted ascending, so the
    /// per-level compare count collapses to the index of the first
    /// level the accumulator fails — bit-exact vs
    /// `MultiThreshold::apply` on sorted rows (equal levels included:
    /// `partition_point` counts the whole `t <= acc` prefix, exactly
    /// what the compare+sum counted).
    #[inline]
    pub fn threshold(&self, acc: i32, ch: usize) -> i32 {
        let ts = &self.thr_flat[ch * self.levels..(ch + 1) * self.levels];
        match self.signs[ch] {
            // count of t with acc >= t == length of the sorted prefix
            // where t <= acc
            s if s > 0 => ts.partition_point(|&t| t <= acc) as i32,
            // count of t with acc <= t == suffix beyond the t < acc prefix
            s if s < 0 => (self.levels - ts.partition_point(|&t| t < acc)) as i32,
            _ => self.consts[ch],
        }
    }

    /// Weight-row count of the compiled multiplier array: the live
    /// output channels of a pruned plan, `geom.cout` otherwise (the
    /// weight-row count for every conv kind). Kernel row indices below
    /// this are compacted; `PruneInfo::live_rows` maps them back to
    /// dense channels.
    #[inline]
    pub fn rows(&self) -> usize {
        self.prune.as_ref().map_or(self.geom.cout, |p| p.live_rows.len())
    }

    /// Product `w[row][col] * act` through the plan's multiplier array.
    /// (`row`/`col` are compacted indices on a pruned plan; the
    /// activation-major table is indexed with [`rows`](Self::rows) as
    /// the row count.)
    #[inline]
    pub fn mul(&self, row: usize, col: usize, act: i32) -> i32 {
        match &self.mults {
            Multipliers::Weights => self.wflat[row * self.cols + col] * act,
            Multipliers::LutDirect { mults } => {
                let pairs = self.cols.div_ceil(2);
                mults[row * pairs + col / 2].eval(col % 2 == 1, act as u32)
            }
            Multipliers::LutTables { products, acts, .. } => {
                products[(col * acts + act as usize) * self.rows() + row]
            }
            Multipliers::LutTablesMacMajor { products, acts, .. } => {
                products[(row * self.cols + col) * acts + act as usize]
            }
            Multipliers::LutApprox { .. } => unreachable!(
                "LutApprox has no per-element product; the kernels dispatch \
                 approx layers to the codebook bodies"
            ),
        }
    }

    /// Inner product of weight row `row` with a full im2col patch
    /// (`[cols]`, column order) through the plan's multiplier array.
    #[inline]
    pub fn dot(&self, row: usize, patch: &[i32]) -> i32 {
        match &self.mults {
            Multipliers::Weights => {
                let wrow = &self.wflat[row * self.cols..(row + 1) * self.cols];
                wrow.iter().zip(patch).map(|(w, a)| w * a).sum()
            }
            Multipliers::LutApprox { layer } => layer.dot(row, patch),
            _ => (0..patch.len()).map(|col| self.mul(row, col, patch[col])).sum(),
        }
    }

    /// Physical LUT6 count of this layer's multiplier array (0 when the
    /// layer multiplies arithmetically).
    pub fn lut_count(&self) -> usize {
        match &self.mults {
            Multipliers::Weights => 0,
            Multipliers::LutDirect { mults } => {
                mults.iter().map(ConstMultiplier::lut_count).sum()
            }
            Multipliers::LutTables { lut6, .. }
            | Multipliers::LutTablesMacMajor { lut6, .. } => *lut6,
            Multipliers::LutApprox { layer } => layer.lut6,
        }
    }

    /// Multiply-accumulates per image — the balance weight
    /// [`NetworkPlan::shard_evenly`] cuts by. Counts live work only:
    /// pruned rows/columns cost neither cycles nor LUTs.
    pub fn macs(&self) -> u64 {
        self.geom.out_pixels() as u64 * self.rows() as u64 * self.cols as u64
    }

    /// Dense (unpruned) multiply-accumulates per image — the
    /// denominator of a pruned layer's savings ratio.
    pub fn dense_macs(&self) -> u64 {
        let dense_cols = self.prune.as_ref().map_or(self.cols, |p| p.dense_cols);
        self.geom.out_pixels() as u64 * self.geom.cout as u64 * dense_cols as u64
    }
}

/// The dense classifier head, lowered. (`name` labels the simulator's
/// stage stats, matching conv stages.)
#[derive(Debug, Clone)]
pub struct DensePlan {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    /// Row-major `[CIN][COUT]` flattened weight codes — one contiguous
    /// slice (`wflat[ci * cout + co]`), so the dense kernel reads a
    /// contiguous `cout`-wide column per input channel instead of
    /// chasing a `Vec<Vec<_>>` double indirection per MAC.
    pub wflat: Vec<i32>,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
}

/// One op of the compiled network, index-aligned with `Network::ops`
/// (trace callbacks keep their op indices across the lowering).
#[derive(Debug, Clone)]
pub enum PlanOp {
    Input,
    Conv(ConvPlan),
    /// Residual tee; `pixels` is the feature-map size at the tee (the
    /// simulator sizes the bypass FIFO from it).
    ResPush { pixels: usize },
    ResAdd { bits: u32 },
    /// Global sum-pool; `pixels` is the map size being pooled.
    PoolSum { pixels: usize },
    Dense(DensePlan),
}

/// A network compiled for one datapath: what the executor runs, the
/// dataflow simulator builds stages from, and the serving stack reads
/// geometry out of. (The datapath itself lives in each conv's
/// [`Multipliers`] variant — that is the single source of truth.)
#[derive(Debug, Clone)]
pub struct NetworkPlan {
    pub io: IoGeom,
    pub ops: Vec<PlanOp>,
}

impl NetworkPlan {
    /// Lower a network once into per-layer plans. On `LutFabric`, every
    /// <=4-bit layer's products are memoized out of the simulated LUT6_2
    /// primitives into activation-major tables
    /// ([`Multipliers::LutTables`]).
    pub fn compile(net: &Network, datapath: Datapath) -> Self {
        Self::lower(net, datapath, TableMode::ActMajor, None, None)
    }

    /// Like [`compile`](Self::compile), but `LutFabric` layers keep the
    /// per-MAC LUT6_2 readout ([`Multipliers::LutDirect`]) instead of
    /// memoized tables — the pre-compilation baseline the bench and the
    /// equivalence tests run against.
    pub fn compile_direct(net: &Network, datapath: Datapath) -> Self {
        Self::lower(net, datapath, TableMode::Direct, None, None)
    }

    /// Like [`compile`](Self::compile), but memoized tables keep the
    /// MAC-major layout ([`Multipliers::LutTablesMacMajor`]) — the
    /// pre-activation-major baseline `benches/bench_kernels.rs` and
    /// `make kernel-smoke` gate the LUT-GEMM speedup against.
    pub fn compile_mac_major(net: &Network, datapath: Datapath) -> Self {
        Self::lower(net, datapath, TableMode::MacMajor, None, None)
    }

    /// Like [`compile`](Self::compile), with a structured-pruning pass
    /// (DESIGN.md S23): every conv's weight matrix is compacted to the
    /// rows/columns `spec` keeps before the multiplier array is built,
    /// so the LUT product tables, `wflat_t` and the batch-major sweeps
    /// touch only live work. A noop spec compiles the identical dense
    /// plan. Bit-exact vs the dense compile of
    /// `PruneSpec::masked_network` on every datapath and batch size
    /// (tests/prune.rs).
    pub fn compile_pruned(net: &Network, datapath: Datapath, spec: &PruneSpec) -> Self {
        Self::lower(net, datapath, TableMode::ActMajor, (!spec.is_noop()).then_some(spec), None)
    }

    /// [`compile_direct`](Self::compile_direct) with a pruning pass —
    /// the per-MAC readout witness over the compacted multipliers.
    pub fn compile_pruned_direct(net: &Network, datapath: Datapath, spec: &PruneSpec) -> Self {
        Self::lower(net, datapath, TableMode::Direct, (!spec.is_noop()).then_some(spec), None)
    }

    /// [`compile_mac_major`](Self::compile_mac_major) with a pruning
    /// pass — the MAC-major table witness over the compacted matrix.
    pub fn compile_pruned_mac_major(net: &Network, datapath: Datapath, spec: &PruneSpec) -> Self {
        Self::lower(net, datapath, TableMode::MacMajor, (!spec.is_noop()).then_some(spec), None)
    }

    /// Like [`compile`](Self::compile), but every eligible layer
    /// (std/pw inside the `lut_ok` envelope) is lowered to the
    /// Maddness-style approximate codebook datapath
    /// ([`Multipliers::LutApprox`], DESIGN.md S24): hash trees and
    /// prototype tables are trained here, at compile time, from the
    /// network's weights and a seeded synthetic patch stream, so the
    /// compile is deterministic. Depthwise and out-of-envelope layers
    /// keep their exact lowering — the approximate plan differs from
    /// [`compile`](Self::compile) only where the codebooks apply.
    /// Does not compose with pruning (a compacted matrix would retrain
    /// different codebooks; prune or approximate, not both).
    pub fn compile_approx(net: &Network, datapath: Datapath, spec: &ApproxSpec) -> Self {
        Self::lower(net, datapath, TableMode::ActMajor, None, Some(spec))
    }

    fn lower(
        net: &Network,
        datapath: Datapath,
        mode: TableMode,
        spec: Option<&PruneSpec>,
        approx: Option<&ApproxSpec>,
    ) -> Self {
        let mut hw = net.meta.image_size;
        let ops = net
            .ops
            .iter()
            .map(|op| match op {
                Op::Input { .. } => PlanOp::Input,
                Op::Conv { .. } => {
                    let plan = ConvPlan::build(op, hw, datapath, mode, spec, approx);
                    hw = plan.geom.out_h();
                    PlanOp::Conv(plan)
                }
                Op::ResPush {} => PlanOp::ResPush { pixels: hw * hw },
                Op::ResAdd { bits } => PlanOp::ResAdd { bits: *bits },
                Op::PoolSum {} => PlanOp::PoolSum { pixels: hw * hw },
                Op::Dense { name, cout, w_codes, scale, bias, .. } => {
                    PlanOp::Dense(DensePlan {
                        name: name.clone(),
                        cin: w_codes.len(),
                        cout: *cout,
                        wflat: w_codes.iter().flatten().copied().collect(),
                        scale: scale.clone(),
                        bias: bias.clone(),
                    })
                }
            })
            .collect();
        Self { io: net.io(), ops }
    }

    /// All compiled conv layers in order.
    pub fn convs(&self) -> impl Iterator<Item = &ConvPlan> {
        self.ops.iter().filter_map(|op| match op {
            PlanOp::Conv(c) => Some(c),
            _ => None,
        })
    }

    /// Number of conv stages (fold vector sizing).
    pub fn n_convs(&self) -> usize {
        self.convs().count()
    }

    /// Logit width of the dense head (`None` for a headless shard plan)
    /// — what the executor sizes its per-image output slots from.
    pub fn dense_cout(&self) -> Option<usize> {
        self.ops.iter().rev().find_map(|op| match op {
            PlanOp::Dense(d) => Some(d.cout),
            _ => None,
        })
    }

    /// Total physical LUT6 of the compiled multiplier arrays.
    pub fn lut_count(&self) -> usize {
        self.convs().map(ConvPlan::lut_count).sum()
    }

    /// The widest per-layer batch tile among the compiled convs (1 for
    /// a conv-free plan). Per-layer tiles are powers of two, so the
    /// widest is a multiple of each — `Executor::run_batch_into` sizes
    /// worker chunks in multiples of this value so no worker's sweep
    /// splits any layer's SIMD batch tile below its width.
    pub fn batch_tile(&self) -> usize {
        self.convs().map(|c| c.batch_tile).max().unwrap_or(1)
    }

    /// Token geometry (spatial side, channels) at every op boundary:
    /// entry `i` is the shape entering `ops[i]`; the final entry is the
    /// network's output shape. Pool collapses the map to a single
    /// 1-"pixel" channel vector, matching the token the simulator's pool
    /// stage emits.
    pub fn boundary_geoms(&self) -> Vec<(usize, usize)> {
        let mut geoms = Vec::with_capacity(self.ops.len() + 1);
        let (mut hw, mut ch) = (self.io.image_size, self.io.in_ch);
        geoms.push((hw, ch));
        for op in &self.ops {
            match op {
                PlanOp::Input | PlanOp::ResPush { .. } | PlanOp::ResAdd { .. } => {}
                PlanOp::Conv(c) => {
                    hw = c.geom.out_h();
                    ch = c.geom.cout;
                }
                PlanOp::PoolSum { .. } => hw = 1,
                PlanOp::Dense(d) => {
                    hw = 1;
                    ch = d.cout;
                }
            }
            geoms.push((hw, ch));
        }
        geoms
    }

    /// Residual bypass depth at every op boundary. A boundary with
    /// nonzero depth sits between a tee and its join — cutting there
    /// would put the bypass FIFO on a network link, so such boundaries
    /// are invalid shard cuts.
    pub fn res_depths(&self) -> Vec<i32> {
        let mut depths = Vec::with_capacity(self.ops.len() + 1);
        let mut d = 0i32;
        depths.push(d);
        for op in &self.ops {
            match op {
                PlanOp::ResPush { .. } => d += 1,
                PlanOp::ResAdd { .. } => d -= 1,
                _ => {}
            }
            depths.push(d);
        }
        depths
    }

    /// Interior op boundaries where the plan may be cut into shards:
    /// residual-balanced, with at least one compute/pool/dense op on
    /// each side (a shard of bare `Input` ops would be an empty
    /// pipeline).
    pub fn cut_points(&self) -> Vec<usize> {
        let depths = self.res_depths();
        let is_stage = |op: &PlanOp| {
            !matches!(op, PlanOp::Input | PlanOp::ResPush { .. } | PlanOp::ResAdd { .. })
        };
        // prefix[b] = number of stage ops in ops[..b]
        let mut prefix = Vec::with_capacity(self.ops.len() + 1);
        let mut n = 0usize;
        prefix.push(n);
        for op in &self.ops {
            n += is_stage(op) as usize;
            prefix.push(n);
        }
        let total = n;
        (1..self.ops.len())
            .filter(|&b| depths[b] == 0 && prefix[b] > 0 && prefix[b] < total)
            .collect()
    }

    /// Slice a contiguous op range into a standalone [`PlanShard`]
    /// (DESIGN.md S18). Fails when the range is empty/out of bounds or
    /// when a residual bypass crosses either end of the range.
    pub fn slice(&self, range: std::ops::Range<usize>) -> anyhow::Result<PlanShard> {
        let (start, end) = (range.start, range.end);
        anyhow::ensure!(
            start < end && end <= self.ops.len(),
            "plan slice {start}..{end} out of bounds for {} ops",
            self.ops.len()
        );
        let mut depth = 0i32;
        for (i, op) in self.ops[start..end].iter().enumerate() {
            match op {
                PlanOp::ResPush { .. } => depth += 1,
                PlanOp::ResAdd { .. } => {
                    depth -= 1;
                    anyhow::ensure!(
                        depth >= 0,
                        "op {} is a res_add whose res_push lies before the slice",
                        start + i
                    );
                }
                _ => {}
            }
        }
        anyhow::ensure!(
            depth == 0,
            "{depth} res_push op(s) in {start}..{end} join after the slice"
        );
        let geoms = self.boundary_geoms();
        let (in_hw, in_ch) = geoms[start];
        let (out_hw, out_ch) = geoms[end];
        Ok(PlanShard {
            plan: NetworkPlan {
                io: IoGeom { image_size: in_hw, in_ch, num_classes: self.io.num_classes },
                ops: self.ops[start..end].to_vec(),
            },
            start,
            end,
            in_pixels: in_hw * in_hw,
            in_ch,
            out_pixels: out_hw * out_hw,
            out_ch,
        })
    }

    /// Slice the plan at the given interior op boundaries (sorted,
    /// deduplicated) into `cuts.len() + 1` contiguous shards tiling the
    /// whole plan.
    pub fn shard(&self, cuts: &[usize]) -> anyhow::Result<Vec<PlanShard>> {
        let mut bounds = vec![0usize];
        for &c in cuts {
            anyhow::ensure!(c > 0 && c < self.ops.len(), "cut {c} is not an interior boundary");
            if *bounds.last().expect("bounds start non-empty") != c {
                bounds.push(c);
            }
        }
        anyhow::ensure!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "cuts must be sorted: {cuts:?}"
        );
        bounds.push(self.ops.len());
        bounds.windows(2).map(|w| self.slice(w[0]..w[1])).collect()
    }

    /// Cut the plan into (up to) `n` contiguous shards balanced by MAC
    /// count, cutting only at valid boundaries
    /// ([`cut_points`](Self::cut_points)): the serving coordinator's
    /// default placement when no analytic multi-FPGA plan
    /// (`dataflow::multi`) is driving the split. Always yields at least
    /// one shard; fewer than `n` when the plan has too few valid
    /// boundaries.
    pub fn shard_evenly(&self, n: usize) -> Vec<PlanShard> {
        let n = n.max(1);
        let cost: Vec<u64> = self
            .ops
            .iter()
            .map(|op| match op {
                PlanOp::Conv(c) => c.macs().max(1),
                PlanOp::Dense(d) => (d.cout * d.cin).max(1) as u64,
                _ => 0,
            })
            .collect();
        let total: u64 = cost.iter().sum();
        let valid = self.cut_points();
        let mut cuts: Vec<usize> = Vec::new();
        let mut acc = 0u64;
        for (i, &c) in cost.iter().enumerate() {
            acc += c;
            let k = cuts.len() as u64 + 1; // shards closed so far, counting this one
            if cuts.len() + 1 < n
                && acc * n as u64 >= total * k
                && valid.binary_search(&(i + 1)).is_ok()
            {
                cuts.push(i + 1);
            }
        }
        self.shard(&cuts)
            .expect("cuts drawn from cut_points() are valid by construction")
    }
}

/// A contiguous sub-plan (DESIGN.md S18): one device's slice of a
/// [`NetworkPlan`], re-packaged as a standalone plan whose [`IoGeom`]
/// describes the shard's *own* input — so every consumer of plan
/// geometry (the pipeline builder, the coordinator, the runtime) works
/// unchanged on a shard.
#[derive(Debug, Clone)]
pub struct PlanShard {
    /// The sub-plan; `plan.io` is the shard's input geometry
    /// (`num_classes` is inherited from the parent).
    pub plan: NetworkPlan,
    /// Half-open op range `[start, end)` in the parent plan.
    pub start: usize,
    pub end: usize,
    /// Tokens (pixels) entering the shard per image, and their width.
    pub in_pixels: usize,
    pub in_ch: usize,
    /// Tokens leaving the shard per image, and their width. For the tail
    /// shard these describe the dense head's logits, which leave as a
    /// result, not as link tokens.
    pub out_pixels: usize,
    pub out_ch: usize,
}

impl PlanShard {
    /// Whether this shard ends in the dense head (emits logits rather
    /// than activation tokens).
    pub fn is_tail(&self) -> bool {
        matches!(self.plan.ops.last(), Some(PlanOp::Dense(_)))
    }

    /// Activation bytes leaving this shard per image at `a_bits`-wide
    /// codes — the executable counterpart of the analytic egress model.
    pub fn egress_bytes(&self, a_bits: u32) -> u64 {
        (self.out_pixels * self.out_ch) as u64 * a_bits.max(1) as u64 / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mobilenet_v2_small;
    use crate::util::prop::Rng;

    fn geom(in_hw: usize, k: usize, stride: usize, pad: usize) -> ConvGeom {
        ConvGeom { in_h: in_hw, in_w: in_hw, cin: 1, cout: 1, k, stride, pad }
    }

    #[test]
    fn interior_ranges() {
        // 3x3 s1 p1 on 8: outputs 1..7 have full windows
        let g = geom(8, 3, 1, 1);
        assert_eq!(g.interior(g.out_h(), g.in_h), (1, 7));
        // pointwise: everything is interior
        let g = geom(5, 1, 1, 0);
        assert_eq!(g.interior(g.out_h(), g.in_h), (0, 5));
        // 3x3 s2 p1 on 7 (odd width): out 4, interior {1, 2}
        let g = geom(7, 3, 2, 1);
        assert_eq!(g.out_h(), 4);
        assert_eq!(g.interior(g.out_h(), g.in_h), (1, 3));
        // degenerate 1x1 map under a 3x3 kernel: all border
        let g = geom(1, 3, 1, 1);
        let (lo, hi) = g.interior(g.out_h(), g.in_h);
        assert!(lo >= hi, "no interior on a 1x1 map");
    }

    #[test]
    fn interior_windows_are_actually_in_bounds() {
        // exhaustive cross-check of the interior predicate
        for in_hw in [1usize, 2, 3, 5, 7, 9] {
            for k in [1usize, 3] {
                for stride in [1usize, 2] {
                    let pad = (k - 1) / 2;
                    if in_hw + 2 * pad < k {
                        continue;
                    }
                    let g = geom(in_hw, k, stride, pad);
                    let (lo, hi) = g.interior(g.out_h(), g.in_h);
                    for o in 0..g.out_h() {
                        let full = (0..k).all(|i| {
                            let y = (o * stride + i) as isize - pad as isize;
                            y >= 0 && y < in_hw as isize
                        });
                        assert_eq!(
                            (lo..hi).contains(&o),
                            full,
                            "in={in_hw} k={k} s={stride} o={o}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lut_tables_match_direct_readout_and_arithmetic() {
        let mut rng = Rng::new(0xA11CE);
        let w_codes: Vec<Vec<i32>> = (0..5).map(|_| rng.vec_i32(7, -8, 7)).collect();
        let direct = ConvPlan::lut_multipliers(&w_codes, 4, TableMode::Direct);
        let tables = ConvPlan::lut_multipliers(&w_codes, 4, TableMode::ActMajor);
        let mac = ConvPlan::lut_multipliers(&w_codes, 4, TableMode::MacMajor);
        let plan_of = |mults: Multipliers| {
            let mut wflat_t = vec![0i32; 7 * 5];
            for (row, codes) in w_codes.iter().enumerate() {
                for (col, &w) in codes.iter().enumerate() {
                    wflat_t[col * 5 + row] = w;
                }
            }
            ConvPlan {
                name: "t".into(),
                kind: ConvKind::Pw,
                geom: ConvGeom { in_h: 1, in_w: 1, cin: 7, cout: 5, k: 1, stride: 1, pad: 0 },
                wflat: w_codes.iter().flatten().copied().collect(),
                wflat_t,
                cols: 7,
                mults,
                thr_flat: vec![0; 5 * 15],
                levels: 15,
                signs: vec![1; 5],
                consts: vec![0; 5],
                tap_offsets: vec![0],
                oy_interior: (0, 1),
                ox_interior: (0, 1),
                batch_tile: batch_tile_for(5),
                prune: None,
            }
        };
        let (pd, pt, pm) = (plan_of(direct), plan_of(tables), plan_of(mac));
        for row in 0..5 {
            for col in 0..7 {
                for act in 0..16 {
                    let want = w_codes[row][col] * act;
                    assert_eq!(pd.mul(row, col, act), want, "direct r{row} c{col} a{act}");
                    assert_eq!(pt.mul(row, col, act), want, "act-major r{row} c{col} a{act}");
                    assert_eq!(pm.mul(row, col, act), want, "mac-major r{row} c{col} a{act}");
                }
            }
        }
        // odd column count: the pad weight of the last pair is 0
        assert_eq!(pd.lut_count(), pt.lut_count());
        assert_eq!(pd.lut_count(), pm.lut_count());
        assert!(pt.lut_count() > 0);
    }

    #[test]
    fn act_major_tables_are_contiguous_per_column() {
        // the whole point of the layout: fixing (col, act) yields the
        // cout-wide product column contiguously
        let mut rng = Rng::new(7);
        let w_codes: Vec<Vec<i32>> = (0..4).map(|_| rng.vec_i32(3, -8, 7)).collect();
        let Multipliers::LutTables { products, acts, .. } =
            ConvPlan::lut_multipliers(&w_codes, 4, TableMode::ActMajor)
        else {
            panic!("ActMajor compiles to LutTables")
        };
        for col in 0..3 {
            for a in 0..acts {
                let slab = &products[(col * acts + a) * 4..(col * acts + a + 1) * 4];
                for (row, &p) in slab.iter().enumerate() {
                    assert_eq!(p, w_codes[row][col] * a as i32, "col {col} act {a} row {row}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "not sorted ascending")]
    fn unsorted_thresholds_are_rejected_at_compile() {
        let mut net = Network::synthetic(&mobilenet_v2_small(), 3);
        for op in net.ops.iter_mut() {
            if let Op::Conv { thresholds, .. } = op {
                thresholds[0].swap(2, 9);
                break;
            }
        }
        let _ = NetworkPlan::compile(&net, Datapath::Arithmetic);
    }

    #[test]
    fn threshold_partition_point_matches_compare_count() {
        // both signs, duplicate levels included: the partition point must
        // equal the per-level compare count the kernels used to take
        let rows = vec![vec![-3, -1, -1, 0, 2, 2, 2, 5, 9, 9, 11, 14, 14, 20, 31]];
        let plan = ConvPlan {
            name: "t".into(),
            kind: ConvKind::Pw,
            geom: ConvGeom { in_h: 1, in_w: 1, cin: 1, cout: 1, k: 1, stride: 1, pad: 0 },
            wflat: vec![1],
            wflat_t: vec![1],
            cols: 1,
            mults: Multipliers::Weights,
            thr_flat: rows[0].clone(),
            levels: 15,
            signs: vec![1],
            consts: vec![0],
            tap_offsets: vec![0],
            oy_interior: (0, 1),
            ox_interior: (0, 1),
            batch_tile: batch_tile_for(1),
            prune: None,
        };
        let mut neg = plan.clone();
        neg.signs = vec![-1];
        for acc in -6..35 {
            let up: i32 = rows[0].iter().map(|&t| (acc >= t) as i32).sum();
            let dn: i32 = rows[0].iter().map(|&t| (acc <= t) as i32).sum();
            assert_eq!(plan.threshold(acc, 0), up, "sign>0 acc={acc}");
            assert_eq!(neg.threshold(acc, 0), dn, "sign<0 acc={acc}");
        }
    }

    #[test]
    fn compile_tracks_shapes_and_alignment() {
        let net = Network::synthetic(&mobilenet_v2_small(), 3);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        assert_eq!(plan.ops.len(), net.ops.len(), "plan ops index-align with network ops");
        assert_eq!(plan.io.image_size, net.meta.image_size);
        assert_eq!(plan.io.num_classes, net.meta.num_classes);
        assert_eq!(plan.n_convs(), net.convs().count());
        // geometry chains: each conv's input side equals the previous out
        let mut hw = net.meta.image_size;
        for cp in plan.convs() {
            assert_eq!(cp.geom.in_h, hw, "{}", cp.name);
            hw = cp.geom.out_h();
        }
        // arithmetic plans own no LUTs; LutFabric plans do
        assert_eq!(plan.lut_count(), 0);
        let lut = NetworkPlan::compile(&net, Datapath::LutFabric);
        assert!(lut.lut_count() > 0);
        // the 8-bit stem stays arithmetic even on the LUT datapath
        let stem = lut.convs().next().unwrap();
        assert!(matches!(stem.mults, Multipliers::Weights));
    }

    #[test]
    fn batch_tiles_are_l1_bounded_powers_of_two_and_wflat_t_transposes() {
        // tile heuristic: power of two, >= 1, <= 16, slab within 8 KiB
        for cout in [1usize, 3, 10, 16, 24, 64, 100, 512, 4096] {
            let t = batch_tile_for(cout);
            assert!(t.is_power_of_two() && t <= 16, "cout={cout} tile={t}");
            assert!(t == 1 || t * cout * 4 <= 8 * 1024, "cout={cout} tile={t} busts L1 budget");
            // maximal: doubling the tile would bust the budget (or 16)
            assert!(t == 16 || 2 * t * cout * 4 > 8 * 1024, "cout={cout} tile={t} not maximal");
        }
        let net = Network::synthetic(&mobilenet_v2_small(), 21);
        let plan = NetworkPlan::compile(&net, Datapath::LutFabric);
        // plan-wide tile is the max (a multiple of every layer's tile,
        // since all are powers of two)
        let widest = plan.batch_tile();
        for cp in plan.convs() {
            assert_eq!(cp.batch_tile, batch_tile_for(cp.geom.cout), "{}", cp.name);
            assert_eq!(widest % cp.batch_tile, 0, "{}", cp.name);
            // wflat_t is exactly the transpose of wflat
            for row in 0..cp.geom.cout {
                for col in 0..cp.cols {
                    assert_eq!(
                        cp.wflat_t[col * cp.geom.cout + row],
                        cp.wflat[row * cp.cols + col],
                        "{} r{row} c{col}",
                        cp.name
                    );
                }
            }
        }
    }

    #[test]
    fn boundary_geoms_chain_and_slices_inherit_them() {
        let net = Network::synthetic(&mobilenet_v2_small(), 5);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let geoms = plan.boundary_geoms();
        assert_eq!(geoms.len(), plan.ops.len() + 1);
        assert_eq!(geoms[0], (net.meta.image_size, net.meta.in_ch));
        // final boundary is the classifier output
        assert_eq!(geoms.last(), Some(&(1, net.meta.num_classes)));
        // every interior boundary is a valid cut on this res-free net
        let cuts = plan.cut_points();
        assert!(!cuts.is_empty());
        for &c in &cuts {
            let head = plan.slice(0..c).unwrap();
            let tail = plan.slice(c..plan.ops.len()).unwrap();
            assert_eq!(head.start, 0);
            assert_eq!(head.end, tail.start);
            assert_eq!(tail.end, plan.ops.len());
            // geometry chains across the cut
            assert_eq!((head.out_pixels, head.out_ch), (tail.in_pixels, tail.in_ch));
            // the shard's own IoGeom is its input shape
            assert_eq!(tail.plan.io.image_size * tail.plan.io.image_size, tail.in_pixels);
            assert_eq!(tail.plan.io.in_ch, tail.in_ch);
            assert_eq!(tail.plan.io.num_classes, plan.io.num_classes);
            assert!(tail.is_tail() && !head.is_tail());
        }
    }

    #[test]
    fn slice_rejects_unbalanced_residual_ranges() {
        // input, conv, push, conv, add, pool, dense — like a residual block
        let net = Network::synthetic(&mobilenet_v2_small(), 3);
        let mut ops = net.ops.clone();
        ops.insert(2, crate::graph::network::Op::ResPush {});
        // duplicate the first conv so the push wraps a real stage
        let conv = ops[1].clone();
        ops.insert(3, conv);
        ops.insert(4, crate::graph::network::Op::ResAdd { bits: 4 });
        let net = Network { meta: net.meta.clone(), ops };
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        // boundary 3 sits between the push (op 2) and the add (op 4)
        assert!(plan.slice(0..3).is_err(), "push without its add must not slice");
        assert!(plan.slice(3..plan.ops.len()).is_err(), "add without its push must not slice");
        assert!(plan.slice(0..plan.ops.len()).is_ok(), "the whole plan is balanced");
        assert!(!plan.cut_points().contains(&3), "cut_points must skip mid-bypass boundaries");
        // out-of-bounds and empty ranges diagnose too
        assert!(plan.slice(5..5).is_err());
        assert!(plan.slice(0..plan.ops.len() + 1).is_err());
    }

    #[test]
    fn shard_evenly_tiles_the_plan_and_balances_macs() {
        let net = Network::synthetic(&mobilenet_v2_small(), 9);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        for n in [1usize, 2, 3, 4] {
            let shards = plan.shard_evenly(n);
            assert!(!shards.is_empty() && shards.len() <= n);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, plan.ops.len());
            for w in shards.windows(2) {
                assert_eq!(w[0].end, w[1].start, "shards tile contiguously");
                assert_eq!((w[0].out_pixels, w[0].out_ch), (w[1].in_pixels, w[1].in_ch));
            }
            assert!(shards.last().unwrap().is_tail());
            // conv stages are preserved exactly once across shards
            let convs: usize = shards.iter().map(|s| s.plan.n_convs()).sum();
            assert_eq!(convs, plan.n_convs());
            if n >= 2 {
                assert!(shards.len() >= 2, "small net has enough boundaries for 2 shards");
            }
        }
    }

    #[test]
    fn wide_activations_fall_back_to_arithmetic() {
        // in_bits > w_bits would index past a multiplier's product table
        // (and the per-MAC readout asserts the same bound), so such
        // layers must not take the LUT path
        let mut net = Network::synthetic(&mobilenet_v2_small(), 11);
        if let Op::Conv { w_bits, in_bits, w_codes, .. } = &mut net.ops[2] {
            *w_bits = 2;
            *in_bits = 4;
            for row in w_codes.iter_mut() {
                for w in row.iter_mut() {
                    *w = (*w).clamp(-2, 1);
                }
            }
        } else {
            unreachable!("op 2 of the synthetic net is a conv");
        }
        let plan = NetworkPlan::compile(&net, Datapath::LutFabric);
        let narrowed = plan.convs().nth(1).unwrap();
        assert!(matches!(narrowed.mults, Multipliers::Weights), "w2/a4 layer stays arithmetic");
        // 4/4 layers still map to LUTs
        assert!(plan.lut_count() > 0);
    }

    #[test]
    fn pruned_compile_compacts_tables_and_saves_luts() {
        use crate::graph::prune::PruneSpec;
        let net = Network::synthetic(&mobilenet_v2_small(), 13);
        let dense = NetworkPlan::compile(&net, Datapath::LutFabric);
        let spec = PruneSpec::channels(0.5);
        let pruned = NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &spec);
        assert!(pruned.lut_count() < dense.lut_count(), "compacted tables reclaim LUT6");
        for (dp, pp) in dense.convs().zip(pruned.convs()) {
            let info = pp.prune.as_ref().expect("every conv carries a PruneInfo at 50%");
            // live + pruned rows partition the dense channel space
            assert_eq!(info.live_rows.len() + info.pruned_rows.len(), pp.geom.cout);
            assert!(info.live_rows.windows(2).all(|w| w[0] < w[1]), "live rows ascend");
            assert!(info.live_rows.iter().enumerate().all(|(r, &ch)| ch >= r));
            assert_eq!(pp.rows(), info.live_rows.len());
            assert!(pp.rows() < pp.geom.cout, "{}: channels actually pruned", pp.name);
            assert_eq!(info.dense_cols, dp.cols);
            assert_eq!(pp.wflat.len(), pp.rows() * pp.cols);
            assert_eq!(pp.wflat_t.len(), pp.wflat.len());
            // compacted entries come from the dense matrix
            for (r, &ch) in info.live_rows.iter().enumerate() {
                for (c, &col) in info.live_cols.iter().enumerate() {
                    assert_eq!(pp.wflat[r * pp.cols + c], dp.wflat[ch * dp.cols + col]);
                    assert_eq!(pp.wflat_t[c * pp.rows() + r], pp.wflat[r * pp.cols + c]);
                }
            }
            // pruned channels carry their constant code threshold(0, ch)
            for &(ch, code) in &info.pruned_rows {
                assert_eq!(code, dp.threshold(0, ch), "{} ch{ch}", pp.name);
            }
            assert!(pp.macs() < dp.macs());
            assert_eq!(pp.dense_macs(), dp.macs());
        }
        // a noop spec compiles the identical dense plan
        let noop = NetworkPlan::compile_pruned(&net, Datapath::LutFabric, &PruneSpec::default());
        assert_eq!(noop.lut_count(), dense.lut_count());
        assert!(noop.convs().all(|c| c.prune.is_none()));
    }
}
