//! Deployed integer network description — deserialized from
//! `artifacts/network.json` (the output of the python streamlining step,
//! DESIGN.md S16). This is the graph the accelerator generator compiles
//! and the dataflow simulator executes.

use std::path::Path;

use crate::quant::MultiThreshold;

/// Convolution flavor (paper section 3.4: the convolution generator
/// supports pointwise, depthwise and standard convolutions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvKind {
    /// Standard dense convolution.
    Std,
    /// Depthwise (one filter per channel).
    Dw,
    /// Pointwise 1x1.
    Pw,
}

/// One operation of the streamlined integer network.
#[derive(Debug, Clone)]
pub enum Op {
    Input {
        bits: u32,
        scale: f64,
    },
    Conv {
        name: String,
        kind: ConvKind,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        w_bits: u32,
        in_bits: u32,
        out_bits: u32,
        /// `[COUT][K*K*CIN]` for std/pw ((tap, channel) minor order),
        /// `[C][K*K]` for depthwise.
        w_codes: Vec<Vec<i32>>,
        thresholds: Vec<Vec<i32>>,
        signs: Vec<i32>,
        consts: Vec<i32>,
        out_scale: f64,
    },
    ResPush {},
    ResAdd {
        bits: u32,
    },
    PoolSum {},
    Dense {
        name: String,
        cin: usize,
        cout: usize,
        w_bits: u32,
        /// `[CIN][COUT]`.
        w_codes: Vec<Vec<i32>>,
        scale: Vec<f32>,
        bias: Vec<f32>,
    },
}

/// Network metadata exported alongside the ops.
#[derive(Debug, Clone)]
pub struct Meta {
    pub image_size: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub in_scale: f64,
    pub w_bits: u32,
    pub a_bits: u32,
    pub acc_int: f64,
    pub n_test: usize,
    /// Golden logits for the first test images (bit-exactness target).
    pub golden_logits: Vec<Vec<f32>>,
}

/// The full deployed network.
#[derive(Debug, Clone)]
pub struct Network {
    pub meta: Meta,
    pub ops: Vec<Op>,
}

impl Network {
    /// Load from `artifacts/network.json`.
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        let net = Self::from_json_str(&text)?;
        net.validate().map_err(|e| anyhow::anyhow!("invalid network: {e}"))?;
        Ok(net)
    }

    /// Decode the `aot.py` export format (see python/compile/aot.py).
    pub fn from_json_str(text: &str) -> anyhow::Result<Self> {
        use crate::util::Json;
        let root = Json::parse(text)?;
        let m = root.field("meta")?;
        let getf = |k: &str, d: f64| m.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(d);
        let meta = Meta {
            image_size: m.field("image_size")?.as_usize()?,
            in_ch: m.field("in_ch")?.as_usize()?,
            num_classes: m.field("num_classes")?.as_usize()?,
            in_scale: m.field("in_scale")?.as_f64()?,
            w_bits: getf("w_bits", 0.0) as u32,
            a_bits: getf("a_bits", 0.0) as u32,
            acc_int: getf("acc_int", 0.0),
            n_test: getf("n_test", 0.0) as usize,
            golden_logits: m
                .get("golden_logits")
                .map(|g| -> anyhow::Result<Vec<Vec<f32>>> {
                    g.as_arr()?.iter().map(Json::as_f32_vec).collect()
                })
                .transpose()?
                .unwrap_or_default(),
        };
        let mut ops = Vec::new();
        for o in root.field("ops")?.as_arr()? {
            let tag = o.field("op")?.as_str()?;
            ops.push(match tag {
                "input" => Op::Input {
                    bits: o.field("bits")?.as_i64()? as u32,
                    scale: o.field("scale")?.as_f64()?,
                },
                "conv" => Op::Conv {
                    name: o.field("name")?.as_str()?.to_string(),
                    kind: match o.field("kind")?.as_str()? {
                        "std" => ConvKind::Std,
                        "dw" => ConvKind::Dw,
                        "pw" => ConvKind::Pw,
                        other => anyhow::bail!("unknown conv kind {other}"),
                    },
                    cin: o.field("cin")?.as_usize()?,
                    cout: o.field("cout")?.as_usize()?,
                    k: o.field("k")?.as_usize()?,
                    stride: o.field("stride")?.as_usize()?,
                    pad: o.field("pad")?.as_usize()?,
                    w_bits: o.field("w_bits")?.as_i64()? as u32,
                    in_bits: o.field("in_bits")?.as_i64()? as u32,
                    out_bits: o.field("out_bits")?.as_i64()? as u32,
                    w_codes: o.field("w_codes")?.as_i32_mat()?,
                    thresholds: o.field("thresholds")?.as_i32_mat()?,
                    signs: o.field("signs")?.as_i32_vec()?,
                    consts: o.field("consts")?.as_i32_vec()?,
                    out_scale: o.field("out_scale")?.as_f64()?,
                },
                "res_push" => Op::ResPush {},
                "res_add" => Op::ResAdd { bits: o.field("bits")?.as_i64()? as u32 },
                "pool_sum" => Op::PoolSum {},
                "dense" => Op::Dense {
                    name: o.field("name")?.as_str()?.to_string(),
                    cin: o.field("cin")?.as_usize()?,
                    cout: o.field("cout")?.as_usize()?,
                    w_bits: o.field("w_bits")?.as_i64()? as u32,
                    w_codes: o.field("w_codes")?.as_i32_mat()?,
                    scale: o.field("scale")?.as_f32_vec()?,
                    bias: o.field("bias")?.as_f32_vec()?,
                },
                other => anyhow::bail!("unknown op tag {other}"),
            });
        }
        Ok(Network { meta, ops })
    }

    /// All convolution layers in order.
    pub fn convs(&self) -> impl Iterator<Item = &Op> {
        self.ops.iter().filter(|op| matches!(op, Op::Conv { .. }))
    }

    /// The op at `idx` if it is a convolution, `None` otherwise — the
    /// panic-free accessor callers match on instead of asserting
    /// "expected conv" themselves.
    pub fn conv_at(&self, idx: usize) -> Option<&Op> {
        self.ops.get(idx).filter(|op| matches!(op, Op::Conv { .. }))
    }

    /// The first convolution (the layer right after `Input`), if any.
    pub fn first_conv(&self) -> Option<&Op> {
        self.convs().next()
    }

    /// The dense classifier head, if any.
    pub fn dense_head(&self) -> Option<&Op> {
        self.ops.iter().rev().find(|op| matches!(op, Op::Dense { .. }))
    }

    /// I/O geometry of the deployed network — the plan-level view of
    /// `meta` the runtime and coordinator consume (DESIGN.md S17).
    pub fn io(&self) -> super::plan::IoGeom {
        super::plan::IoGeom {
            image_size: self.meta.image_size,
            in_ch: self.meta.in_ch,
            num_classes: self.meta.num_classes,
        }
    }

    /// Total operations per inference (2 x MACs, the roofline convention)
    /// derived from the deployed shapes — the GOPS denominator the
    /// serving metrics use for whatever network is actually served.
    pub fn ops_per_image(&self) -> u64 {
        let mut hw = self.meta.image_size;
        let mut total: u64 = 0;
        for op in &self.ops {
            match op {
                Op::Conv { cout, k, stride, pad, w_codes, .. } => {
                    let out = (hw + 2 * pad - k) / stride + 1;
                    total += 2 * (out * out * cout) as u64 * w_codes[0].len() as u64;
                    hw = out;
                }
                Op::Dense { cin, cout, .. } => total += 2 * (cin * cout) as u64,
                _ => {}
            }
        }
        total
    }

    /// Build a synthetic deployed network from a shape spec: real layer
    /// geometry, seeded random weights and ascending thresholds. Benches
    /// and tests use this when the Python-trained artifacts are absent
    /// (EXPERIMENTS.md "Test triage"), so the executor, pipeline and
    /// coordinator can be exercised on trained-network shapes offline.
    /// The spec's final layer (the 1x1 classifier over the pooled map)
    /// becomes the dense head.
    pub fn synthetic(spec: &crate::graph::arch::ArchSpec, seed: u64) -> Self {
        use crate::util::prop::Rng;
        let mut rng = Rng::new(seed);
        let (head, convs) = spec.layers.split_last().expect("spec has layers");
        let mut ops = vec![Op::Input { bits: 4, scale: 1.0 / 15.0 }];
        for l in convs {
            let cols = if l.kind == ConvKind::Dw { l.k * l.k } else { l.k * l.k * l.cin };
            let (wlo, whi) = crate::quant::weight_qrange(l.w_bits);
            let thresholds: Vec<Vec<i32>> = (0..l.cout)
                .map(|_| {
                    let base = rng.range_i32(-20, 20);
                    let step = rng.range_i32(1, 5);
                    (0..15).map(|i| base + i * step).collect()
                })
                .collect();
            ops.push(Op::Conv {
                name: l.name.clone(),
                kind: l.kind,
                cin: l.cin,
                cout: l.cout,
                k: l.k,
                stride: l.stride,
                pad: (l.k - 1) / 2,
                w_bits: l.w_bits,
                in_bits: l.a_bits,
                out_bits: 4,
                w_codes: (0..l.cout).map(|_| rng.vec_i32(cols, wlo, whi)).collect(),
                thresholds,
                signs: (0..l.cout).map(|_| if rng.below(8) == 0 { -1 } else { 1 }).collect(),
                consts: vec![0; l.cout],
                out_scale: 0.1,
            });
        }
        ops.push(Op::PoolSum {});
        ops.push(Op::Dense {
            name: head.name.clone(),
            cin: head.cin,
            cout: head.cout,
            w_bits: head.w_bits,
            w_codes: (0..head.cin).map(|_| rng.vec_i32(head.cout, -128, 127)).collect(),
            scale: (0..head.cout).map(|_| rng.range_f64(0.001, 0.02) as f32).collect(),
            bias: (0..head.cout).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
        });
        let net = Network {
            meta: Meta {
                image_size: spec.input_hw,
                in_ch: spec.input_ch,
                num_classes: head.cout,
                in_scale: 1.0 / 15.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops,
        };
        debug_assert!(net.validate().is_ok(), "synthetic network invalid");
        net
    }

    /// Structural validation: shapes, code ranges, threshold consistency.
    pub fn validate(&self) -> Result<(), String> {
        for op in &self.ops {
            if let Op::Conv {
                name,
                kind,
                cin,
                cout,
                k,
                w_bits,
                w_codes,
                thresholds,
                signs,
                consts,
                ..
            } = op
            {
                let rows = if *kind == ConvKind::Dw { *cout } else { *cout };
                let cols = if *kind == ConvKind::Dw { k * k } else { k * k * cin };
                if w_codes.len() != rows {
                    return Err(format!("{name}: {} weight rows, want {rows}", w_codes.len()));
                }
                for (r, row) in w_codes.iter().enumerate() {
                    if row.len() != cols {
                        return Err(format!("{name}: row {r} has {} cols, want {cols}", row.len()));
                    }
                }
                let (lo, hi) = crate::quant::weight_qrange(*w_bits);
                let bad = w_codes.iter().flatten().any(|&w| w < lo || w > hi);
                if bad {
                    return Err(format!("{name}: weight code out of {w_bits}-bit range"));
                }
                let mt = MultiThreshold {
                    thresholds: thresholds.clone(),
                    signs: signs.clone(),
                    consts: consts.clone(),
                };
                if mt.channels() != *cout {
                    return Err(format!("{name}: {} threshold channels, want {cout}", mt.channels()));
                }
                mt.validate().map_err(|e| format!("{name}: {e}"))?;
            }
        }
        Ok(())
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_conv() -> Op {
        Op::Conv {
            name: "c".into(),
            kind: ConvKind::Pw,
            cin: 2,
            cout: 2,
            k: 1,
            stride: 1,
            pad: 0,
            w_bits: 4,
            in_bits: 4,
            out_bits: 4,
            w_codes: vec![vec![1, -3], vec![7, -8]],
            thresholds: vec![vec![0; 15], vec![0; 15]],
            signs: vec![1, 1],
            consts: vec![0, 0],
            out_scale: 0.1,
        }
    }

    fn tiny_net() -> Network {
        Network {
            meta: Meta {
                image_size: 2,
                in_ch: 2,
                num_classes: 2,
                in_scale: 1.0 / 255.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 / 15.0 },
                tiny_conv(),
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: 2,
                    cout: 2,
                    w_bits: 8,
                    w_codes: vec![vec![1, 2], vec![3, 4]],
                    scale: vec![0.1, 0.1],
                    bias: vec![0.0, 0.0],
                },
            ],
        }
    }

    #[test]
    fn json_decode_export_format() {
        // exactly the structure python/compile/aot.py writes
        let text = r#"{
          "meta": {"image_size": 2, "in_ch": 2, "num_classes": 2,
                   "in_scale": 0.00392, "w_bits": 4, "a_bits": 4},
          "ops": [
            {"op": "input", "bits": 4, "scale": 0.0667},
            {"op": "conv", "name": "c", "kind": "pw", "cin": 2, "cout": 1,
             "k": 1, "stride": 1, "pad": 0, "w_bits": 4, "in_bits": 4,
             "out_bits": 4, "w_codes": [[1, -3]],
             "thresholds": [[0,1,2,3,4,5,6,7,8,9,10,11,12,13,14]],
             "signs": [1], "consts": [0], "out_scale": 0.1},
            {"op": "res_push"},
            {"op": "res_add", "bits": 4},
            {"op": "pool_sum"},
            {"op": "dense", "name": "fc", "cin": 1, "cout": 2, "w_bits": 8,
             "w_codes": [[1, 2]], "scale": [0.1, 0.2], "bias": [0.0, -1.5]}
          ]
        }"#;
        let net = Network::from_json_str(text).unwrap();
        assert_eq!(net.ops.len(), 6);
        assert!(net.validate().is_ok());
        assert!(matches!(net.ops[2], Op::ResPush {}));
        // conv_at / dense_head guarantee the variant, so the patterns
        // below are irrefutable in practice — no panic arms needed
        let conv = net.conv_at(1).expect("op 1 decodes as a conv");
        if let Op::Conv { w_codes, kind, .. } = conv {
            assert_eq!(w_codes[0], vec![1, -3]);
            assert_eq!(*kind, ConvKind::Pw);
        }
        let dense = net.dense_head().expect("export has a dense head");
        if let Op::Dense { bias, .. } = dense {
            assert_eq!(bias[1], -1.5);
        }
    }

    #[test]
    fn ops_per_image_from_shapes() {
        // tiny_net: pw conv 2->2 on a 2x2 input (4 px, 2 weights/output)
        // = 2*4*2*2 = 32 ops; dense 2x2 = 8 ops
        assert_eq!(tiny_net().ops_per_image(), 40);
    }

    #[test]
    fn synthetic_network_is_valid_and_deterministic() {
        let spec = crate::graph::arch::mobilenet_v2_small();
        let a = Network::synthetic(&spec, 7);
        let b = Network::synthetic(&spec, 7);
        assert!(a.validate().is_ok());
        assert_eq!(a.ops.len(), b.ops.len());
        assert_eq!(a.meta.image_size, 16);
        assert_eq!(a.meta.num_classes, 10);
        // same seed -> identical weights; shapes track the spec
        let ca = a.first_conv().expect("synthetic has a conv after input");
        let cb = b.first_conv().expect("synthetic has a conv after input");
        if let (Op::Conv { w_codes: wa, .. }, Op::Conv { w_codes: wb, .. }) = (ca, cb) {
            assert_eq!(wa, wb);
        }
        assert_eq!(a.convs().count(), spec.layers.len() - 1);
    }

    #[test]
    fn typed_accessors_are_panic_free() {
        let net = tiny_net();
        assert!(net.conv_at(1).is_some());
        assert!(net.conv_at(0).is_none(), "input op is not a conv");
        assert!(net.conv_at(99).is_none(), "out of range is None, not a panic");
        assert!(matches!(net.first_conv(), Some(Op::Conv { .. })));
        assert!(matches!(net.dense_head(), Some(Op::Dense { .. })));
        assert_eq!(net.io().image_size, 2);
        assert_eq!(net.io().num_classes, 2);
    }

    #[test]
    fn validate_rejects_out_of_range_weights() {
        let mut net = tiny_net();
        if let Op::Conv { w_codes, .. } = &mut net.ops[1] {
            w_codes[0][0] = 9; // outside int4
        }
        assert!(net.validate().is_err());
    }

    #[test]
    fn validate_rejects_ragged_weights() {
        let mut net = tiny_net();
        if let Op::Conv { w_codes, .. } = &mut net.ops[1] {
            w_codes[0].push(0);
        }
        assert!(net.validate().is_err());
    }

    #[test]
    fn decode_rejects_unknown_tags() {
        let text = r#"{"meta": {"image_size": 2, "in_ch": 1, "num_classes": 2,
                       "in_scale": 1.0},
                      "ops": [{"op": "transmogrify"}]}"#;
        assert!(Network::from_json_str(text).is_err());
    }
}
