//! Architecture specifications (shapes only, no weights) used by the
//! synthesis analog, roofline analysis and the Table 2 harness.
//!
//! `mobilenet_v2_full` is the standard ImageNet MobileNetV2 the paper
//! accelerates (3.4M params, ~0.6 GOPs/inference); `mobilenet_v2_small`
//! mirrors the trained network in `python/compile/model.py`.


use super::network::ConvKind;

/// Shape-level description of one compute layer.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub kind: ConvKind,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    /// Input spatial side (square feature maps).
    pub in_hw: usize,
    pub w_bits: u32,
    pub a_bits: u32,
}

impl LayerSpec {
    /// Output spatial side (SAME padding).
    pub fn out_hw(&self) -> usize {
        self.in_hw.div_ceil(self.stride)
    }

    /// Effective dot-product length per output element.
    pub fn cin_eff(&self) -> usize {
        match self.kind {
            ConvKind::Dw => self.k * self.k,
            _ => self.k * self.k * self.cin,
        }
    }

    /// Multiplications per output pixel (all output channels).
    pub fn mults_per_pixel(&self) -> u64 {
        (self.cout * self.cin_eff()) as u64
    }

    /// Total MACs per image.
    pub fn macs(&self) -> u64 {
        let o = self.out_hw() as u64;
        o * o * self.mults_per_pixel()
    }

    /// Total operations per image (MACs x 2, the roofline convention).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Number of distinct weights.
    pub fn n_weights(&self) -> u64 {
        match self.kind {
            ConvKind::Dw => (self.cout * self.k * self.k) as u64,
            _ => (self.cout * self.cin * self.k * self.k) as u64,
        }
    }
}

/// A network architecture: ordered layers plus input geometry.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: String,
    pub input_hw: usize,
    pub input_ch: usize,
    pub layers: Vec<LayerSpec>,
}

impl ArchSpec {
    /// Total operations per inference (the GOPS denominator).
    pub fn ops_per_image(&self) -> u64 {
        self.layers.iter().map(LayerSpec::ops).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(LayerSpec::n_weights).sum()
    }
}

fn conv(
    name: impl Into<String>,
    kind: ConvKind,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    in_hw: usize,
    w_bits: u32,
    a_bits: u32,
) -> LayerSpec {
    LayerSpec { name: name.into(), kind, cin, cout, k, stride, in_hw, w_bits, a_bits }
}

/// Standard ImageNet MobileNetV2 1.0x @ 224 (Sandler et al. 2018), with
/// the paper's quantization scheme (W4A4, first/last layers 8-bit).
pub fn mobilenet_v2_full() -> ArchSpec {
    // (expansion t, channels c, repeats n, stride s) per the paper's Table 2
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut layers = Vec::new();
    let mut hw = 224usize;
    // stem: conv2d 3x3 s2, 3 -> 32, 8-bit first layer
    layers.push(conv("stem", ConvKind::Std, 3, 32, 3, 2, hw, 8, 8));
    hw /= 2;
    let mut cin = 32usize;
    for (bi, &(t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let mid = cin * t;
            let base = format!("b{bi}_{r}");
            if t != 1 {
                layers.push(conv(format!("{base}_exp"), ConvKind::Pw, cin, mid, 1, 1, hw, 4, 4));
            }
            layers.push(conv(format!("{base}_dw"), ConvKind::Dw, mid, mid, 3, stride, hw, 4, 4));
            hw = hw.div_ceil(stride);
            layers.push(conv(format!("{base}_proj"), ConvKind::Pw, mid, c, 1, 1, hw, 4, 4));
            cin = c;
        }
    }
    // head conv 1x1 320 -> 1280, then classifier (1x1 conv over pooled map)
    layers.push(conv("head", ConvKind::Pw, cin, 1280, 1, 1, hw, 4, 4));
    layers.push(conv("fc", ConvKind::Pw, 1280, 1000, 1, 1, 1, 8, 8));
    ArchSpec { name: "MobileNetV2".into(), input_hw: 224, input_ch: 3, layers }
}

/// The scaled-down trained network (mirror of `python/compile/model.py`).
pub fn mobilenet_v2_small() -> ArchSpec {
    let mut layers = Vec::new();
    let mut hw = 16usize;
    layers.push(conv("stem", ConvKind::Std, 3, 16, 3, 1, hw, 8, 4));
    let blocks: [(usize, usize, usize, bool); 4] =
        [(2, 24, 2, false), (2, 24, 1, true), (2, 32, 2, false), (2, 32, 1, true)];
    let mut cin = 16usize;
    for (bi, &(t, c, s, _res)) in blocks.iter().enumerate() {
        let mid = cin * t;
        layers.push(conv(format!("ir{bi}_exp"), ConvKind::Pw, cin, mid, 1, 1, hw, 4, 4));
        layers.push(conv(format!("ir{bi}_dw"), ConvKind::Dw, mid, mid, 3, s, hw, 4, 4));
        hw = hw.div_ceil(s);
        layers.push(conv(format!("ir{bi}_proj"), ConvKind::Pw, mid, c, 1, 1, hw, 4, 4));
        cin = c;
    }
    layers.push(conv("head", ConvKind::Pw, cin, 64, 1, 1, hw, 4, 4));
    layers.push(conv("fc", ConvKind::Pw, 64, 10, 1, 1, 1, 8, 8));
    ArchSpec { name: "MobileNetV2-small".into(), input_hw: 16, input_ch: 3, layers }
}

/// The paper's Figure 6 layer: second convolution of MobileNetV2 — a
/// 1x1 kernel with 32 input and 32 output channels (1024 4-bit weights).
pub fn fig6_conv2() -> LayerSpec {
    conv("conv2", ConvKind::Pw, 32, 32, 1, 1, 112, 4, 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mobilenet_ops_match_paper() {
        // MobileNetV2 @224 is ~300M MACs = ~0.6 GOPs; the paper's Table 2
        // implies 978.6 GOPS / 1627 FPS = 0.6015 GOPs per image.
        let arch = mobilenet_v2_full();
        let gops = arch.ops_per_image() as f64 / 1e9;
        assert!((gops - 0.60).abs() < 0.06, "got {gops} GOPs");
    }

    #[test]
    fn full_mobilenet_param_count() {
        // 3.4M params (paper section 4.1). Conv layers only (no BN).
        let arch = mobilenet_v2_full();
        let m = arch.total_weights() as f64 / 1e6;
        assert!((m - 3.4).abs() < 0.3, "got {m}M weights");
    }

    #[test]
    fn layer_geometry() {
        let l = conv("t", ConvKind::Std, 3, 32, 3, 2, 224, 8, 8);
        assert_eq!(l.out_hw(), 112);
        assert_eq!(l.cin_eff(), 27);
        assert_eq!(l.macs(), 112 * 112 * 32 * 27);
    }

    #[test]
    fn depthwise_geometry() {
        let l = conv("dw", ConvKind::Dw, 32, 32, 3, 1, 56, 4, 4);
        assert_eq!(l.cin_eff(), 9);
        assert_eq!(l.n_weights(), 32 * 9);
        assert_eq!(l.mults_per_pixel(), 32 * 9);
    }

    #[test]
    fn fig6_layer_is_1024_weights() {
        let l = fig6_conv2();
        assert_eq!(l.n_weights(), 1024);
        assert_eq!(l.mults_per_pixel(), 1024);
    }

    #[test]
    fn small_arch_matches_python_model() {
        let a = mobilenet_v2_small();
        assert_eq!(a.layers.len(), 1 + 4 * 3 + 2);
        assert_eq!(a.input_hw, 16);
        // stem 8-bit, middle 4-bit, fc 8-bit
        assert_eq!(a.layers[0].w_bits, 8);
        assert_eq!(a.layers.last().unwrap().w_bits, 8);
    }
}
