//! Structured pruning specs for sparsity-aware plan compilation
//! (DESIGN.md S23). A [`PruneSpec`] names which output channels and
//! which weight columns (im2row taps x input channels) of each conv
//! survive; `NetworkPlan::compile_pruned` consumes it to build
//! compacted plans whose LUT tables and batch-major sweeps touch only
//! live work, while [`PruneSpec::masked_network`] produces the dense
//! witness — the same network with the pruned weights zeroed — that the
//! pruned plan must match bit-for-bit (tests/prune.rs).
//!
//! Masks are resolved against the ORIGINAL network: magnitude ranking
//! uses the unmasked weights, so a pruned compile and its masked dense
//! reference always agree on which rows/columns were dropped.

use std::collections::BTreeMap;

use super::network::{Network, Op};

/// What to prune, either by global magnitude fractions or by explicit
/// per-layer masks (`true` = keep). Explicit masks win over the
/// magnitude fractions for the layers they name; all other convs fall
/// back to magnitude ranking.
#[derive(Debug, Clone, Default)]
pub struct PruneSpec {
    /// Fraction of output channels to drop per conv, magnitude-ranked
    /// by row L1 (ties broken by index, lowest pruned first). At least
    /// one channel always survives.
    pub channel_sparsity: f64,
    /// Fraction of weight columns (tap x cin for std/pw, taps for
    /// depthwise) to drop per conv, ranked by column L1 over the
    /// surviving rows. At least one column always survives.
    pub tap_sparsity: f64,
    /// Explicit keep-mask per conv name, length `cout` — test injection
    /// and hand-tuned schedules.
    pub channel_masks: BTreeMap<String, Vec<bool>>,
    /// Explicit keep-mask per conv name, length `cols`.
    pub tap_masks: BTreeMap<String, Vec<bool>>,
}

impl PruneSpec {
    /// Magnitude-based channel pruning at the given sparsity.
    pub fn channels(channel_sparsity: f64) -> Self {
        PruneSpec { channel_sparsity, ..Default::default() }
    }

    /// Magnitude-based channel + tap pruning.
    pub fn channels_and_taps(channel_sparsity: f64, tap_sparsity: f64) -> Self {
        PruneSpec { channel_sparsity, tap_sparsity, ..Default::default() }
    }

    /// Inject an explicit channel keep-mask for one conv (`true` = keep).
    pub fn with_channel_mask(mut self, name: &str, mask: Vec<bool>) -> Self {
        self.channel_masks.insert(name.to_string(), mask);
        self
    }

    /// Inject an explicit column keep-mask for one conv (`true` = keep).
    pub fn with_tap_mask(mut self, name: &str, mask: Vec<bool>) -> Self {
        self.tap_masks.insert(name.to_string(), mask);
        self
    }

    /// A spec that prunes nothing at all — `compile_pruned` with a noop
    /// spec is exactly `compile`.
    pub fn is_noop(&self) -> bool {
        self.channel_sparsity <= 0.0
            && self.tap_sparsity <= 0.0
            && self.channel_masks.is_empty()
            && self.tap_masks.is_empty()
    }

    /// Resolve the keep-masks for one conv op: `(row_mask, col_mask)`,
    /// `true` = keep, lengths `cout` and `w_codes[0].len()`. Columns
    /// that are all-zero across the surviving rows are always dropped
    /// (their LUT table column is identically zero), independent of
    /// `tap_sparsity`. Returns `None` for non-conv ops.
    pub fn resolve(&self, op: &Op) -> Option<(Vec<bool>, Vec<bool>)> {
        let Op::Conv { name, cout, w_codes, .. } = op else {
            return None;
        };
        let rows = *cout;
        let cols = w_codes[0].len();

        let row_mask: Vec<bool> = match self.channel_masks.get(name) {
            Some(m) => {
                assert_eq!(m.len(), rows, "{name}: channel mask length != cout");
                assert!(m.iter().any(|&b| b), "{name}: channel mask keeps no channels");
                m.clone()
            }
            None => {
                let l1 = |r: &Vec<i32>| r.iter().map(|&w| (w as i64).abs()).sum::<i64>();
                magnitude_mask(self.channel_sparsity, &w_codes.iter().map(l1).collect::<Vec<_>>())
            }
        };

        let col_l1 = |c: usize| -> i64 {
            w_codes
                .iter()
                .enumerate()
                .filter(|(r, _)| row_mask[*r])
                .map(|(_, row)| (row[c] as i64).abs())
                .sum()
        };
        let col_l1s: Vec<i64> = (0..cols).map(col_l1).collect();
        let mut col_mask: Vec<bool> = match self.tap_masks.get(name) {
            Some(m) => {
                assert_eq!(m.len(), cols, "{name}: tap mask length != cols");
                assert!(m.iter().any(|&b| b), "{name}: tap mask keeps no columns");
                m.clone()
            }
            None => magnitude_mask(self.tap_sparsity, &col_l1s),
        };
        // zero-weight columns contribute nothing on any datapath: drop
        // them even when the spec names only channels
        for (c, keep) in col_mask.iter_mut().enumerate() {
            if col_l1s[c] == 0 {
                *keep = false;
            }
        }
        if !col_mask.iter().any(|&b| b) {
            col_mask[0] = true; // degenerate all-zero layer: keep one column
        }
        Some((row_mask, col_mask))
    }

    /// The dense witness: the same network with every pruned row zeroed
    /// entirely and every pruned column zeroed in the surviving rows.
    /// Compiled with the plain dense `NetworkPlan::compile*`, it must
    /// produce bit-identical outputs to the pruned plan on every
    /// datapath and batch size.
    pub fn masked_network(&self, net: &Network) -> Network {
        let mut masked = net.clone();
        for op in &mut masked.ops {
            // rank against the original weights, then zero the clone's
            let Some((row_mask, col_mask)) = self.resolve(op) else {
                continue;
            };
            let Op::Conv { w_codes, .. } = op else { unreachable!() };
            for (r, row) in w_codes.iter_mut().enumerate() {
                if !row_mask[r] {
                    row.fill(0);
                } else {
                    for (c, w) in row.iter_mut().enumerate() {
                        if !col_mask[c] {
                            *w = 0;
                        }
                    }
                }
            }
        }
        masked
    }
}

/// Keep-mask over `scores`: drop the `floor(sparsity * n)` lowest
/// scores (ties broken by index), always keeping at least one entry.
fn magnitude_mask(sparsity: f64, scores: &[i64]) -> Vec<bool> {
    let n = scores.len();
    let drop = ((sparsity.clamp(0.0, 1.0) * n as f64).floor() as usize).min(n.saturating_sub(1));
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (scores[i], i));
    let mut mask = vec![true; n];
    for &i in &order[..drop] {
        mask[i] = false;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::network::ConvKind;

    fn conv(name: &str, w_codes: Vec<Vec<i32>>) -> Op {
        let cout = w_codes.len();
        Op::Conv {
            name: name.into(),
            kind: ConvKind::Pw,
            cin: w_codes[0].len(),
            cout,
            k: 1,
            stride: 1,
            pad: 0,
            w_bits: 4,
            in_bits: 4,
            out_bits: 4,
            w_codes,
            thresholds: vec![(0..15).collect(); cout],
            signs: vec![1; cout],
            consts: vec![0; cout],
            out_scale: 0.1,
        }
    }

    #[test]
    fn magnitude_mask_drops_lowest_and_keeps_one() {
        assert_eq!(magnitude_mask(0.5, &[5, 1, 9, 2]), vec![true, false, true, false]);
        assert_eq!(magnitude_mask(1.0, &[5, 1, 9]), vec![false, false, true]);
        assert_eq!(magnitude_mask(0.0, &[5, 1]), vec![true, true]);
    }

    #[test]
    fn resolve_ranks_rows_by_l1_and_drops_zero_columns() {
        // row L1: 4, 0, 9 -> 50% drops floor(1.5)=1 row, the all-zero one.
        // column 1 is zero across the survivors -> auto-dropped.
        let op = conv("c", vec![vec![3, 0, -1], vec![0, 0, 0], vec![-4, 0, 5]]);
        let (rm, cm) = PruneSpec::channels(0.5).resolve(&op).unwrap();
        assert_eq!(rm, vec![true, false, true]);
        assert_eq!(cm, vec![true, false, true]);
    }

    #[test]
    fn explicit_masks_win_over_magnitude() {
        let op = conv("c", vec![vec![9, 9], vec![1, 1]]);
        let spec = PruneSpec::channels(0.5).with_channel_mask("c", vec![false, true]);
        let (rm, _) = spec.resolve(&op).unwrap();
        assert_eq!(rm, vec![false, true], "mask overrides magnitude ranking");
    }

    #[test]
    fn masked_network_zeroes_pruned_rows_and_columns() {
        let net = Network {
            meta: crate::graph::network::Meta {
                image_size: 1,
                in_ch: 3,
                num_classes: 2,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![conv("c", vec![vec![3, 2, -1], vec![1, 0, 0]])],
        };
        let spec = PruneSpec::channels(0.5).with_tap_mask("c", vec![true, false, true]);
        let masked = spec.masked_network(&net);
        let Op::Conv { w_codes, .. } = &masked.ops[0] else { unreachable!() };
        assert_eq!(w_codes[0], vec![3, 0, -1], "pruned column zeroed in surviving row");
        assert_eq!(w_codes[1], vec![0, 0, 0], "pruned row zeroed entirely");
    }

    #[test]
    fn noop_spec_resolves_to_all_keep() {
        let spec = PruneSpec::default();
        assert!(spec.is_noop());
        let op = conv("c", vec![vec![1, 2], vec![3, 4]]);
        let (rm, cm) = spec.resolve(&op).unwrap();
        assert!(rm.iter().all(|&b| b) && cm.iter().all(|&b| b));
        assert!(!PruneSpec::channels(0.5).is_noop());
    }
}
