//! Reference integer executor — the spec-level engine of a streamlined
//! network (DESIGN.md S5/S17).
//!
//! `Executor::new` compiles the network ONCE into a
//! [`NetworkPlan`](super::plan::NetworkPlan) — flattened weights,
//! im2row tap offsets with an interior/border split, threshold tables,
//! and (on the `LutFabric` datapath) per-multiplier product tables read
//! out of the simulated LUT6_2 primitives at build time — then executes
//! the kernel functions of [`graph::kernels`](super::kernels) over it.
//!
//! The executor serves behind the engine's uniform backend contract
//! (`engine::ExecutorBackend`, DESIGN.md S19); the serving coordinator
//! and CLI drive it as a boxed `InferenceBackend`.
//!
//! Two multiply datapaths:
//!  * `Arithmetic`: plain integer multiply-accumulate (fast; used by the
//!    serving coordinator).
//!  * `LutFabric`: every 4-bit multiplication comes from simulated
//!    LUT6_2 primitives built from Figure 5 INIT vectors — memoized at
//!    plan-build time, bit-identical to reading the fabric per MAC
//!    (`NetworkPlan::compile_direct` keeps the per-MAC readout as the
//!    baseline). 8-bit layers (first/last) fall back to arithmetic,
//!    mirroring the paper where those layers use DSP packing.
//!
//! Both paths must agree bit-for-bit with each other and with the JAX
//! golden model (`python/compile/model.py::forward_int`).

use super::kernels;
use super::network::Network;
use super::plan::{NetworkPlan, PlanOp};

pub use super::plan::Datapath;

/// A [H, W, C] integer activation tensor (single image).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i32>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0; h * w * c] }
    }

    pub fn from_hwc(h: usize, w: usize, c: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), h * w * c);
        Self { h, w, c, data }
    }

    #[inline]
    pub fn get(&self, y: isize, x: isize, ch: usize) -> i32 {
        // zero padding outside bounds (exact: code 0 == value 0)
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.data[(y as usize * self.w + x as usize) * self.c + ch]
        }
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i32) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }
}

/// The reference executor: a compiled network plan plus batch drivers.
/// Holds its plan behind an `Arc` — the `Network` it was compiled from
/// can be dropped or mutated freely afterwards, and a pool of executors
/// over one plan ([`shared`](Self::shared), the engine's worker
/// factories) reads a single copy of the flattened weights and LUT
/// product tables.
pub struct Executor {
    plan: std::sync::Arc<NetworkPlan>,
}

impl Executor {
    /// Compile `net` for `datapath` (memoized LUT product tables on
    /// `LutFabric`) and wrap the plan in batch drivers.
    pub fn new(net: &Network, datapath: Datapath) -> Self {
        Self::from_plan(NetworkPlan::compile(net, datapath))
    }

    /// Run a pre-compiled plan — e.g. `NetworkPlan::compile_direct`'s
    /// per-MAC LUT-readout baseline (bench + equivalence tests).
    pub fn from_plan(plan: NetworkPlan) -> Self {
        Self::shared(std::sync::Arc::new(plan))
    }

    /// Run an already-shared plan without cloning it (DESIGN.md S19:
    /// every backend of an engine reads the engine's one compiled plan).
    pub fn shared(plan: std::sync::Arc<NetworkPlan>) -> Self {
        Self { plan }
    }

    /// The compiled plan — the shared geometry source the dataflow
    /// simulator and serving stack consume (DESIGN.md S17).
    pub fn plan(&self) -> &NetworkPlan {
        &self.plan
    }

    /// Run one image (`[H, W, C]` uint8 codes) to logits.
    pub fn execute(&self, image: &Tensor) -> Vec<f32> {
        self.execute_traced(image, &mut |_, _| {})
    }

    /// Batch-major fast path (DESIGN.md S5, EXPERIMENTS.md E9): run a
    /// whole batch to logits, bit-exact with `images.len()` independent
    /// [`execute`](Self::execute) calls.
    ///
    /// The batch is split into one contiguous chunk per available core
    /// (scoped threads; batch 1 never spawns), and each chunk executes
    /// *op-major*: every compiled layer plan runs across all of the
    /// chunk's images before the next layer starts, so the plan's
    /// flattened weights, thresholds and LUT product tables are fetched
    /// once per chunk instead of once per image. This is what turns the
    /// coordinator's dynamic batches into arithmetic throughput rather
    /// than just queueing fairness.
    pub fn run_batch(&self, images: &[Tensor]) -> Vec<Vec<f32>> {
        let cores =
            std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1);
        self.run_batch_with_threads(images, cores)
    }

    /// [`run_batch`](Self::run_batch) with an explicit thread cap. The
    /// coordinator divides the machine's cores across its worker pool so
    /// concurrent workers don't oversubscribe the CPU.
    pub fn run_batch_with_threads(&self, images: &[Tensor], max_threads: usize) -> Vec<Vec<f32>> {
        match images.len() {
            0 => Vec::new(),
            1 => vec![self.execute(&images[0])],
            n => {
                let threads = max_threads.max(1).min(n);
                if threads <= 1 {
                    return self.run_chunk(images);
                }
                let per = n.div_ceil(threads);
                std::thread::scope(|s| {
                    let handles: Vec<_> = images
                        .chunks(per)
                        .map(|chunk| s.spawn(move || self.run_chunk(chunk)))
                        .collect();
                    handles
                        .into_iter()
                        .flat_map(|h| h.join().expect("batch worker panicked"))
                        .collect()
                })
            }
        }
    }

    /// Op-major execution of one contiguous chunk of the batch. The
    /// per-image arithmetic is the same kernel code as `execute_traced`,
    /// so bit-exactness vs the sequential path holds by construction;
    /// only the loop nest order (layers outer, images inner) and the
    /// amortized per-layer plan lookups differ.
    fn run_chunk(&self, images: &[Tensor]) -> Vec<Vec<f32>> {
        let n = images.len();
        let mut xs: Vec<Tensor> = images.to_vec();
        let mut res_stacks: Vec<Vec<Tensor>> = vec![Vec::new(); n];
        let mut pooled: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut logits: Vec<Vec<f32>> = vec![Vec::new(); n];
        for op in &self.plan.ops {
            match op {
                PlanOp::Input => {}
                PlanOp::Conv(cp) => {
                    for x in xs.iter_mut() {
                        *x = kernels::conv(cp, x);
                    }
                }
                PlanOp::ResPush { .. } => {
                    for (i, x) in xs.iter().enumerate() {
                        res_stacks[i].push(x.clone());
                    }
                }
                PlanOp::ResAdd { bits } => {
                    for (i, x) in xs.iter_mut().enumerate() {
                        let saved = res_stacks[i].pop().expect("res_add without res_push");
                        kernels::res_add(x, &saved, *bits);
                    }
                }
                PlanOp::PoolSum { .. } => {
                    for (i, x) in xs.iter().enumerate() {
                        pooled[i] = kernels::pool_sum(x);
                    }
                }
                PlanOp::Dense(dp) => {
                    for (i, p) in pooled.iter().enumerate() {
                        logits[i] = kernels::dense(dp, p);
                    }
                }
            }
        }
        assert!(logits.iter().all(|l| !l.is_empty()), "network has no dense head");
        logits
    }

    /// Run one image, invoking `trace(op_index, tensor)` after every op
    /// that produces an activation tensor (used to cross-check the
    /// dataflow simulator stage by stage; plan ops are index-aligned
    /// with `Network::ops`).
    pub fn execute_traced(
        &self,
        image: &Tensor,
        trace: &mut dyn FnMut(usize, &Tensor),
    ) -> Vec<f32> {
        let mut x = image.clone();
        let mut res_stack: Vec<Tensor> = Vec::new();
        let mut pooled: Vec<i32> = Vec::new();
        let mut logits: Vec<f32> = Vec::new();
        for (oi, op) in self.plan.ops.iter().enumerate() {
            match op {
                PlanOp::Input => {}
                PlanOp::Conv(cp) => {
                    x = kernels::conv(cp, &x);
                    trace(oi, &x);
                }
                PlanOp::ResPush { .. } => res_stack.push(x.clone()),
                PlanOp::ResAdd { bits } => {
                    let saved = res_stack.pop().expect("res_add without res_push");
                    kernels::res_add(&mut x, &saved, *bits);
                    trace(oi, &x);
                }
                PlanOp::PoolSum { .. } => pooled = kernels::pool_sum(&x),
                PlanOp::Dense(dp) => logits = kernels::dense(dp, &pooled),
            }
        }
        assert!(!logits.is_empty(), "network has no dense head");
        logits
    }
}

/// Decode the raw test-set bytes exported by `aot.py`.
pub fn decode_test_images(bytes: &[u8], image_size: usize, in_ch: usize) -> Vec<Tensor> {
    let px = image_size * image_size * in_ch;
    bytes
        .chunks_exact(px)
        .map(|chunk| {
            Tensor::from_hwc(image_size, image_size, in_ch, chunk.iter().map(|&b| b as i32).collect())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::network::{ConvKind, Meta, Op};

    fn net_with_conv(kind: ConvKind, cin: usize, cout: usize, k: usize, stride: usize) -> Network {
        let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
        // identity-ish thresholds: code = clamp(acc, 0, 15) via t=1..15
        let thr: Vec<i32> = (1..=15).collect();
        Network {
            meta: Meta {
                image_size: 4,
                in_ch: cin,
                num_classes: cout,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 },
                Op::Conv {
                    name: "c".into(),
                    kind,
                    cin,
                    cout,
                    k,
                    stride,
                    pad: (k - 1) / 2,
                    w_bits: 4,
                    in_bits: 4,
                    out_bits: 4,
                    w_codes: vec![vec![1; cols]; cout],
                    thresholds: vec![thr.clone(); cout],
                    signs: vec![1; cout],
                    consts: vec![0; cout],
                    out_scale: 1.0,
                },
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: cout,
                    cout: 2,
                    w_bits: 8,
                    w_codes: vec![vec![1, -1]; cout],
                    scale: vec![1.0, 1.0],
                    bias: vec![0.0, 0.5],
                },
            ],
        }
    }

    #[test]
    fn pointwise_identity_weights() {
        let net = net_with_conv(ConvKind::Pw, 2, 2, 1, 1);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 2);
        img.set(0, 0, 0, 3);
        img.set(0, 0, 1, 4);
        let logits = ex.execute(&img);
        // conv: acc = 3+4 = 7 per out channel -> code 7 (threshold count)
        // pool: 7 per channel (only one nonzero pixel), dense: 14 vs -14+0.5
        assert_eq!(logits, vec![14.0, -13.5]);
    }

    #[test]
    fn lut_fabric_matches_arithmetic() {
        let mut net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        // randomize weights deterministically
        if let Op::Conv { w_codes, .. } = &mut net.ops[1] {
            let mut seed = 12345u64;
            for row in w_codes.iter_mut() {
                for v in row.iter_mut() {
                    seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                    *v = ((seed >> 33) % 16) as i32 - 8;
                }
            }
        }
        let a = Executor::new(&net, Datapath::Arithmetic);
        let b = Executor::new(&net, Datapath::LutFabric);
        let mut img = Tensor::zeros(4, 4, 3);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = (i % 16) as i32;
        }
        assert_eq!(a.execute(&img), b.execute(&img));
    }

    #[test]
    fn direct_lut_readout_matches_compiled_tables() {
        // the memoized product tables ARE the per-MAC fabric readout
        let net = net_with_conv(ConvKind::Std, 2, 3, 3, 1);
        let compiled = Executor::new(&net, Datapath::LutFabric);
        let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));
        let mut img = Tensor::zeros(4, 4, 2);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = ((i * 5) % 16) as i32;
        }
        assert_eq!(compiled.execute(&img), direct.execute(&img));
        assert_eq!(compiled.plan().lut_count(), direct.plan().lut_count());
    }

    #[test]
    fn depthwise_stride2() {
        let net = net_with_conv(ConvKind::Dw, 2, 2, 3, 2);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 2);
        for v in img.data.iter_mut() {
            *v = 1;
        }
        let logits = ex.execute(&img);
        // output 2x2; each output = count of in-bounds taps (weights 1),
        // thresholded to itself (<=15), pooled
        assert!(logits[0] > 0.0);
    }

    #[test]
    fn res_add_path() {
        // conv -> push -> conv -> add, all identity
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(2, Op::ResAdd { bits: 4 });
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        // ops: input, res_push, conv, conv, res_add, pool, dense — fix order:
        // we want input, res_push, conv, res_add
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 5);
        let logits = ex.execute(&img);
        // first conv: 5 -> 5; second conv 5 -> 5; add: 5+5=10; pool=10
        assert_eq!(logits[0], 10.0);
    }

    #[test]
    fn saturating_res_add_clamps_at_15() {
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 12);
        let logits = ex.execute(&img);
        // 12 through two convs stays 12; 12+12=24 -> clamps to 15
        assert_eq!(logits[0], 15.0);
    }

    #[test]
    fn run_batch_matches_sequential_execute() {
        // batch sizes around the thread-chunking edges, both datapaths
        let net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            let images: Vec<Tensor> = (0..9)
                .map(|s| {
                    let mut img = Tensor::zeros(4, 4, 3);
                    for (i, v) in img.data.iter_mut().enumerate() {
                        *v = ((i + s * 7) % 16) as i32;
                    }
                    img
                })
                .collect();
            for n in [0usize, 1, 2, 3, 9] {
                let got = ex.run_batch(&images[..n]);
                let want: Vec<Vec<f32>> = images[..n].iter().map(|t| ex.execute(t)).collect();
                assert_eq!(got, want, "batch {n}, {dp:?}");
            }
        }
    }

    #[test]
    fn run_batch_handles_residual_state_per_image() {
        // res-push/add state must stay per-image in the op-major loop
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let images: Vec<Tensor> = (0..5)
            .map(|s| {
                let mut img = Tensor::zeros(4, 4, 1);
                img.set(0, 0, 0, s as i32 + 3);
                img
            })
            .collect();
        let got = ex.run_batch(&images);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(got[i], ex.execute(img), "image {i}");
        }
    }

    #[test]
    fn decode_test_images_shapes() {
        let bytes: Vec<u8> = (0..2 * 4 * 4 * 3).map(|i| (i % 256) as u8).collect();
        let imgs = decode_test_images(&bytes, 4, 3);
        assert_eq!(imgs.len(), 2);
        assert_eq!(imgs[0].get(0, 0, 1), 1);
    }
}
