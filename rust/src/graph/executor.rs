//! Reference integer executor — the spec-level interpreter of a
//! streamlined network (DESIGN.md S5).
//!
//! Two multiply datapaths:
//!  * `Arithmetic`: plain integer multiply-accumulate (fast; used by the
//!    serving coordinator).
//!  * `LutFabric`: every 4-bit multiplication is performed by *reading
//!    simulated LUT6_2 primitives* built from Figure 5 INIT vectors —
//!    the hardware-true datapath. 8-bit layers (first/last) fall back to
//!    arithmetic, mirroring the paper where those layers use DSP packing.
//!
//! Both paths must agree bit-for-bit with each other and with the JAX
//! golden model (`python/compile/model.py::forward_int`).

use crate::fabric::lutmul::ConstMultiplier;
use crate::quant::{saturating_res_add, MultiThreshold};

use super::network::{ConvKind, Network, Op};

/// A [H, W, C] integer activation tensor (single image).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i32>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0; h * w * c] }
    }

    pub fn from_hwc(h: usize, w: usize, c: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), h * w * c);
        Self { h, w, c, data }
    }

    #[inline]
    pub fn get(&self, y: isize, x: isize, ch: usize) -> i32 {
        // zero padding outside bounds (exact: code 0 == value 0)
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.data[(y as usize * self.w + x as usize) * self.c + ch]
        }
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i32) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }
}

/// Multiply datapath selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    Arithmetic,
    /// Read products out of simulated LUT6_2 fabric (w_bits <= 4 layers).
    LutFabric,
}

/// Pre-built LUT fabric for one conv layer: one `ConstMultiplier` per
/// *pair* of weights (Figure 5 packs two weights per 4 LUT6).
pub struct LayerFabric {
    mults: Vec<ConstMultiplier>,
    cols: usize,
}

impl LayerFabric {
    /// Embed a layer's weight matrix `[rows][cols]` into LUT multipliers,
    /// pairing weights along the column (input) dimension.
    pub fn build(w_codes: &[Vec<i32>], w_bits: u32) -> Self {
        assert!(w_bits <= 4, "Figure 5 packing requires <= 4-bit weights");
        let cols = w_codes[0].len();
        let pairs = cols.div_ceil(2);
        let mut mults = Vec::with_capacity(w_codes.len() * pairs);
        for row in w_codes {
            for p in 0..pairs {
                let w0 = row[2 * p];
                let w1 = if 2 * p + 1 < cols { row[2 * p + 1] } else { 0 };
                mults.push(ConstMultiplier::new(w0, w1, w_bits.max(1)));
            }
        }
        Self { mults, cols }
    }

    /// Product `w[row][col] * act` via LUT readout.
    #[inline]
    pub fn mul(&self, row: usize, col: usize, act: i32) -> i32 {
        let pairs = self.cols.div_ceil(2);
        let m = &self.mults[row * pairs + col / 2];
        m.eval(col % 2 == 1, act as u32)
    }

    /// Physical LUT6 count of this layer's multiplier array.
    pub fn lut_count(&self) -> usize {
        self.mults.iter().map(ConstMultiplier::lut_count).sum()
    }
}

/// Per-conv precomputed state: flattened weights + threshold unit
/// (built once in `Executor::new`; the hot loop must not allocate).
struct PreppedConv {
    mt: MultiThreshold,
    /// row-major `[rows][cols]` flattening of `w_codes`.
    wflat: Vec<i32>,
    cols: usize,
    /// row-major `[channels][levels]` flattening of the thresholds.
    thr_flat: Vec<i32>,
    levels: usize,
}

impl PreppedConv {
    /// Threshold application over the flattened levels — equivalent to
    /// `MultiThreshold::apply` (asserted by the module tests) but
    /// indirection-free and branchless (the 15-wide compare+sum
    /// vectorizes; an early-exit loop measured slower).
    #[inline]
    fn threshold(&self, acc: i32, ch: usize) -> i32 {
        let ts = &self.thr_flat[ch * self.levels..(ch + 1) * self.levels];
        match self.mt.signs[ch] {
            s if s > 0 => ts.iter().map(|&t| (acc >= t) as i32).sum(),
            s if s < 0 => ts.iter().map(|&t| (acc <= t) as i32).sum(),
            _ => self.mt.consts[ch],
        }
    }
}

/// The reference executor.
pub struct Executor<'n> {
    net: &'n Network,
    datapath: Datapath,
    fabrics: Vec<Option<LayerFabric>>, // one per op index
    prepped: Vec<Option<PreppedConv>>, // one per op index
}

impl<'n> Executor<'n> {
    pub fn new(net: &'n Network, datapath: Datapath) -> Self {
        let fabrics = net
            .ops
            .iter()
            .map(|op| match (datapath, op) {
                (Datapath::LutFabric, Op::Conv { w_codes, w_bits, in_bits, .. })
                    if *w_bits <= 4 && *in_bits <= 4 =>
                {
                    Some(LayerFabric::build(w_codes, *w_bits))
                }
                _ => None,
            })
            .collect();
        let prepped = net
            .ops
            .iter()
            .map(|op| match op {
                Op::Conv { w_codes, thresholds, signs, consts, .. } => Some(PreppedConv {
                    mt: MultiThreshold {
                        thresholds: thresholds.clone(),
                        signs: signs.clone(),
                        consts: consts.clone(),
                    },
                    wflat: w_codes.iter().flatten().copied().collect(),
                    cols: w_codes[0].len(),
                    thr_flat: thresholds.iter().flatten().copied().collect(),
                    levels: thresholds[0].len(),
                }),
                _ => None,
            })
            .collect();
        Self { net, datapath, fabrics, prepped }
    }

    /// Run one image (`[H, W, C]` uint8 codes) to logits.
    pub fn execute(&self, image: &Tensor) -> Vec<f32> {
        self.execute_traced(image, &mut |_, _| {})
    }

    /// Batch-major fast path (DESIGN.md S5, EXPERIMENTS.md E9): run a
    /// whole batch to logits, bit-exact with `images.len()` independent
    /// [`execute`](Self::execute) calls.
    ///
    /// The batch is split into one contiguous chunk per available core
    /// (scoped threads; batch 1 never spawns), and each chunk executes
    /// *op-major*: every streamlined layer runs across all of the chunk's
    /// images before the next layer starts, so the layer's flattened
    /// weights, thresholds and LUT fabric are fetched once per chunk
    /// instead of once per image. This is what turns the coordinator's
    /// dynamic batches into arithmetic throughput rather than just
    /// queueing fairness.
    pub fn run_batch(&self, images: &[Tensor]) -> Vec<Vec<f32>> {
        let cores =
            std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1);
        self.run_batch_with_threads(images, cores)
    }

    /// [`run_batch`](Self::run_batch) with an explicit thread cap. The
    /// coordinator divides the machine's cores across its worker pool so
    /// concurrent workers don't oversubscribe the CPU.
    pub fn run_batch_with_threads(&self, images: &[Tensor], max_threads: usize) -> Vec<Vec<f32>> {
        match images.len() {
            0 => Vec::new(),
            1 => vec![self.execute(&images[0])],
            n => {
                let threads = max_threads.max(1).min(n);
                if threads <= 1 {
                    return self.run_chunk(images);
                }
                let per = n.div_ceil(threads);
                std::thread::scope(|s| {
                    let handles: Vec<_> = images
                        .chunks(per)
                        .map(|chunk| s.spawn(move || self.run_chunk(chunk)))
                        .collect();
                    handles
                        .into_iter()
                        .flat_map(|h| h.join().expect("batch worker panicked"))
                        .collect()
                })
            }
        }
    }

    /// Op-major execution of one contiguous chunk of the batch. The
    /// per-image arithmetic is the same code as `execute_traced` (the
    /// `conv`/threshold/res-add/dense bodies), so bit-exactness vs the
    /// sequential path holds by construction; only the loop nest order
    /// (layers outer, images inner) and the amortized per-layer state
    /// lookups differ.
    fn run_chunk(&self, images: &[Tensor]) -> Vec<Vec<f32>> {
        let n = images.len();
        let mut xs: Vec<Tensor> = images.to_vec();
        let mut res_stacks: Vec<Vec<Tensor>> = vec![Vec::new(); n];
        let mut pooled: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut logits: Vec<Vec<f32>> = vec![Vec::new(); n];
        for (oi, op) in self.net.ops.iter().enumerate() {
            match op {
                Op::Input { .. } => {}
                Op::Conv { kind, cout, k, stride, pad, .. } => {
                    // per-layer state resolved once for the whole chunk
                    let prep = self.prepped[oi].as_ref().expect("conv prepped");
                    let fabric = self.fabrics[oi].as_ref();
                    for x in xs.iter_mut() {
                        *x = self.conv(x, *kind, *cout, *k, *stride, *pad, prep, fabric);
                    }
                }
                Op::ResPush {} => {
                    for (i, x) in xs.iter().enumerate() {
                        res_stacks[i].push(x.clone());
                    }
                }
                Op::ResAdd { bits } => {
                    for (i, x) in xs.iter_mut().enumerate() {
                        let saved = res_stacks[i].pop().expect("res_add without res_push");
                        assert_eq!((saved.h, saved.w, saved.c), (x.h, x.w, x.c));
                        for (a, b) in x.data.iter_mut().zip(&saved.data) {
                            *a = saturating_res_add(*a, *b, *bits);
                        }
                    }
                }
                Op::PoolSum {} => {
                    for (i, x) in xs.iter().enumerate() {
                        let mut acc = vec![0; x.c];
                        for px in x.data.chunks_exact(x.c) {
                            for (a, &v) in acc.iter_mut().zip(px) {
                                *a += v;
                            }
                        }
                        pooled[i] = acc;
                    }
                }
                Op::Dense { cout, w_codes, scale, bias, .. } => {
                    for (i, p) in pooled.iter().enumerate() {
                        logits[i] = (0..*cout)
                            .map(|co| {
                                let acc: i64 = p
                                    .iter()
                                    .enumerate()
                                    .map(|(ci, &a)| a as i64 * w_codes[ci][co] as i64)
                                    .sum();
                                // FMA to match the golden (see execute_traced)
                                (acc as f32).mul_add(scale[co], bias[co])
                            })
                            .collect();
                    }
                }
            }
        }
        assert!(logits.iter().all(|l| !l.is_empty()), "network has no dense head");
        logits
    }

    /// Run one image, invoking `trace(op_index, tensor)` after every op
    /// that produces an activation tensor (used to cross-check the
    /// dataflow simulator stage by stage).
    pub fn execute_traced(
        &self,
        image: &Tensor,
        trace: &mut dyn FnMut(usize, &Tensor),
    ) -> Vec<f32> {
        let mut x = image.clone();
        let mut res_stack: Vec<Tensor> = Vec::new();
        let mut pooled: Vec<i32> = Vec::new();
        let mut logits: Vec<f32> = Vec::new();
        for (oi, op) in self.net.ops.iter().enumerate() {
            match op {
                Op::Input { .. } => {}
                Op::Conv { kind, cout, k, stride, pad, .. } => {
                    let prep = self.prepped[oi].as_ref().expect("conv prepped");
                    x = self.conv(&x, *kind, *cout, *k, *stride, *pad, prep, self.fabrics[oi].as_ref());
                    trace(oi, &x);
                }
                Op::ResPush {} => res_stack.push(x.clone()),
                Op::ResAdd { bits } => {
                    let saved = res_stack.pop().expect("res_add without res_push");
                    assert_eq!((saved.h, saved.w, saved.c), (x.h, x.w, x.c));
                    for (a, b) in x.data.iter_mut().zip(&saved.data) {
                        *a = saturating_res_add(*a, *b, *bits);
                    }
                    trace(oi, &x);
                }
                Op::PoolSum {} => {
                    pooled = vec![0; x.c];
                    for y in 0..x.h {
                        for xx in 0..x.w {
                            for ch in 0..x.c {
                                pooled[ch] += x.get(y as isize, xx as isize, ch);
                            }
                        }
                    }
                }
                Op::Dense { cout, w_codes, scale, bias, .. } => {
                    logits = (0..*cout)
                        .map(|co| {
                            let acc: i64 = pooled
                                .iter()
                                .enumerate()
                                .map(|(ci, &a)| a as i64 * w_codes[ci][co] as i64)
                                .sum();
                            // fused multiply-add: XLA CPU emits an FMA for
                            // `acc * scale + bias`, so a separate mul+add
                            // here would differ by 1 ULP from the golden
                            (acc as f32).mul_add(scale[co], bias[co])
                        })
                        .collect();
                }
            }
        }
        assert!(!logits.is_empty(), "network has no dense head");
        logits
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        x: &Tensor,
        kind: ConvKind,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        prep: &PreppedConv,
        fabric: Option<&LayerFabric>,
    ) -> Tensor {
        let ho = (x.h + 2 * pad - k) / stride + 1;
        let wo = (x.w + 2 * pad - k) / stride + 1;
        let mut out = Tensor::zeros(ho, wo, cout);
        let cols = prep.cols;

        // fast path: pointwise conv on the arithmetic datapath — a matmul
        // over contiguous HWC pixels (the bulk of MobileNetV2's MACs)
        if kind == ConvKind::Pw && k == 1 && stride == 1 && fabric.is_none() {
            let cin = x.c;
            for px in 0..x.h * x.w {
                let xs = &x.data[px * cin..(px + 1) * cin];
                let o = &mut out.data[px * cout..(px + 1) * cout];
                for (co, slot) in o.iter_mut().enumerate() {
                    let row = &prep.wflat[co * cols..(co + 1) * cols];
                    let mut acc: i32 = 0;
                    for (w, a) in row.iter().zip(xs) {
                        acc += w * a;
                    }
                    *slot = prep.threshold(acc, co);
                }
            }
            return out;
        }

        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..cout {
                    let mut acc: i32 = 0;
                    match kind {
                        ConvKind::Dw => {
                            // one filter per channel: w[co][tap]
                            for i in 0..k {
                                for j in 0..k {
                                    let a = x.get(
                                        (oy * stride + i) as isize - pad as isize,
                                        (ox * stride + j) as isize - pad as isize,
                                        co,
                                    );
                                    let tap = i * k + j;
                                    acc += self.mul(fabric, prep, co, tap, a);
                                }
                            }
                        }
                        _ => {
                            let cin = x.c;
                            for i in 0..k {
                                for j in 0..k {
                                    for ci in 0..cin {
                                        let a = x.get(
                                            (oy * stride + i) as isize - pad as isize,
                                            (ox * stride + j) as isize - pad as isize,
                                            ci,
                                        );
                                        let col = (i * k + j) * cin + ci;
                                        acc += self.mul(fabric, prep, co, col, a);
                                    }
                                }
                            }
                        }
                    }
                    out.set(oy, ox, co, prep.threshold(acc, co));
                }
            }
        }
        out
    }

    #[inline]
    fn mul(&self, fabric: Option<&LayerFabric>, prep: &PreppedConv, row: usize, col: usize, a: i32) -> i32 {
        match (self.datapath, fabric) {
            (Datapath::LutFabric, Some(f)) => f.mul(row, col, a),
            _ => prep.wflat[row * prep.cols + col] * a,
        }
    }
}

/// Decode the raw test-set bytes exported by `aot.py`.
pub fn decode_test_images(bytes: &[u8], image_size: usize, in_ch: usize) -> Vec<Tensor> {
    let px = image_size * image_size * in_ch;
    bytes
        .chunks_exact(px)
        .map(|chunk| {
            Tensor::from_hwc(image_size, image_size, in_ch, chunk.iter().map(|&b| b as i32).collect())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::network::{Meta, Op};

    fn net_with_conv(kind: ConvKind, cin: usize, cout: usize, k: usize, stride: usize) -> Network {
        let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
        // identity-ish thresholds: code = clamp(acc, 0, 15) via t=1..15
        let thr: Vec<i32> = (1..=15).collect();
        Network {
            meta: Meta {
                image_size: 4,
                in_ch: cin,
                num_classes: cout,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 },
                Op::Conv {
                    name: "c".into(),
                    kind,
                    cin,
                    cout,
                    k,
                    stride,
                    pad: (k - 1) / 2,
                    w_bits: 4,
                    in_bits: 4,
                    out_bits: 4,
                    w_codes: vec![vec![1; cols]; cout],
                    thresholds: vec![thr.clone(); cout],
                    signs: vec![1; cout],
                    consts: vec![0; cout],
                    out_scale: 1.0,
                },
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: cout,
                    cout: 2,
                    w_bits: 8,
                    w_codes: vec![vec![1, -1]; cout],
                    scale: vec![1.0, 1.0],
                    bias: vec![0.0, 0.5],
                },
            ],
        }
    }

    #[test]
    fn pointwise_identity_weights() {
        let net = net_with_conv(ConvKind::Pw, 2, 2, 1, 1);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 2);
        img.set(0, 0, 0, 3);
        img.set(0, 0, 1, 4);
        let logits = ex.execute(&img);
        // conv: acc = 3+4 = 7 per out channel -> code 7 (threshold count)
        // pool: 7 per channel (only one nonzero pixel), dense: 14 vs -14+0.5
        assert_eq!(logits, vec![14.0, -13.5]);
    }

    #[test]
    fn lut_fabric_matches_arithmetic() {
        let mut net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        // randomize weights deterministically
        if let Op::Conv { w_codes, .. } = &mut net.ops[1] {
            let mut seed = 12345u64;
            for row in w_codes.iter_mut() {
                for v in row.iter_mut() {
                    seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                    *v = ((seed >> 33) % 16) as i32 - 8;
                }
            }
        }
        let a = Executor::new(&net, Datapath::Arithmetic);
        let b = Executor::new(&net, Datapath::LutFabric);
        let mut img = Tensor::zeros(4, 4, 3);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = (i % 16) as i32;
        }
        assert_eq!(a.execute(&img), b.execute(&img));
    }

    #[test]
    fn depthwise_stride2() {
        let net = net_with_conv(ConvKind::Dw, 2, 2, 3, 2);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 2);
        for v in img.data.iter_mut() {
            *v = 1;
        }
        let logits = ex.execute(&img);
        // output 2x2; each output = count of in-bounds taps (weights 1),
        // thresholded to itself (<=15), pooled
        assert!(logits[0] > 0.0);
    }

    #[test]
    fn res_add_path() {
        // conv -> push -> conv -> add, all identity
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(2, Op::ResAdd { bits: 4 });
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        // ops: input, res_push, conv, conv, res_add, pool, dense — fix order:
        // we want input, res_push, conv, res_add
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 5);
        let logits = ex.execute(&img);
        // first conv: 5 -> 5; second conv 5 -> 5; add: 5+5=10; pool=10
        assert_eq!(logits[0], 10.0);
    }

    #[test]
    fn saturating_res_add_clamps_at_15() {
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 12);
        let logits = ex.execute(&img);
        // 12 through two convs stays 12; 12+12=24 -> clamps to 15
        assert_eq!(logits[0], 15.0);
    }

    #[test]
    fn run_batch_matches_sequential_execute() {
        // batch sizes around the thread-chunking edges, both datapaths
        let net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            let images: Vec<Tensor> = (0..9)
                .map(|s| {
                    let mut img = Tensor::zeros(4, 4, 3);
                    for (i, v) in img.data.iter_mut().enumerate() {
                        *v = ((i + s * 7) % 16) as i32;
                    }
                    img
                })
                .collect();
            for n in [0usize, 1, 2, 3, 9] {
                let got = ex.run_batch(&images[..n]);
                let want: Vec<Vec<f32>> = images[..n].iter().map(|t| ex.execute(t)).collect();
                assert_eq!(got, want, "batch {n}, {dp:?}");
            }
        }
    }

    #[test]
    fn run_batch_handles_residual_state_per_image() {
        // res-push/add state must stay per-image in the op-major loop
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let images: Vec<Tensor> = (0..5)
            .map(|s| {
                let mut img = Tensor::zeros(4, 4, 1);
                img.set(0, 0, 0, s as i32 + 3);
                img
            })
            .collect();
        let got = ex.run_batch(&images);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(got[i], ex.execute(img), "image {i}");
        }
    }

    #[test]
    fn decode_test_images_shapes() {
        let bytes: Vec<u8> = (0..2 * 4 * 4 * 3).map(|i| (i % 256) as u8).collect();
        let imgs = decode_test_images(&bytes, 4, 3);
        assert_eq!(imgs.len(), 2);
        assert_eq!(imgs[0].get(0, 0, 1), 1);
    }
}
