//! Reference integer executor — the spec-level engine of a streamlined
//! network (DESIGN.md S5/S17/S20).
//!
//! `Executor::new` compiles the network ONCE into a
//! [`NetworkPlan`](super::plan::NetworkPlan) — flattened weights,
//! im2row tap offsets with an interior/border split, threshold tables,
//! and (on the `LutFabric` datapath) activation-major product tables
//! read out of the simulated LUT6_2 primitives at build time — then
//! executes the kernel functions of [`graph::kernels`](super::kernels)
//! over it.
//!
//! Execution is **zero-allocation in steady state** (DESIGN.md S20):
//! every image runs inside a caller-owned [`Scratch`] arena — a
//! ping-pong pair of activation buffers sized from the plan's largest
//! layer footprint, plus residual/pool/dense scratch — via the
//! kernels' `_into` variants. [`run_batch_into`](Executor::run_batch_into)
//! threads one arena per worker thread through the batch, so a
//! persistent serving backend re-allocates nothing after its first
//! batch (`rust/tests/zero_alloc.rs` asserts this with a counting
//! allocator).
//!
//! Batches of two or more images run **batch-major** (DESIGN.md S22):
//! images interleaved `[pixel][n][c]` in the arena, the plan walked
//! once per chunk with the batch kernels so every looked-up product
//! column is amortized across the whole batch, with within-layer
//! output-row fan-out for heavy convs when the batch is too thin to
//! fill the cores. The pre-S22 per-image driver survives as
//! [`run_image_major_into`](Executor::run_image_major_into) — the perf
//! baseline and equivalence witness.
//!
//! Structurally pruned plans (`NetworkPlan::compile_pruned`, DESIGN.md
//! S23) run through the same drivers unchanged: the kernels dispatch on
//! `ConvPlan::prune` to compacted-index sparse bodies, and the arena
//! footprints are sized from the full-width geometry, so a pruned plan
//! is a drop-in for its dense witness — bit-exact against the dense
//! compile of `PruneSpec::masked_network` (tests/prune.rs).
//!
//! The executor serves behind the engine's uniform backend contract
//! (`engine::ExecutorBackend`, DESIGN.md S19); the serving coordinator
//! and CLI drive it as a boxed `InferenceBackend`.
//!
//! Two multiply datapaths:
//!  * `Arithmetic`: plain integer multiply-accumulate (fast; used by the
//!    serving coordinator).
//!  * `LutFabric`: every 4-bit multiplication comes from simulated
//!    LUT6_2 primitives built from Figure 5 INIT vectors — memoized at
//!    plan-build time into activation-major tables, bit-identical to
//!    reading the fabric per MAC (`NetworkPlan::compile_direct` keeps
//!    the per-MAC readout, `NetworkPlan::compile_mac_major` the old
//!    table layout, as baselines). 8-bit layers (first/last) fall back
//!    to arithmetic, mirroring the paper where those layers use DSP
//!    packing.
//!
//! Both paths must agree bit-for-bit with each other and with the JAX
//! golden model (`python/compile/model.py::forward_int`).

use super::kernels;
use super::network::Network;
use super::plan::{Multipliers, NetworkPlan, PlanOp};
use super::scratch::{Scratch, ScratchPool};

pub use super::plan::Datapath;

/// A [H, W, C] integer activation tensor (single image).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i32>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0; h * w * c] }
    }

    pub fn from_hwc(h: usize, w: usize, c: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), h * w * c);
        Self { h, w, c, data }
    }

    #[inline]
    pub fn get(&self, y: isize, x: isize, ch: usize) -> i32 {
        // zero padding outside bounds (exact: code 0 == value 0)
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.data[(y as usize * self.w + x as usize) * self.c + ch]
        }
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i32) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }
}

/// Minimum batch-weighted MAC count (`ConvPlan::macs() * nb`) for a
/// conv layer to fan its output rows across threads inside a
/// batch-major sweep — below it the scoped-thread spawn/join overhead
/// (tens of microseconds per layer) outweighs the parallel win, so
/// light layers run single-threaded within the sweep.
const ROW_PAR_MIN_MACS: u64 = 200_000;

/// The reference executor: a compiled network plan plus batch drivers.
/// Holds its plan behind an `Arc` — the `Network` it was compiled from
/// can be dropped or mutated freely afterwards, and a pool of executors
/// over one plan ([`shared`](Self::shared), the engine's worker
/// factories) reads a single copy of the flattened weights and LUT
/// product tables.
pub struct Executor {
    plan: std::sync::Arc<NetworkPlan>,
}

impl Executor {
    /// Compile `net` for `datapath` (memoized activation-major LUT
    /// product tables on `LutFabric`) and wrap the plan in batch
    /// drivers.
    pub fn new(net: &Network, datapath: Datapath) -> Self {
        Self::from_plan(NetworkPlan::compile(net, datapath))
    }

    /// Run a pre-compiled plan — e.g. `NetworkPlan::compile_direct`'s
    /// per-MAC LUT-readout baseline or `compile_mac_major`'s old table
    /// layout (bench + equivalence tests).
    pub fn from_plan(plan: NetworkPlan) -> Self {
        Self::shared(std::sync::Arc::new(plan))
    }

    /// Run an already-shared plan without cloning it (DESIGN.md S19:
    /// every backend of an engine reads the engine's one compiled plan).
    pub fn shared(plan: std::sync::Arc<NetworkPlan>) -> Self {
        Self { plan }
    }

    /// The compiled plan — the shared geometry source the dataflow
    /// simulator and serving stack consume (DESIGN.md S17).
    pub fn plan(&self) -> &NetworkPlan {
        &self.plan
    }

    /// Run one image (`[H, W, C]` uint8 codes) to logits (convenience:
    /// allocates a fresh arena — the fresh-allocation reference path
    /// the arena tests compare against).
    pub fn execute(&self, image: &Tensor) -> Vec<f32> {
        let nc = self.plan.dense_cout().expect("network has no dense head");
        let mut scratch = Scratch::for_plan(&self.plan);
        let mut logits = vec![0.0f32; nc];
        self.run_image(image, &mut scratch, None, &mut logits);
        logits
    }

    /// Run one image inside a caller-owned arena, writing the logits
    /// into `logits` (`[dense_cout]`) — the zero-allocation single-image
    /// entry point. The arena is grown to fit the plan if needed and
    /// may carry arbitrary garbage from previous images or other plans.
    pub fn execute_into(&self, image: &Tensor, scratch: &mut Scratch, logits: &mut [f32]) {
        self.run_image(image, scratch, None, logits);
    }

    /// Batch-major fast path (DESIGN.md S5/S20, EXPERIMENTS.md E9): run
    /// a whole batch to logits, bit-exact with `images.len()`
    /// independent [`execute`](Self::execute) calls.
    pub fn run_batch(&self, images: &[Tensor]) -> Vec<Vec<f32>> {
        let cores =
            std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1);
        self.run_batch_with_threads(images, cores)
    }

    /// [`run_batch`](Self::run_batch) with an explicit thread cap. The
    /// coordinator divides the machine's cores across its worker pool so
    /// concurrent workers don't oversubscribe the CPU. (Convenience over
    /// [`run_batch_into`](Self::run_batch_into) with a throwaway arena
    /// pool — persistent callers should hold their own pool.)
    pub fn run_batch_with_threads(&self, images: &[Tensor], max_threads: usize) -> Vec<Vec<f32>> {
        let mut pool = ScratchPool::new();
        let mut out = Vec::new();
        self.run_batch_into(images, max_threads, &mut pool, &mut out);
        out
    }

    /// The batch engine (DESIGN.md S22): run the batch **batch-major**
    /// — images interleaved `[pixel][n][c]` so every looked-up product
    /// column is amortized across the batch — choosing the parallelism
    /// shape from the batch width:
    ///
    ///  * one thread: a single batch-major sweep over one arena;
    ///  * a thin batch (`n < 2 * threads`, where chunking would hand
    ///    workers fewer than two images and kill the amortization): one
    ///    sweep whose heavy convs fan their output rows across the
    ///    worker threads instead;
    ///  * otherwise: one contiguous chunk per thread, each a batch-major
    ///    sweep over its own arena, chunk widths aligned to the plan's
    ///    widest batch tile when that costs no worker — so no chunk
    ///    splits a layer's SIMD batch tile below its width. The ragged
    ///    tail still runs batch-major at its own width.
    ///
    /// `out` is reused in place (inner `Vec`s keep their capacity), so a
    /// caller that holds its pool across batches — the serving backend —
    /// performs **zero heap allocation per image in steady state** on
    /// the single-thread path, and only the thread-spawn bookkeeping
    /// otherwise (`rust/tests/zero_alloc.rs`).
    pub fn run_batch_into(
        &self,
        images: &[Tensor],
        max_threads: usize,
        pool: &mut ScratchPool,
        out: &mut Vec<Vec<f32>>,
    ) {
        let n = images.len();
        out.truncate(n);
        while out.len() < n {
            out.push(Vec::new());
        }
        if n == 0 {
            return;
        }
        let nc = self.plan.dense_cout().expect("network has no dense head");
        for o in out.iter_mut() {
            o.clear();
            o.resize(nc, 0.0);
        }
        let threads = max_threads.max(1).min(n);
        pool.ensure(threads, &self.plan);
        if threads == 1 {
            self.run_chunk(images, &mut pool.slots[0], out);
            return;
        }
        if n < 2 * threads {
            self.run_sweep(images, &mut pool.slots[0], out, threads);
            return;
        }
        let per = n.div_ceil(threads);
        let tile = self.plan.batch_tile();
        let aligned = per.div_ceil(tile) * tile;
        // align only when it keeps every worker busy (same chunk count)
        let per = if n.div_ceil(aligned) == n.div_ceil(per) { aligned } else { per };
        std::thread::scope(|s| {
            let mut slots = pool.slots.as_mut_slice();
            let mut outs = out.as_mut_slice();
            for chunk in images.chunks(per) {
                let (o, outs_rest) = outs.split_at_mut(chunk.len());
                outs = outs_rest;
                let (slot, slots_rest) = slots.split_at_mut(1);
                slots = slots_rest;
                let scratch = &mut slot[0];
                s.spawn(move || self.run_chunk(chunk, scratch, o));
            }
        });
    }

    /// Image-major witness path — the pre-S22 batch driver: chunk the
    /// batch across threads and run every image alone through the
    /// per-image kernels over its worker's arena. Kept public as the
    /// baseline `benches/bench_kernels.rs` charts the batch-major
    /// speedup against and as an equivalence witness
    /// (`tests/kernels_batch.rs`); production callers use
    /// [`run_batch_into`](Self::run_batch_into).
    pub fn run_image_major_into(
        &self,
        images: &[Tensor],
        max_threads: usize,
        pool: &mut ScratchPool,
        out: &mut Vec<Vec<f32>>,
    ) {
        let n = images.len();
        out.truncate(n);
        while out.len() < n {
            out.push(Vec::new());
        }
        if n == 0 {
            return;
        }
        let nc = self.plan.dense_cout().expect("network has no dense head");
        for o in out.iter_mut() {
            o.clear();
            o.resize(nc, 0.0);
        }
        let threads = max_threads.max(1).min(n);
        pool.ensure(threads, &self.plan);
        if threads == 1 {
            self.run_images(images, &mut pool.slots[0], out);
            return;
        }
        let per = n.div_ceil(threads);
        std::thread::scope(|s| {
            let mut slots = pool.slots.as_mut_slice();
            let mut outs = out.as_mut_slice();
            for chunk in images.chunks(per) {
                let (o, outs_rest) = outs.split_at_mut(chunk.len());
                outs = outs_rest;
                let (slot, slots_rest) = slots.split_at_mut(1);
                slots = slots_rest;
                let scratch = &mut slot[0];
                s.spawn(move || self.run_images(chunk, scratch, o));
            }
        });
    }

    /// One thread's contiguous chunk of the batch: two or more images
    /// run as one batch-major sweep over the worker's arena
    /// ([`run_sweep`](Self::run_sweep)); a single image runs the
    /// image-major body (nothing to amortize across a batch of one).
    fn run_chunk(&self, images: &[Tensor], scratch: &mut Scratch, out: &mut [Vec<f32>]) {
        if images.len() >= 2 {
            self.run_sweep(images, scratch, out, 1);
        } else {
            self.run_images(images, scratch, out);
        }
    }

    /// Image-major chunk body: per image the kernels ping-pong between
    /// the arena's two activation buffers — no per-image or per-layer
    /// allocation. (The same `run_image` body every sequential entry
    /// point drives, so bit-exactness holds by construction.)
    fn run_images(&self, images: &[Tensor], scratch: &mut Scratch, out: &mut [Vec<f32>]) {
        for (img, o) in images.iter().zip(out.iter_mut()) {
            self.run_image(img, scratch, None, o);
        }
    }

    /// Batch-major layer sweep (DESIGN.md S22): interleave the images
    /// into the arena as `[pixel][n][c]`, then walk the plan ONCE with
    /// the batch kernels — each looked-up product column amortized
    /// across the whole batch — fanning a conv's output rows across
    /// `row_threads` scoped threads when the layer is heavy enough
    /// ([`ROW_PAR_MIN_MACS`]) to pay the spawn cost. Per image the
    /// accumulation order matches [`run_image`](Self::run_image)
    /// exactly, so the sweep is bit-exact with the image-major path.
    fn run_sweep(
        &self,
        images: &[Tensor],
        s: &mut Scratch,
        out: &mut [Vec<f32>],
        row_threads: usize,
    ) {
        let io = self.plan.io;
        let nb = images.len();
        for image in images {
            assert_eq!(
                (image.h, image.w, image.c),
                (io.image_size, io.image_size, io.in_ch),
                "input image shape disagrees with the compiled plan"
            );
        }
        s.ensure_batch(&self.plan, nb);
        let mut c = io.in_ch;
        let mut len = io.image_size * io.image_size * c; // per-image elems
        for (n, image) in images.iter().enumerate() {
            kernels::interleave_image(&image.data, n, nb, c, &mut s.ping[..nb * len]);
        }
        let mut res_depth = 0usize;
        let mut pooled_ch = 0usize;
        let mut wrote_logits = false;
        for op in self.plan.ops.iter() {
            match op {
                PlanOp::Input => {}
                PlanOp::Conv(cp) => {
                    let g = cp.geom;
                    let out_len = g.out_pixels() * g.cout;
                    if let Multipliers::LutApprox { layer } = &cp.mults {
                        // approx layers (DESIGN.md S24) run the two-pass
                        // codebook driver over the arena's codes slot
                        kernels::conv_batch_approx_into(
                            cp,
                            &s.ping[..nb * len],
                            nb,
                            &mut s.pong[..nb * out_len],
                            &mut s.codes[..nb * layer.n_codebooks],
                        );
                    } else {
                        let rt = if cp.macs().saturating_mul(nb as u64) >= ROW_PAR_MIN_MACS {
                            row_threads
                        } else {
                            1
                        };
                        kernels::conv_batch_into(
                            cp,
                            &s.ping[..nb * len],
                            nb,
                            &mut s.pong[..nb * out_len],
                            rt,
                        );
                    }
                    std::mem::swap(&mut s.ping, &mut s.pong);
                    c = g.cout;
                    len = out_len;
                }
                PlanOp::ResPush { .. } => {
                    let slot = &mut s.res[res_depth];
                    slot.clear();
                    slot.extend_from_slice(&s.ping[..nb * len]);
                    res_depth += 1;
                }
                PlanOp::ResAdd { bits } => {
                    res_depth = res_depth.checked_sub(1).expect("res_add without res_push");
                    kernels::res_add_into(&mut s.ping[..nb * len], &s.res[res_depth], *bits);
                }
                PlanOp::PoolSum { .. } => {
                    kernels::pool_sum_batch_into(&s.ping[..nb * len], nb, &mut s.pooled[..nb * c]);
                    pooled_ch = c;
                }
                PlanOp::Dense(dp) => {
                    kernels::dense_batch_into(
                        dp,
                        &s.pooled[..nb * pooled_ch],
                        nb,
                        &mut s.acc64[..nb * dp.cout],
                        out,
                    );
                    wrote_logits = true;
                }
            }
        }
        assert!(wrote_logits, "network has no dense head");
    }

    /// Run one image, invoking `trace(op_index, tensor)` after every op
    /// that produces an activation tensor (used to cross-check the
    /// dataflow simulator stage by stage; plan ops are index-aligned
    /// with `Network::ops`). The traced tensors are materialized copies
    /// of the arena buffers — the debug path pays that copy, the hot
    /// paths never trace.
    pub fn execute_traced(
        &self,
        image: &Tensor,
        trace: &mut dyn FnMut(usize, &Tensor),
    ) -> Vec<f32> {
        let nc = self.plan.dense_cout().expect("network has no dense head");
        let mut scratch = Scratch::for_plan(&self.plan);
        let mut logits = vec![0.0f32; nc];
        self.run_image(image, &mut scratch, Some(trace), &mut logits);
        logits
    }

    /// The one execution body every public entry point drives: walk the
    /// compiled ops over the arena's ping-pong buffers, writing the
    /// logits into `logits` (`[dense_cout]`).
    fn run_image(
        &self,
        image: &Tensor,
        s: &mut Scratch,
        mut trace: Option<&mut dyn FnMut(usize, &Tensor)>,
        logits: &mut [f32],
    ) {
        let io = self.plan.io;
        assert_eq!(
            (image.h, image.w, image.c),
            (io.image_size, io.image_size, io.in_ch),
            "input image shape disagrees with the compiled plan"
        );
        s.ensure(&self.plan);
        let (mut h, mut w, mut c) = (image.h, image.w, image.c);
        let mut len = h * w * c;
        s.ping[..len].copy_from_slice(&image.data);
        let mut res_depth = 0usize;
        let mut pooled_ch = 0usize;
        let mut wrote_logits = false;
        for (oi, op) in self.plan.ops.iter().enumerate() {
            match op {
                PlanOp::Input => {}
                PlanOp::Conv(cp) => {
                    let g = cp.geom;
                    let out_len = g.out_pixels() * g.cout;
                    kernels::conv_into(cp, &s.ping[..len], &mut s.pong[..out_len]);
                    std::mem::swap(&mut s.ping, &mut s.pong);
                    (h, w, c) = (g.out_h(), g.out_w(), g.cout);
                    len = out_len;
                    if let Some(t) = &mut trace {
                        t(oi, &Tensor::from_hwc(h, w, c, s.ping[..len].to_vec()));
                    }
                }
                PlanOp::ResPush { .. } => {
                    let slot = &mut s.res[res_depth];
                    slot.clear();
                    slot.extend_from_slice(&s.ping[..len]);
                    res_depth += 1;
                }
                PlanOp::ResAdd { bits } => {
                    res_depth = res_depth.checked_sub(1).expect("res_add without res_push");
                    kernels::res_add_into(&mut s.ping[..len], &s.res[res_depth], *bits);
                    if let Some(t) = &mut trace {
                        t(oi, &Tensor::from_hwc(h, w, c, s.ping[..len].to_vec()));
                    }
                }
                PlanOp::PoolSum { .. } => {
                    kernels::pool_sum_into(&s.ping[..len], &mut s.pooled[..c]);
                    pooled_ch = c;
                }
                PlanOp::Dense(dp) => {
                    kernels::dense_into(
                        dp,
                        &s.pooled[..pooled_ch],
                        &mut s.acc64[..dp.cout],
                        logits,
                    );
                    wrote_logits = true;
                }
            }
        }
        assert!(wrote_logits, "network has no dense head");
    }
}

/// Decode the raw test-set bytes exported by `aot.py`.
pub fn decode_test_images(bytes: &[u8], image_size: usize, in_ch: usize) -> Vec<Tensor> {
    let px = image_size * image_size * in_ch;
    bytes
        .chunks_exact(px)
        .map(|chunk| {
            Tensor::from_hwc(image_size, image_size, in_ch, chunk.iter().map(|&b| b as i32).collect())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::network::{ConvKind, Meta, Op};

    fn net_with_conv(kind: ConvKind, cin: usize, cout: usize, k: usize, stride: usize) -> Network {
        let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
        // identity-ish thresholds: code = clamp(acc, 0, 15) via t=1..15
        let thr: Vec<i32> = (1..=15).collect();
        Network {
            meta: Meta {
                image_size: 4,
                in_ch: cin,
                num_classes: cout,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 },
                Op::Conv {
                    name: "c".into(),
                    kind,
                    cin,
                    cout,
                    k,
                    stride,
                    pad: (k - 1) / 2,
                    w_bits: 4,
                    in_bits: 4,
                    out_bits: 4,
                    w_codes: vec![vec![1; cols]; cout],
                    thresholds: vec![thr.clone(); cout],
                    signs: vec![1; cout],
                    consts: vec![0; cout],
                    out_scale: 1.0,
                },
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: cout,
                    cout: 2,
                    w_bits: 8,
                    w_codes: vec![vec![1, -1]; cout],
                    scale: vec![1.0, 1.0],
                    bias: vec![0.0, 0.5],
                },
            ],
        }
    }

    #[test]
    fn pointwise_identity_weights() {
        let net = net_with_conv(ConvKind::Pw, 2, 2, 1, 1);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 2);
        img.set(0, 0, 0, 3);
        img.set(0, 0, 1, 4);
        let logits = ex.execute(&img);
        // conv: acc = 3+4 = 7 per out channel -> code 7 (threshold count)
        // pool: 7 per channel (only one nonzero pixel), dense: 14 vs -14+0.5
        assert_eq!(logits, vec![14.0, -13.5]);
    }

    #[test]
    fn lut_fabric_matches_arithmetic() {
        let mut net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        // randomize weights deterministically
        if let Op::Conv { w_codes, .. } = &mut net.ops[1] {
            let mut seed = 12345u64;
            for row in w_codes.iter_mut() {
                for v in row.iter_mut() {
                    seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                    *v = ((seed >> 33) % 16) as i32 - 8;
                }
            }
        }
        let a = Executor::new(&net, Datapath::Arithmetic);
        let b = Executor::new(&net, Datapath::LutFabric);
        let mut img = Tensor::zeros(4, 4, 3);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = (i % 16) as i32;
        }
        assert_eq!(a.execute(&img), b.execute(&img));
    }

    #[test]
    fn direct_lut_readout_matches_compiled_tables() {
        // the memoized product tables ARE the per-MAC fabric readout —
        // in both table layouts
        let net = net_with_conv(ConvKind::Std, 2, 3, 3, 1);
        let compiled = Executor::new(&net, Datapath::LutFabric);
        let direct = Executor::from_plan(NetworkPlan::compile_direct(&net, Datapath::LutFabric));
        let mac = Executor::from_plan(NetworkPlan::compile_mac_major(&net, Datapath::LutFabric));
        let mut img = Tensor::zeros(4, 4, 2);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = ((i * 5) % 16) as i32;
        }
        assert_eq!(compiled.execute(&img), direct.execute(&img));
        assert_eq!(compiled.execute(&img), mac.execute(&img));
        assert_eq!(compiled.plan().lut_count(), direct.plan().lut_count());
        assert_eq!(compiled.plan().lut_count(), mac.plan().lut_count());
    }

    #[test]
    fn depthwise_stride2() {
        let net = net_with_conv(ConvKind::Dw, 2, 2, 3, 2);
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 2);
        for v in img.data.iter_mut() {
            *v = 1;
        }
        let logits = ex.execute(&img);
        // output 2x2; each output = count of in-bounds taps (weights 1),
        // thresholded to itself (<=15), pooled
        assert!(logits[0] > 0.0);
    }

    #[test]
    fn res_add_path() {
        // conv -> push -> conv -> add, all identity
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(2, Op::ResAdd { bits: 4 });
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        // ops: input, res_push, conv, conv, res_add, pool, dense — fix order:
        // we want input, res_push, conv, res_add
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 5);
        let logits = ex.execute(&img);
        // first conv: 5 -> 5; second conv 5 -> 5; add: 5+5=10; pool=10
        assert_eq!(logits[0], 10.0);
    }

    #[test]
    fn saturating_res_add_clamps_at_15() {
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 12);
        let logits = ex.execute(&img);
        // 12 through two convs stays 12; 12+12=24 -> clamps to 15
        assert_eq!(logits[0], 15.0);
    }

    #[test]
    fn run_batch_matches_sequential_execute() {
        // batch sizes around the thread-chunking edges, both datapaths
        let net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            let images: Vec<Tensor> = (0..9)
                .map(|s| {
                    let mut img = Tensor::zeros(4, 4, 3);
                    for (i, v) in img.data.iter_mut().enumerate() {
                        *v = ((i + s * 7) % 16) as i32;
                    }
                    img
                })
                .collect();
            for n in [0usize, 1, 2, 3, 9] {
                let got = ex.run_batch(&images[..n]);
                let want: Vec<Vec<f32>> = images[..n].iter().map(|t| ex.execute(t)).collect();
                assert_eq!(got, want, "batch {n}, {dp:?}");
            }
        }
    }

    #[test]
    fn run_batch_into_reuses_a_dirty_pool_bit_exactly() {
        // persistent-arena contract: a poisoned pool and a reused output
        // vector must reproduce the fresh-allocation path exactly
        let net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        let ex = Executor::new(&net, Datapath::LutFabric);
        let images: Vec<Tensor> = (0..5)
            .map(|s| {
                let mut img = Tensor::zeros(4, 4, 3);
                for (i, v) in img.data.iter_mut().enumerate() {
                    *v = ((i * 3 + s) % 16) as i32;
                }
                img
            })
            .collect();
        let want: Vec<Vec<f32>> = images.iter().map(|t| ex.execute(t)).collect();
        let mut pool = ScratchPool::new();
        let mut out = vec![vec![99.0f32; 7]; 9]; // wrong shape on purpose
        ex.run_batch_into(&images, 1, &mut pool, &mut out);
        assert_eq!(out, want);
        pool.dirty(-1);
        ex.run_batch_into(&images, 2, &mut pool, &mut out);
        assert_eq!(out, want, "dirty pool, two threads");
    }

    #[test]
    fn image_major_witness_matches_batch_major_across_policies() {
        // both drivers, every dispatch arm (single-thread sweep, thin
        // batch, chunking with ragged tail), bit-exact vs execute
        let net = net_with_conv(ConvKind::Std, 3, 4, 3, 1);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let ex = Executor::new(&net, dp);
            let images: Vec<Tensor> = (0..13)
                .map(|s| {
                    let mut img = Tensor::zeros(4, 4, 3);
                    for (i, v) in img.data.iter_mut().enumerate() {
                        *v = ((i * 3 + s * 5) % 16) as i32;
                    }
                    img
                })
                .collect();
            let want: Vec<Vec<f32>> = images.iter().map(|t| ex.execute(t)).collect();
            for n in [1usize, 2, 5, 13] {
                for threads in [1usize, 2, 3, 8] {
                    let mut pool = ScratchPool::new();
                    let mut got = Vec::new();
                    ex.run_batch_into(&images[..n], threads, &mut pool, &mut got);
                    assert_eq!(&got[..], &want[..n], "batch-major n={n} t={threads} {dp:?}");
                    let mut got = Vec::new();
                    ex.run_image_major_into(&images[..n], threads, &mut pool, &mut got);
                    assert_eq!(&got[..], &want[..n], "image-major n={n} t={threads} {dp:?}");
                }
            }
        }
    }

    #[test]
    fn run_batch_handles_residual_state_per_image() {
        // res-push/add state must stay per-image in the arena loop
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let images: Vec<Tensor> = (0..5)
            .map(|s| {
                let mut img = Tensor::zeros(4, 4, 1);
                img.set(0, 0, 0, s as i32 + 3);
                img
            })
            .collect();
        let got = ex.run_batch(&images);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(got[i], ex.execute(img), "image {i}");
        }
    }

    #[test]
    fn execute_traced_fires_per_activation_op() {
        let mut net = net_with_conv(ConvKind::Pw, 1, 1, 1, 1);
        let conv = net.ops[1].clone();
        net.ops.insert(1, Op::ResPush {});
        net.ops.insert(2, conv);
        net.ops.insert(4, Op::ResAdd { bits: 4 });
        let ex = Executor::new(&net, Datapath::Arithmetic);
        let mut img = Tensor::zeros(4, 4, 1);
        img.set(0, 0, 0, 2);
        let mut seen: Vec<(usize, i32)> = Vec::new();
        let logits = ex.execute_traced(&img, &mut |oi, t| seen.push((oi, t.get(0, 0, 0))));
        // two convs (ops 2 and 3) and the res_add (op 4) trace
        assert_eq!(seen, vec![(2, 2), (3, 2), (4, 4)]);
        assert_eq!(logits[0], 4.0);
    }

    #[test]
    fn pruned_plan_matches_masked_dense_in_batch_drivers() {
        use crate::graph::prune::PruneSpec;
        let mut net = net_with_conv(ConvKind::Std, 3, 6, 3, 1);
        if let Op::Conv { w_codes, .. } = &mut net.ops[1] {
            let mut seed = 777u64;
            for row in w_codes.iter_mut() {
                for v in row.iter_mut() {
                    seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                    *v = ((seed >> 33) % 16) as i32 - 8;
                }
            }
        }
        let spec = PruneSpec::channels(0.5);
        let masked = spec.masked_network(&net);
        for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
            let pruned = Executor::from_plan(NetworkPlan::compile_pruned(&net, dp, &spec));
            let dense = Executor::new(&masked, dp);
            let images: Vec<Tensor> = (0..5)
                .map(|s| {
                    let mut img = Tensor::zeros(4, 4, 3);
                    for (i, v) in img.data.iter_mut().enumerate() {
                        *v = ((i * 7 + s * 3) % 16) as i32;
                    }
                    img
                })
                .collect();
            for n in [1usize, 2, 5] {
                assert_eq!(
                    pruned.run_batch_with_threads(&images[..n], 2),
                    dense.run_batch_with_threads(&images[..n], 2),
                    "pruned vs masked dense, batch {n}, {dp:?}"
                );
            }
        }
    }

    #[test]
    fn decode_test_images_shapes() {
        let bytes: Vec<u8> = (0..2 * 4 * 4 * 3).map(|i| (i % 256) as u8).collect();
        let imgs = decode_test_images(&bytes, 4, 3);
        assert_eq!(imgs.len(), 2);
        assert_eq!(imgs[0].get(0, 0, 1), 1);
    }
}
