//! Kernel functions over compiled layer plans (DESIGN.md S17) — the
//! bodies the reference executor and the dataflow simulator share.
//!
//! Every kernel is generic over the plan's multiplier readout
//! ([`Multipliers`] variant), monomorphized so the datapath dispatch is
//! hoisted out of the MAC loops: the hot loop sees either a plain
//! integer multiply, a memoized LUT product-table load, or (baseline)
//! a per-MAC simulated LUT6_2 readout — never a per-multiply branch.
//!
//! Accumulation order is identical across kernels and datapaths
//! (tap-major, channel-minor, matching `python/compile/model.py::
//! im2col`), so all paths stay bit-for-bit interchangeable.

use crate::quant::saturating_res_add;

use super::executor::Tensor;
use super::network::ConvKind;
use super::plan::{ConvPlan, DensePlan, Multipliers};

/// Run one compiled conv layer over an input activation tensor.
pub fn conv(plan: &ConvPlan, x: &Tensor) -> Tensor {
    // hard assert (one compare per layer, outside the MAC loops): the
    // interior fast path indexes with plan-derived strides, so a
    // mismatched tensor would compute garbage instead of failing loudly
    assert_eq!(
        (x.h, x.w, x.c),
        (plan.geom.in_h, plan.geom.in_w, plan.geom.cin),
        "{}: input shape disagrees with the compiled plan",
        plan.name
    );
    match &plan.mults {
        Multipliers::Weights => {
            conv_with(plan, x, |row, col, a| plan.wflat[row * plan.cols + col] * a)
        }
        Multipliers::LutDirect { mults } => {
            let pairs = plan.cols.div_ceil(2);
            conv_with(plan, x, move |row, col, a| {
                mults[row * pairs + col / 2].eval(col % 2 == 1, a as u32)
            })
        }
        Multipliers::LutTables { products, acts, .. } => {
            let acts = *acts;
            conv_with(plan, x, move |row, col, a| {
                products[(row * plan.cols + col) * acts + a as usize]
            })
        }
    }
}

/// Shared conv body, monomorphized per multiplier readout.
fn conv_with(plan: &ConvPlan, x: &Tensor, mul: impl Fn(usize, usize, i32) -> i32) -> Tensor {
    let g = plan.geom;
    if plan.kind == ConvKind::Pw && g.k == 1 && g.stride == 1 && g.pad == 0 {
        return pointwise(plan, x, mul);
    }
    let (ho, wo) = (g.out_h(), g.out_w());
    let mut out = Tensor::zeros(ho, wo, g.cout);
    let dw = plan.kind == ConvKind::Dw;
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out.data[(oy * wo + ox) * g.cout..(oy * wo + ox + 1) * g.cout];
            if y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1 {
                // interior: whole window in bounds — direct indexing off
                // the precomputed tap offsets, no per-tap bounds check
                let base = ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * g.cin;
                if dw {
                    for (c, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                            acc += mul(c, tap, x.data[base + off + c]);
                        }
                        *slot = plan.threshold(acc, c);
                    }
                } else {
                    for (co, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                            let px = &x.data[base + off..base + off + g.cin];
                            for (ci, &a) in px.iter().enumerate() {
                                acc += mul(co, tap * g.cin + ci, a);
                            }
                        }
                        *slot = plan.threshold(acc, co);
                    }
                }
            } else {
                // border rim: zero-padded taps, bounds-checked gather
                if dw {
                    for (c, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for i in 0..g.k {
                            for j in 0..g.k {
                                let a = x.get(
                                    (oy * g.stride + i) as isize - g.pad as isize,
                                    (ox * g.stride + j) as isize - g.pad as isize,
                                    c,
                                );
                                acc += mul(c, i * g.k + j, a);
                            }
                        }
                        *slot = plan.threshold(acc, c);
                    }
                } else {
                    for (co, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for i in 0..g.k {
                            for j in 0..g.k {
                                for ci in 0..g.cin {
                                    let a = x.get(
                                        (oy * g.stride + i) as isize - g.pad as isize,
                                        (ox * g.stride + j) as isize - g.pad as isize,
                                        ci,
                                    );
                                    acc += mul(co, (i * g.k + j) * g.cin + ci, a);
                                }
                            }
                        }
                        *slot = plan.threshold(acc, co);
                    }
                }
            }
        }
    }
    out
}

/// Pointwise conv as a matmul over contiguous HWC pixels (the bulk of
/// MobileNetV2's MACs). The arithmetic variant dots contiguous slices
/// (vectorizes); the LUT variants go through the readout closure.
fn pointwise(plan: &ConvPlan, x: &Tensor, mul: impl Fn(usize, usize, i32) -> i32) -> Tensor {
    let (cin, cout) = (plan.geom.cin, plan.geom.cout);
    let mut out = Tensor::zeros(x.h, x.w, cout);
    let arith = matches!(plan.mults, Multipliers::Weights);
    for px in 0..x.h * x.w {
        let xs = &x.data[px * cin..(px + 1) * cin];
        let o = &mut out.data[px * cout..(px + 1) * cout];
        for (co, slot) in o.iter_mut().enumerate() {
            let acc = if arith {
                plan.dot(co, xs)
            } else {
                let mut acc = 0i32;
                for (ci, &a) in xs.iter().enumerate() {
                    acc += mul(co, ci, a);
                }
                acc
            };
            *slot = plan.threshold(acc, co);
        }
    }
    out
}

/// One output pixel from a full im2col patch (`[K*K*CIN]`, (tap,
/// channel) minor order) — the dataflow simulator's conv-stage body.
pub fn patch_out(plan: &ConvPlan, patch: &[i32]) -> Vec<i32> {
    let mut out = vec![0i32; plan.geom.cout];
    match plan.kind {
        ConvKind::Dw => {
            let cin = plan.geom.cin;
            for (c, o) in out.iter_mut().enumerate() {
                let mut acc = 0i32;
                for tap in 0..plan.cols {
                    acc += plan.mul(c, tap, patch[tap * cin + c]);
                }
                *o = plan.threshold(acc, c);
            }
        }
        _ => {
            for (co, o) in out.iter_mut().enumerate() {
                *o = plan.threshold(plan.dot(co, patch), co);
            }
        }
    }
    out
}

/// Global sum-pool over all pixels, per channel.
pub fn pool_sum(x: &Tensor) -> Vec<i32> {
    let mut acc = vec![0i32; x.c];
    for px in x.data.chunks_exact(x.c) {
        for (a, &v) in acc.iter_mut().zip(px) {
            *a += v;
        }
    }
    acc
}

/// Saturating residual join: `x = sat(x + saved)` element-wise on codes.
pub fn res_add(x: &mut Tensor, saved: &Tensor, bits: u32) {
    assert_eq!((saved.h, saved.w, saved.c), (x.h, x.w, x.c));
    for (a, b) in x.data.iter_mut().zip(&saved.data) {
        *a = saturating_res_add(*a, *b, bits);
    }
}

/// Dense head over the pooled channel vector.
pub fn dense(plan: &DensePlan, pooled: &[i32]) -> Vec<f32> {
    (0..plan.cout)
        .map(|co| {
            let acc: i64 = pooled
                .iter()
                .enumerate()
                .map(|(ci, &a)| a as i64 * plan.w_codes[ci][co] as i64)
                .sum();
            // fused multiply-add: XLA CPU emits an FMA for
            // `acc * scale + bias`, so a separate mul+add here would
            // differ by 1 ULP from the golden
            (acc as f32).mul_add(plan.scale[co], plan.bias[co])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::network::{Network, Op};
    use crate::graph::plan::{Datapath, NetworkPlan, PlanOp};
    use crate::util::prop::Rng;

    /// One-conv network over an `hw x hw x cin` input.
    #[allow(clippy::too_many_arguments)]
    fn conv_net(
        rng: &mut Rng,
        kind: ConvKind,
        hw: usize,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
    ) -> Network {
        use crate::graph::network::Meta;
        let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
        let thresholds: Vec<Vec<i32>> = (0..cout)
            .map(|_| {
                let base = rng.range_i32(-10, 10);
                (0..15).map(|i| base + i).collect()
            })
            .collect();
        Network {
            meta: Meta {
                image_size: hw,
                in_ch: cin,
                num_classes: 2,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 },
                Op::Conv {
                    name: "c".into(),
                    kind,
                    cin,
                    cout,
                    k,
                    stride,
                    pad: (k - 1) / 2,
                    w_bits: 4,
                    in_bits: 4,
                    out_bits: 4,
                    w_codes: (0..cout).map(|_| rng.vec_i32(cols, -8, 7)).collect(),
                    thresholds,
                    signs: vec![1; cout],
                    consts: vec![0; cout],
                    out_scale: 1.0,
                },
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: cout,
                    cout: 2,
                    w_bits: 8,
                    w_codes: vec![vec![1, -1]; cout],
                    scale: vec![1.0, 1.0],
                    bias: vec![0.0, 0.0],
                },
            ],
        }
    }

    /// Naive direct convolution — the spec the kernels must match.
    fn naive_conv(net: &Network, x: &Tensor) -> Tensor {
        let Op::Conv { kind, cout, k, stride, pad, w_codes, thresholds, .. } = &net.ops[1] else {
            panic!("conv_net has a conv at 1")
        };
        let ho = (x.h + 2 * pad - k) / stride + 1;
        let wo = (x.w + 2 * pad - k) / stride + 1;
        let mut out = Tensor::zeros(ho, wo, *cout);
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..*cout {
                    let mut acc = 0i32;
                    for i in 0..*k {
                        for j in 0..*k {
                            let y = (oy * stride + i) as isize - *pad as isize;
                            let xx = (ox * stride + j) as isize - *pad as isize;
                            if *kind == ConvKind::Dw {
                                acc += w_codes[co][i * k + j] * x.get(y, xx, co);
                            } else {
                                for ci in 0..x.c {
                                    acc += w_codes[co][(i * k + j) * x.c + ci] * x.get(y, xx, ci);
                                }
                            }
                        }
                    }
                    let code = thresholds[co].iter().filter(|&&t| acc >= t).count() as i32;
                    out.set(oy, ox, co, code);
                }
            }
        }
        out
    }

    fn first_conv_plan(net: &Network, dp: Datapath) -> crate::graph::plan::ConvPlan {
        let plan = NetworkPlan::compile(net, dp);
        plan.ops
            .iter()
            .find_map(|op| match op {
                PlanOp::Conv(c) => Some(c.clone()),
                _ => None,
            })
            .expect("conv plan")
    }

    #[test]
    fn kernels_match_naive_conv_all_kinds_and_datapaths() {
        let mut rng = Rng::new(99);
        for (kind, hw, cin, cout, k, stride) in [
            (ConvKind::Pw, 6, 3, 5, 1, 1),
            (ConvKind::Std, 7, 2, 4, 3, 1), // odd width: border split exercised
            (ConvKind::Std, 8, 3, 3, 3, 2),
            (ConvKind::Dw, 7, 4, 4, 3, 2),
            (ConvKind::Dw, 5, 2, 2, 3, 1),
        ] {
            let net = conv_net(&mut rng, kind, hw, cin, cout, k, stride);
            let x = Tensor::from_hwc(hw, hw, cin, rng.vec_i32(hw * hw * cin, 0, 15));
            let want = naive_conv(&net, &x);
            for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
                let cp = first_conv_plan(&net, dp);
                assert_eq!(conv(&cp, &x), want, "{kind:?} hw={hw} k={k} s={stride} {dp:?}");
            }
        }
    }

    #[test]
    fn patch_out_matches_conv_on_pointwise() {
        // for a 1x1 conv the im2col patch IS the pixel, so patch_out and
        // the tensor kernel must agree pixel by pixel
        let mut rng = Rng::new(5);
        let net = conv_net(&mut rng, ConvKind::Pw, 4, 3, 4, 1, 1);
        let x = Tensor::from_hwc(4, 4, 3, rng.vec_i32(4 * 4 * 3, 0, 15));
        let cp = first_conv_plan(&net, Datapath::LutFabric);
        let whole = conv(&cp, &x);
        for px in 0..16 {
            let patch = &x.data[px * 3..(px + 1) * 3];
            assert_eq!(patch_out(&cp, patch), whole.data[px * 4..(px + 1) * 4].to_vec());
        }
    }

    #[test]
    fn pool_and_res_add_bit_exact() {
        let x = Tensor::from_hwc(2, 2, 3, vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]);
        assert_eq!(pool_sum(&x), vec![1 + 4 + 7 + 10, 2 + 5 + 8 + 11, 3 + 6 + 9 + 12]);
        let mut a = Tensor::from_hwc(1, 1, 2, vec![9, 3]);
        let b = Tensor::from_hwc(1, 1, 2, vec![9, 3]);
        res_add(&mut a, &b, 4);
        assert_eq!(a.data, vec![15, 6]); // 18 saturates to 15
    }
}
