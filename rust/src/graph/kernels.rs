//! Kernel functions over compiled layer plans (DESIGN.md S17/S20) — the
//! bodies the reference executor and the dataflow simulator share.
//!
//! Every kernel comes in two forms: an `_into` variant that writes into
//! caller-owned buffers (the zero-allocation engine the executor's
//! arena path runs — see `graph::scratch`), and a thin allocating
//! wrapper (tests, the simulator's token construction, and the
//! fresh-allocation reference the arena tests compare against).
//!
//! Multiplier dispatch is hoisted out of the MAC loops per
//! [`Multipliers`] variant:
//!
//!  * `Weights` and `LutDirect` run the scalar body, monomorphized over
//!    a `mul(row, col, act)` closure (plain integer multiply, or the
//!    per-MAC simulated LUT6_2 readout — the bit-exactness witness);
//!  * `LutTablesMacMajor` runs the same scalar body over the memoized
//!    MAC-major table (the pre-activation-major baseline the kernel
//!    bench gates against);
//!  * `LutTables` (activation-major, the default) runs the **LUT-GEMM
//!    column body**: the activation lookup is hoisted per (tap, ci) and
//!    one *contiguous* `cout`-wide product column is accumulated into
//!    the output slot — an axpy the autovectorizer chews on, instead of
//!    a strided per-MAC gather.
//!
//! Accumulation order is unchanged across all bodies and datapaths:
//! every output channel still sums its taps in (tap, ci)-ascending
//! order — the column body merely interleaves the *channels*, and i32
//! addition is exact whatever the interleaving — so all paths stay
//! bit-for-bit interchangeable (and match
//! `python/compile/model.py::im2col`).
//!
//! On top of the per-image bodies sit the **batch-major kernels**
//! (DESIGN.md S22, [`conv_batch_into`] / [`dense_batch_into`] /
//! [`pool_sum_batch_into`]): activations interleaved `[pixel][n][c]`,
//! each looked-up product column amortized across a whole batch tile,
//! fixed-`LANES` SIMD accumulate blocks, and optional output-row
//! fan-out across threads. Per image they preserve the exact same
//! accumulation order, so they are bit-exact with everything above.

//! **Sparse bodies** (DESIGN.md S23): a plan compiled with a
//! `PruneSpec` carries a `PruneInfo` compaction record, and every conv
//! entry point dispatches to a sparse body that sweeps only the live
//! rows/columns — the act-major bodies accumulate into the first
//! `rows()` slots of the output slab, then scatter through
//! `live_rows` (descending, so no accumulator is clobbered before it
//! is read) and splat the pruned channels' constant codes. Live
//! columns are visited in ascending dense order and a skipped column
//! contributes an exact i32 zero, so sparse output is bit-identical to
//! the dense kernels running the masked network (tests/prune.rs).

use crate::quant::saturating_res_add;

use super::approx::ApproxLayer;
use super::executor::Tensor;
use super::network::ConvKind;
use super::plan::{ConvPlan, DensePlan, Multipliers, PruneInfo};

/// Zero-padded read from a flat HWC activation slice.
#[inline]
fn at(x: &[i32], w: usize, c: usize, h: usize, y: isize, xx: isize, ch: usize) -> i32 {
    if y < 0 || xx < 0 || y >= h as isize || xx >= w as isize {
        0
    } else {
        x[(y as usize * w + xx as usize) * c + ch]
    }
}

/// Run one compiled conv layer over an input activation tensor
/// (allocating wrapper over [`conv_into`]).
pub fn conv(plan: &ConvPlan, x: &Tensor) -> Tensor {
    // hard assert (one compare per layer, outside the MAC loops): the
    // interior fast path indexes with plan-derived strides, so a
    // mismatched tensor would compute garbage instead of failing loudly
    assert_eq!(
        (x.h, x.w, x.c),
        (plan.geom.in_h, plan.geom.in_w, plan.geom.cin),
        "{}: input shape disagrees with the compiled plan",
        plan.name
    );
    let g = plan.geom;
    let mut out = Tensor::zeros(g.out_h(), g.out_w(), g.cout);
    conv_into(plan, &x.data, &mut out.data);
    out
}

/// Run one compiled conv layer from a flat HWC input slice into a
/// caller-owned flat HWC output slice (exact footprints; zero
/// allocation).
pub fn conv_into(plan: &ConvPlan, x: &[i32], out: &mut [i32]) {
    let g = plan.geom;
    assert_eq!(
        x.len(),
        g.in_pixels() * g.cin,
        "{}: input len disagrees with the compiled plan",
        plan.name
    );
    assert_eq!(
        out.len(),
        g.out_pixels() * g.cout,
        "{}: output len disagrees with the compiled plan",
        plan.name
    );
    if let Some(info) = &plan.prune {
        return match &plan.mults {
            Multipliers::LutTables { products, acts, .. } => {
                conv_sparse_cols(plan, info, x, out, products, *acts)
            }
            Multipliers::Weights => conv_sparse_scalar(plan, info, x, out, |row, col, a| {
                plan.wflat[row * plan.cols + col] * a
            }),
            Multipliers::LutDirect { mults } => {
                let pairs = plan.cols.div_ceil(2);
                conv_sparse_scalar(plan, info, x, out, move |row, col, a| {
                    mults[row * pairs + col / 2].eval(col % 2 == 1, a as u32)
                })
            }
            Multipliers::LutTablesMacMajor { products, acts, .. } => {
                let acts = *acts;
                conv_sparse_scalar(plan, info, x, out, move |row, col, a| {
                    products[(row * plan.cols + col) * acts + a as usize]
                })
            }
            Multipliers::LutApprox { .. } => unreachable!(
                "{}: approx plans are never pruned (compile_approx takes no PruneSpec)",
                plan.name
            ),
        };
    }
    match &plan.mults {
        Multipliers::LutTables { products, acts, .. } => {
            conv_cols(plan, x, out, products, *acts)
        }
        Multipliers::LutApprox { layer } => conv_approx_cols(plan, layer, x, out),
        Multipliers::Weights => {
            conv_scalar(plan, x, out, |row, col, a| plan.wflat[row * plan.cols + col] * a)
        }
        Multipliers::LutDirect { mults } => {
            let pairs = plan.cols.div_ceil(2);
            conv_scalar(plan, x, out, move |row, col, a| {
                mults[row * pairs + col / 2].eval(col % 2 == 1, a as u32)
            })
        }
        Multipliers::LutTablesMacMajor { products, acts, .. } => {
            let acts = *acts;
            conv_scalar(plan, x, out, move |row, col, a| {
                products[(row * plan.cols + col) * acts + a as usize]
            })
        }
    }
}

/// Scalar conv body, monomorphized per multiplier readout (`Weights`,
/// `LutDirect`, `LutTablesMacMajor`).
fn conv_scalar(plan: &ConvPlan, x: &[i32], out: &mut [i32], mul: impl Fn(usize, usize, i32) -> i32) {
    let g = plan.geom;
    if plan.kind == ConvKind::Pw && g.k == 1 && g.stride == 1 && g.pad == 0 {
        return pointwise_scalar(plan, x, out, mul);
    }
    let (ho, wo) = (g.out_h(), g.out_w());
    let dw = plan.kind == ConvKind::Dw;
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[(oy * wo + ox) * g.cout..(oy * wo + ox + 1) * g.cout];
            if y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1 {
                // interior: whole window in bounds — direct indexing off
                // the precomputed tap offsets, no per-tap bounds check
                let base = ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * g.cin;
                if dw {
                    for (c, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                            acc += mul(c, tap, x[base + off + c]);
                        }
                        *slot = plan.threshold(acc, c);
                    }
                } else {
                    for (co, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                            let px = &x[base + off..base + off + g.cin];
                            for (ci, &a) in px.iter().enumerate() {
                                acc += mul(co, tap * g.cin + ci, a);
                            }
                        }
                        *slot = plan.threshold(acc, co);
                    }
                }
            } else {
                // border rim: zero-padded taps, bounds-checked gather
                if dw {
                    for (c, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for i in 0..g.k {
                            for j in 0..g.k {
                                let a = at(
                                    x,
                                    g.in_w,
                                    g.cin,
                                    g.in_h,
                                    (oy * g.stride + i) as isize - g.pad as isize,
                                    (ox * g.stride + j) as isize - g.pad as isize,
                                    c,
                                );
                                acc += mul(c, i * g.k + j, a);
                            }
                        }
                        *slot = plan.threshold(acc, c);
                    }
                } else {
                    for (co, slot) in o.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for i in 0..g.k {
                            for j in 0..g.k {
                                for ci in 0..g.cin {
                                    let a = at(
                                        x,
                                        g.in_w,
                                        g.cin,
                                        g.in_h,
                                        (oy * g.stride + i) as isize - g.pad as isize,
                                        (ox * g.stride + j) as isize - g.pad as isize,
                                        ci,
                                    );
                                    acc += mul(co, (i * g.k + j) * g.cin + ci, a);
                                }
                            }
                        }
                        *slot = plan.threshold(acc, co);
                    }
                }
            }
        }
    }
}

/// Pointwise conv as a matmul over contiguous HWC pixels (the bulk of
/// MobileNetV2's MACs) — scalar-readout variant. The arithmetic path
/// dots contiguous weight rows (vectorizes); the LUT readouts go
/// through the closure.
fn pointwise_scalar(
    plan: &ConvPlan,
    x: &[i32],
    out: &mut [i32],
    mul: impl Fn(usize, usize, i32) -> i32,
) {
    let (cin, cout) = (plan.geom.cin, plan.geom.cout);
    let arith = matches!(plan.mults, Multipliers::Weights);
    for px in 0..plan.geom.in_pixels() {
        let xs = &x[px * cin..(px + 1) * cin];
        let o = &mut out[px * cout..(px + 1) * cout];
        for (co, slot) in o.iter_mut().enumerate() {
            let acc = if arith {
                plan.dot(co, xs)
            } else {
                let mut acc = 0i32;
                for (ci, &a) in xs.iter().enumerate() {
                    acc += mul(co, ci, a);
                }
                acc
            };
            *slot = plan.threshold(acc, co);
        }
    }
}

/// Activation-major LUT-GEMM conv body (`Multipliers::LutTables`,
/// DESIGN.md S20): per output pixel the output slot doubles as the
/// `cout`-wide accumulator — one contiguous product column is axpy'd
/// per (tap, ci) with the activation lookup hoisted out of the channel
/// loop — then the thresholds are applied in place. Out-of-bounds
/// border taps are skipped outright: their activation is the zero code,
/// whose product column is all zeros by table construction.
fn conv_cols(plan: &ConvPlan, x: &[i32], out: &mut [i32], products: &[i32], acts: usize) {
    let g = plan.geom;
    let (ho, wo) = (g.out_h(), g.out_w());
    let (cin, cout) = (g.cin, g.cout);
    let dw = plan.kind == ConvKind::Dw;
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[(oy * wo + ox) * cout..(oy * wo + ox + 1) * cout];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            if dw {
                // depthwise: every channel reads its own activation, so
                // this stays a gather — but it shares the hoisted
                // interior/border machinery and the in-place thresholds
                for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                    if interior {
                        let base =
                            ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * cin;
                        let px = &x[base + off..base + off + cin];
                        let tbl = &products[tap * acts * cout..(tap + 1) * acts * cout];
                        for (c, (&a, slot)) in px.iter().zip(o.iter_mut()).enumerate() {
                            *slot += tbl[a as usize * cout + c];
                        }
                    } else {
                        let (i, j) = (tap / g.k, tap % g.k);
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                            continue; // zero activation: zero column
                        }
                        let base = (y as usize * g.in_w + xx as usize) * cin;
                        let tbl = &products[tap * acts * cout..(tap + 1) * acts * cout];
                        for (c, slot) in o.iter_mut().enumerate() {
                            *slot += tbl[x[base + c] as usize * cout + c];
                        }
                    }
                }
            } else if interior {
                let base = ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * cin;
                for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                    let px = &x[base + off..base + off + cin];
                    for (ci, &a) in px.iter().enumerate() {
                        let col = tap * cin + ci;
                        let tbl = &products[(col * acts + a as usize) * cout..][..cout];
                        for (slot, &p) in o.iter_mut().zip(tbl) {
                            *slot += p;
                        }
                    }
                }
            } else {
                for i in 0..g.k {
                    for j in 0..g.k {
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                            continue; // zero activation: zero column
                        }
                        let base = (y as usize * g.in_w + xx as usize) * cin;
                        for ci in 0..cin {
                            let col = (i * g.k + j) * cin + ci;
                            let a = x[base + ci] as usize;
                            let tbl = &products[(col * acts + a) * cout..][..cout];
                            for (slot, &p) in o.iter_mut().zip(tbl) {
                                *slot += p;
                            }
                        }
                    }
                }
            }
            for (co, slot) in o.iter_mut().enumerate() {
                *slot = plan.threshold(*slot, co);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Approx bodies (DESIGN.md S24): Maddness codebook sweeps over a
// `Multipliers::LutApprox` layer (std/pw only — plan compile gates
// depthwise out). Per output pixel each codebook hashes its activation
// sub-patch through the trained decision tree (`depth` compares over
// the split-dimension columns only) and one row-contiguous table
// column is axpy'd — `n_codebooks` accumulations instead of `cols`.
// Zero-padded border taps feed activation code 0 into the hash (NOT
// skipped like the exact bodies' zero columns: a prototype's partial
// dot is not linear in single activations), which is also what the
// saturated exact configuration needs — code 0's table entry is 0.
// Codebook order is ascending in every approx entry point, so the
// per-image, batch-major and patch bodies are bit-identical to each
// other on any ApproxSpec.
// ---------------------------------------------------------------------

/// Per-image approx conv body: the output slot doubles as the
/// accumulator, one table-column axpy per codebook, thresholds applied
/// in place.
fn conv_approx_cols(plan: &ConvPlan, layer: &ApproxLayer, x: &[i32], out: &mut [i32]) {
    let g = plan.geom;
    let (ho, wo) = (g.out_h(), g.out_w());
    let (cin, cout) = (g.cin, g.cout);
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[(oy * wo + ox) * cout..][..cout];
            o.fill(0);
            if y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1 {
                let base = ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * cin;
                for cb in 0..layer.n_codebooks {
                    let code = layer
                        .code_with(cb, |col| x[base + plan.tap_offsets[col / cin] + col % cin]);
                    axpy(o, layer.table_col(cb, code));
                }
            } else {
                for cb in 0..layer.n_codebooks {
                    let code = layer.code_with(cb, |col| {
                        let (tap, ci) = (col / cin, col % cin);
                        at(
                            x,
                            g.in_w,
                            cin,
                            g.in_h,
                            (oy * g.stride + tap / g.k) as isize - g.pad as isize,
                            (ox * g.stride + tap % g.k) as isize - g.pad as isize,
                            ci,
                        )
                    });
                    axpy(o, layer.table_col(cb, code));
                }
            }
            for (co, slot) in o.iter_mut().enumerate() {
                *slot = plan.threshold(*slot, co);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Sparse bodies (DESIGN.md S23): compacted-index sweeps over a pruned
// plan's live rows/columns. `PruneInfo::live_cols` maps a compacted
// column back to its dense (tap, ci) position for the activation read;
// compacted row `r` maps to dense channel `live_rows[r]`.
// ---------------------------------------------------------------------

/// Threshold the first-`live`-slot accumulators of a `[cout]` output
/// slab and scatter them to their dense channel slots — descending, so
/// a scatter target (`live_rows[r] >= r`) never clobbers an accumulator
/// that is still to be read — then splat the pruned channels' constant
/// codes.
#[inline]
fn scatter_sparse_out(plan: &ConvPlan, info: &PruneInfo, o: &mut [i32]) {
    for r in (0..info.live_rows.len()).rev() {
        let ch = info.live_rows[r];
        o[ch] = plan.threshold(o[r], ch);
    }
    for &(ch, code) in &info.pruned_rows {
        o[ch] = code;
    }
}

/// Sparse scalar conv body (`Weights`, `LutDirect`, `LutTablesMacMajor`
/// over a pruned plan): register accumulation per live row over the
/// live columns only — compacted `mul` indices, dense activation reads.
fn conv_sparse_scalar(
    plan: &ConvPlan,
    info: &PruneInfo,
    x: &[i32],
    out: &mut [i32],
    mul: impl Fn(usize, usize, i32) -> i32,
) {
    let g = plan.geom;
    let (ho, wo) = (g.out_h(), g.out_w());
    let dw = plan.kind == ConvKind::Dw;
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[(oy * wo + ox) * g.cout..][..g.cout];
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base = if interior {
                ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * g.cin
            } else {
                0
            };
            for (r, &ch) in info.live_rows.iter().enumerate() {
                let mut acc = 0i32;
                for (c, &dcol) in info.live_cols.iter().enumerate() {
                    let (tap, ci) = if dw { (dcol, ch) } else { (dcol / g.cin, dcol % g.cin) };
                    let a = if interior {
                        x[base + plan.tap_offsets[tap] + ci]
                    } else {
                        let (i, j) = (tap / g.k, tap % g.k);
                        at(
                            x,
                            g.in_w,
                            g.cin,
                            g.in_h,
                            (oy * g.stride + i) as isize - g.pad as isize,
                            (ox * g.stride + j) as isize - g.pad as isize,
                            ci,
                        )
                    };
                    acc += mul(r, c, a);
                }
                o[ch] = plan.threshold(acc, ch);
            }
            for &(ch, code) in &info.pruned_rows {
                o[ch] = code;
            }
        }
    }
}

/// Sparse activation-major LUT-GEMM conv body: one compacted product
/// column per live (tap, ci), axpy'd into the first-`live` slots of the
/// output slab — pruned columns never reach the sweep, pruned rows
/// never occupy table space — then scattered out through `live_rows`.
fn conv_sparse_cols(
    plan: &ConvPlan,
    info: &PruneInfo,
    x: &[i32],
    out: &mut [i32],
    products: &[i32],
    acts: usize,
) {
    let g = plan.geom;
    let (ho, wo) = (g.out_h(), g.out_w());
    let live = info.live_rows.len();
    let dw = plan.kind == ConvKind::Dw;
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[(oy * wo + ox) * g.cout..][..g.cout];
            o[..live].fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base = if interior {
                ((oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)) * g.cin
            } else {
                0
            };
            for (c, &dcol) in info.live_cols.iter().enumerate() {
                if dw {
                    let tap = dcol;
                    let tbl = &products[c * acts * live..][..acts * live];
                    if interior {
                        let px = base + plan.tap_offsets[tap];
                        for (r, &ch) in info.live_rows.iter().enumerate() {
                            o[r] += tbl[x[px + ch] as usize * live + r];
                        }
                    } else {
                        let (i, j) = (tap / g.k, tap % g.k);
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                            continue; // zero activation: zero column
                        }
                        let px = (y as usize * g.in_w + xx as usize) * g.cin;
                        for (r, &ch) in info.live_rows.iter().enumerate() {
                            o[r] += tbl[x[px + ch] as usize * live + r];
                        }
                    }
                } else {
                    let (tap, ci) = (dcol / g.cin, dcol % g.cin);
                    let a = if interior {
                        x[base + plan.tap_offsets[tap] + ci]
                    } else {
                        let (i, j) = (tap / g.k, tap % g.k);
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                            continue; // zero activation: zero column
                        }
                        x[(y as usize * g.in_w + xx as usize) * g.cin + ci]
                    };
                    let tbl = &products[(c * acts + a as usize) * live..][..live];
                    axpy(&mut o[..live], tbl);
                }
            }
            scatter_sparse_out(plan, info, o);
        }
    }
}

/// One output pixel from a full im2col patch (`[K*K*CIN]`, (tap,
/// channel) minor order) — the dataflow simulator's conv-stage body
/// (allocating wrapper over [`patch_out_into`]).
pub fn patch_out(plan: &ConvPlan, patch: &[i32]) -> Vec<i32> {
    let mut out = vec![0i32; plan.geom.cout];
    patch_out_into(plan, patch, &mut out);
    out
}

/// [`patch_out`] into a caller-owned `[cout]` slice. The slot doubles as
/// the accumulator on the activation-major path, so no scratch beyond
/// the output itself is needed.
pub fn patch_out_into(plan: &ConvPlan, patch: &[i32], out: &mut [i32]) {
    assert_eq!(out.len(), plan.geom.cout, "{}: patch output len", plan.name);
    let cin = plan.geom.cin;
    if let Some(info) = &plan.prune {
        return patch_out_sparse(plan, info, patch, out);
    }
    match (&plan.mults, plan.kind) {
        (Multipliers::LutTables { products, acts, .. }, ConvKind::Dw) => {
            let cout = plan.geom.cout;
            out.fill(0);
            for tap in 0..plan.cols {
                let tbl = &products[tap * acts * cout..(tap + 1) * acts * cout];
                for (c, slot) in out.iter_mut().enumerate() {
                    *slot += tbl[patch[tap * cin + c] as usize * cout + c];
                }
            }
        }
        (Multipliers::LutTables { products, acts, .. }, _) => {
            // std/pw: the patch index IS the weight column, so the whole
            // pixel is `cols` contiguous column axpys
            let cout = plan.geom.cout;
            out.fill(0);
            for (col, &a) in patch.iter().enumerate() {
                let tbl = &products[(col * acts + a as usize) * cout..][..cout];
                for (slot, &p) in out.iter_mut().zip(tbl) {
                    *slot += p;
                }
            }
        }
        (Multipliers::LutApprox { layer }, _) => {
            // approx layers are std/pw only (plan compile gates Dw out),
            // so the patch index IS the weight column: each codebook
            // hashes straight off the patch and contributes one
            // row-contiguous table column axpy.
            out.fill(0);
            for cb in 0..layer.n_codebooks {
                let code = layer.code_with(cb, |col| patch[col]);
                axpy(out, layer.table_col(cb, code));
            }
        }
        (_, ConvKind::Dw) => {
            for (c, o) in out.iter_mut().enumerate() {
                let mut acc = 0i32;
                for tap in 0..plan.cols {
                    acc += plan.mul(c, tap, patch[tap * cin + c]);
                }
                *o = acc;
            }
        }
        _ => {
            for (co, o) in out.iter_mut().enumerate() {
                *o = plan.dot(co, patch);
            }
        }
    }
    for (co, slot) in out.iter_mut().enumerate() {
        *slot = plan.threshold(*slot, co);
    }
}

/// Sparse patch body for the simulator's conv stages: the full-width
/// im2col patch is indexed at the live columns' dense positions only,
/// through the compacted multiplier array.
fn patch_out_sparse(plan: &ConvPlan, info: &PruneInfo, patch: &[i32], out: &mut [i32]) {
    let cin = plan.geom.cin;
    let live = info.live_rows.len();
    match (&plan.mults, plan.kind) {
        (Multipliers::LutTables { products, acts, .. }, ConvKind::Dw) => {
            out[..live].fill(0);
            for (c, &tap) in info.live_cols.iter().enumerate() {
                let tbl = &products[c * acts * live..][..acts * live];
                for (r, &ch) in info.live_rows.iter().enumerate() {
                    out[r] += tbl[patch[tap * cin + ch] as usize * live + r];
                }
            }
            scatter_sparse_out(plan, info, out);
        }
        (Multipliers::LutTables { products, acts, .. }, _) => {
            out[..live].fill(0);
            for (c, &dcol) in info.live_cols.iter().enumerate() {
                let tbl = &products[(c * acts + patch[dcol] as usize) * live..][..live];
                axpy(&mut out[..live], tbl);
            }
            scatter_sparse_out(plan, info, out);
        }
        (_, ConvKind::Dw) => {
            for (r, &ch) in info.live_rows.iter().enumerate() {
                let mut acc = 0i32;
                for (c, &tap) in info.live_cols.iter().enumerate() {
                    acc += plan.mul(r, c, patch[tap * cin + ch]);
                }
                out[ch] = plan.threshold(acc, ch);
            }
            for &(ch, code) in &info.pruned_rows {
                out[ch] = code;
            }
        }
        _ => {
            for (r, &ch) in info.live_rows.iter().enumerate() {
                let mut acc = 0i32;
                for (c, &dcol) in info.live_cols.iter().enumerate() {
                    acc += plan.mul(r, c, patch[dcol]);
                }
                out[ch] = plan.threshold(acc, ch);
            }
            for &(ch, code) in &info.pruned_rows {
                out[ch] = code;
            }
        }
    }
}

/// Global sum-pool over all pixels, per channel (allocating wrapper).
pub fn pool_sum(x: &Tensor) -> Vec<i32> {
    let mut acc = vec![0i32; x.c];
    pool_sum_into(&x.data, &mut acc);
    acc
}

/// Global sum-pool into a caller-owned `[channels]` slice (the slice
/// length is the channel count).
pub fn pool_sum_into(x: &[i32], out: &mut [i32]) {
    out.fill(0);
    for px in x.chunks_exact(out.len()) {
        for (a, &v) in out.iter_mut().zip(px) {
            *a += v;
        }
    }
}

/// Saturating residual join: `x = sat(x + saved)` element-wise on codes.
pub fn res_add(x: &mut Tensor, saved: &Tensor, bits: u32) {
    assert_eq!((saved.h, saved.w, saved.c), (x.h, x.w, x.c));
    res_add_into(&mut x.data, &saved.data, bits);
}

/// [`res_add`] over flat slices (equal length).
pub fn res_add_into(x: &mut [i32], saved: &[i32], bits: u32) {
    assert_eq!(x.len(), saved.len(), "residual join width mismatch");
    for (a, &b) in x.iter_mut().zip(saved) {
        *a = saturating_res_add(*a, b, bits);
    }
}

/// Dense head over the pooled channel vector (allocating wrapper).
pub fn dense(plan: &DensePlan, pooled: &[i32]) -> Vec<f32> {
    let mut acc = vec![0i64; plan.cout];
    let mut out = vec![0.0f32; plan.cout];
    dense_into(plan, pooled, &mut acc, &mut out);
    out
}

/// Dense head into caller-owned buffers: `acc` is the `[cout]` `i64`
/// accumulator, `out` the `[cout]` logits. Blocked accumulation over
/// the flat `[CIN][COUT]` weights — each input channel's contiguous
/// `cout`-wide row is axpy'd, so every logit still sums its channels in
/// ascending-`ci` order (bit-identical to the nested-`Vec` loop it
/// replaces; `i64` adds are exact in any order regardless).
pub fn dense_into(plan: &DensePlan, pooled: &[i32], acc: &mut [i64], out: &mut [f32]) {
    assert_eq!(
        pooled.len(),
        plan.cin,
        "{}: pooled vector width disagrees with the dense plan",
        plan.name
    );
    assert_eq!(acc.len(), plan.cout, "{}: dense accumulator len", plan.name);
    assert_eq!(out.len(), plan.cout, "{}: logits len", plan.name);
    acc.fill(0);
    for (ci, &a) in pooled.iter().enumerate() {
        let a = a as i64;
        let row = &plan.wflat[ci * plan.cout..(ci + 1) * plan.cout];
        for (s, &w) in acc.iter_mut().zip(row) {
            *s += a * w as i64;
        }
    }
    for (co, (o, &s)) in out.iter_mut().zip(acc.iter()).enumerate() {
        // fused multiply-add: XLA CPU emits an FMA for
        // `acc * scale + bias`, so a separate mul+add here would
        // differ by 1 ULP from the golden
        *o = (s as f32).mul_add(plan.scale[co], plan.bias[co]);
    }
}

// ---------------------------------------------------------------------
// Batch-major kernels (DESIGN.md S22): activations live interleaved as
// `[pixel][n][c]` so the per-pixel `[nb][cout]` output slab is one
// contiguous accumulator and every looked-up product column is
// accumulated into all images of a batch tile while the (tap, ci)
// table slab stays cache-resident — the lookup-reuse lever the
// image-major sweep leaves on the table. Per image the accumulation
// order is IDENTICAL to the image-major bodies ((tap, ci)-ascending
// per output channel), so batch-major output is bit-exact with
// `conv_into` on every datapath.
// ---------------------------------------------------------------------

/// SIMD block width of the batch-major inner loops: 8 i32 lanes = one
/// AVX2 register (two NEON registers). The axpy bodies run
/// `chunks_exact` blocks of this width so the compiler emits straight
/// vector adds without having to prove anything about slice lengths —
/// the software analogue of the FINN `mvu_lut` PE×SIMD tiling.
pub const LANES: usize = 8;

/// `acc[i] += col[i]` in fixed-width lane blocks (the batch-major
/// LUT-GEMM accumulate: `col` is one looked-up product column).
#[inline]
fn axpy(acc: &mut [i32], col: &[i32]) {
    let mut blocks = acc.chunks_exact_mut(LANES);
    let mut cols = col.chunks_exact(LANES);
    for (av, cv) in blocks.by_ref().zip(cols.by_ref()) {
        for l in 0..LANES {
            av[l] += cv[l];
        }
    }
    for (slot, &p) in blocks.into_remainder().iter_mut().zip(cols.remainder()) {
        *slot += p;
    }
}

/// `acc[i] += col[i] * a` in fixed-width lane blocks (the batch-major
/// arithmetic accumulate: `col` is one `wflat_t` weight column).
#[inline]
fn axpy_scaled(acc: &mut [i32], col: &[i32], a: i32) {
    let mut blocks = acc.chunks_exact_mut(LANES);
    let mut cols = col.chunks_exact(LANES);
    for (av, cv) in blocks.by_ref().zip(cols.by_ref()) {
        for l in 0..LANES {
            av[l] += cv[l] * a;
        }
    }
    for (slot, &p) in blocks.into_remainder().iter_mut().zip(cols.remainder()) {
        *slot += p * a;
    }
}

/// Pack image `n` of `nb` (flat HWC, `[pixels * c]`) into the
/// batch-major interleaved layout `[pixel][nb][c]`.
pub fn interleave_image(img: &[i32], n: usize, nb: usize, c: usize, out: &mut [i32]) {
    assert_eq!(img.len() * nb, out.len(), "interleave: image/batch footprint mismatch");
    for (px, chunk) in img.chunks_exact(c).enumerate() {
        out[(px * nb + n) * c..][..c].copy_from_slice(chunk);
    }
}

/// Extract image `n` of `nb` from the interleaved `[pixel][nb][c]`
/// layout back into flat HWC (the inverse of [`interleave_image`];
/// tests and the sharded link path deinterleave with it).
pub fn deinterleave_image(x: &[i32], n: usize, nb: usize, c: usize, out: &mut [i32]) {
    assert_eq!(out.len() * nb, x.len(), "deinterleave: image/batch footprint mismatch");
    for (px, chunk) in out.chunks_exact_mut(c).enumerate() {
        chunk.copy_from_slice(&x[(px * nb + n) * c..][..c]);
    }
}

/// Run one compiled conv layer over `nb` interleaved images
/// (`[pixel][nb][cin]` in, `[pixel][nb][cout]` out), optionally fanning
/// output rows across `row_threads` scoped threads — the within-layer
/// parallelism for large early convs where batch width alone can't
/// fill cores. Output rows are contiguous in the interleaved layout,
/// so the fan-out is a plain `chunks_mut` split with no aliasing.
pub fn conv_batch_into(plan: &ConvPlan, x: &[i32], nb: usize, out: &mut [i32], row_threads: usize) {
    let g = plan.geom;
    assert!(nb >= 1, "{}: empty batch", plan.name);
    assert_eq!(
        x.len(),
        g.in_pixels() * g.cin * nb,
        "{}: batch input len disagrees with the compiled plan",
        plan.name
    );
    assert_eq!(
        out.len(),
        g.out_pixels() * g.cout * nb,
        "{}: batch output len disagrees with the compiled plan",
        plan.name
    );
    let ho = g.out_h();
    let threads = row_threads.max(1).min(ho);
    if threads <= 1 {
        return conv_batch_rows(plan, x, nb, out, 0, ho);
    }
    let rows_per = ho.div_ceil(threads);
    let row_elems = g.out_w() * nb * g.cout;
    std::thread::scope(|s| {
        for (idx, chunk) in out.chunks_mut(rows_per * row_elems).enumerate() {
            let oy0 = idx * rows_per;
            let oy1 = (oy0 + rows_per).min(ho);
            s.spawn(move || conv_batch_rows(plan, x, nb, chunk, oy0, oy1));
        }
    });
}

/// Output rows `[oy0, oy1)` of one batch-major conv; `out` holds
/// exactly those rows (`[(oy - oy0) * wo + ox][nb][cout]`).
fn conv_batch_rows(plan: &ConvPlan, x: &[i32], nb: usize, out: &mut [i32], oy0: usize, oy1: usize) {
    if let Some(info) = &plan.prune {
        return match &plan.mults {
            Multipliers::LutTables { products, acts, .. } => {
                conv_batch_sparse_cols(plan, info, x, nb, out, products, *acts, oy0, oy1)
            }
            Multipliers::Weights => conv_batch_sparse_weights(plan, info, x, nb, out, oy0, oy1),
            Multipliers::LutDirect { mults } => {
                let pairs = plan.cols.div_ceil(2);
                conv_batch_sparse_scalar(plan, info, x, nb, out, oy0, oy1, move |row, col, a| {
                    mults[row * pairs + col / 2].eval(col % 2 == 1, a as u32)
                })
            }
            Multipliers::LutTablesMacMajor { products, acts, .. } => {
                let acts = *acts;
                conv_batch_sparse_scalar(plan, info, x, nb, out, oy0, oy1, move |row, col, a| {
                    products[(row * plan.cols + col) * acts + a as usize]
                })
            }
            Multipliers::LutApprox { .. } => unreachable!(
                "{}: approx plans are never pruned (compile_approx takes no PruneSpec)",
                plan.name
            ),
        };
    }
    match &plan.mults {
        Multipliers::LutTables { products, acts, .. } => {
            conv_batch_cols(plan, x, nb, out, products, *acts, oy0, oy1)
        }
        Multipliers::LutApprox { layer } => {
            conv_batch_approx_rows(plan, layer, x, nb, out, oy0, oy1)
        }
        Multipliers::Weights => conv_batch_weights(plan, x, nb, out, oy0, oy1),
        Multipliers::LutDirect { mults } => {
            let pairs = plan.cols.div_ceil(2);
            conv_batch_scalar(plan, x, nb, out, oy0, oy1, move |row, col, a| {
                mults[row * pairs + col / 2].eval(col % 2 == 1, a as u32)
            })
        }
        Multipliers::LutTablesMacMajor { products, acts, .. } => {
            let acts = *acts;
            conv_batch_scalar(plan, x, nb, out, oy0, oy1, move |row, col, a| {
                products[(row * plan.cols + col) * acts + a as usize]
            })
        }
    }
}

/// Batch-major LUT-GEMM conv body (`Multipliers::LutTables`): per
/// output pixel the interleaved `[nb][cout]` slab doubles as the
/// accumulator. The batch is walked in `plan.batch_tile`-wide tiles;
/// within a tile each (tap, ci) table slab (`acts * cout` products,
/// a few KiB) is gathered once and its activation-selected columns
/// are axpy'd into every image's slot — one gather, N accumulates —
/// before the sweep moves to the next weight column.
#[allow(clippy::too_many_arguments)]
fn conv_batch_cols(
    plan: &ConvPlan,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    products: &[i32],
    acts: usize,
    oy0: usize,
    oy1: usize,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let dw = plan.kind == ConvKind::Dw;
    let tile = plan.batch_tile.min(nb);
    let slot = nb * cout;
    for oy in oy0..oy1 {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base_px = if interior {
                (oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)
            } else {
                0
            };
            let mut n0 = 0usize;
            while n0 < nb {
                let n1 = (n0 + tile).min(nb);
                if interior {
                    for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                        let px = (base_px + off / cin) * nb * cin;
                        if dw {
                            let tbl = &products[tap * acts * cout..][..acts * cout];
                            for n in n0..n1 {
                                let xs = &x[px + n * cin..][..cin];
                                let on = &mut o[n * cout..][..cout];
                                for (c, s) in on.iter_mut().enumerate() {
                                    *s += tbl[xs[c] as usize * cout + c];
                                }
                            }
                        } else {
                            for ci in 0..cin {
                                let col = tap * cin + ci;
                                let tbl = &products[col * acts * cout..][..acts * cout];
                                for n in n0..n1 {
                                    let a = x[px + n * cin + ci] as usize;
                                    axpy(&mut o[n * cout..][..cout], &tbl[a * cout..][..cout]);
                                }
                            }
                        }
                    }
                } else {
                    for i in 0..g.k {
                        for j in 0..g.k {
                            let y = (oy * g.stride + i) as isize - g.pad as isize;
                            let xx = (ox * g.stride + j) as isize - g.pad as isize;
                            if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                                continue; // zero activation: zero column
                            }
                            let px = (y as usize * g.in_w + xx as usize) * nb * cin;
                            let tap = i * g.k + j;
                            if dw {
                                let tbl = &products[tap * acts * cout..][..acts * cout];
                                for n in n0..n1 {
                                    let xs = &x[px + n * cin..][..cin];
                                    let on = &mut o[n * cout..][..cout];
                                    for (c, s) in on.iter_mut().enumerate() {
                                        *s += tbl[xs[c] as usize * cout + c];
                                    }
                                }
                            } else {
                                for ci in 0..cin {
                                    let col = tap * cin + ci;
                                    let tbl = &products[col * acts * cout..][..acts * cout];
                                    for n in n0..n1 {
                                        let a = x[px + n * cin + ci] as usize;
                                        axpy(&mut o[n * cout..][..cout], &tbl[a * cout..][..cout]);
                                    }
                                }
                            }
                        }
                    }
                }
                n0 = n1;
            }
            for n in 0..nb {
                let on = &mut o[n * cout..][..cout];
                for (co, s) in on.iter_mut().enumerate() {
                    *s = plan.threshold(*s, co);
                }
            }
        }
    }
}

/// Batch-major approx conv body over output rows `[oy0, oy1)` — the
/// generic-dispatch arm of [`conv_batch_rows`]: codes are hashed inline
/// per (codebook, image), so any caller (threaded row fan-out included)
/// runs without scratch. The executor's sweep uses the two-pass
/// [`conv_batch_approx_into`] over its `Scratch::codes` slot instead.
fn conv_batch_approx_rows(
    plan: &ConvPlan,
    layer: &ApproxLayer,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    oy0: usize,
    oy1: usize,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let tile = plan.batch_tile.min(nb);
    let slot = nb * cout;
    for oy in oy0..oy1 {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base_px = if interior {
                (oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)
            } else {
                0
            };
            let mut n0 = 0usize;
            while n0 < nb {
                let n1 = (n0 + tile).min(nb);
                for cb in 0..layer.n_codebooks {
                    for n in n0..n1 {
                        let code = layer.code_with(cb, |col| {
                            batch_col_read(plan, x, nb, oy, ox, interior, base_px, n, col)
                        });
                        axpy(&mut o[n * cout..][..cout], layer.table_col(cb, code));
                    }
                }
                n0 = n1;
            }
            for n in 0..nb {
                let on = &mut o[n * cout..][..cout];
                for (co, s) in on.iter_mut().enumerate() {
                    *s = plan.threshold(*s, co);
                }
            }
        }
    }
}

/// Zero-padded activation read for one weight column of one image from
/// the interleaved `[pixel][nb][cin]` batch layout (the approx hash's
/// column accessor; only split-dimension columns are ever read).
#[inline]
fn batch_col_read(
    plan: &ConvPlan,
    x: &[i32],
    nb: usize,
    oy: usize,
    ox: usize,
    interior: bool,
    base_px: usize,
    n: usize,
    col: usize,
) -> i32 {
    let g = plan.geom;
    let cin = g.cin;
    let (tap, ci) = (col / cin, col % cin);
    if interior {
        x[(base_px + plan.tap_offsets[tap] / cin) * nb * cin + n * cin + ci]
    } else {
        let y = (oy * g.stride + tap / g.k) as isize - g.pad as isize;
        let xx = (ox * g.stride + tap % g.k) as isize - g.pad as isize;
        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
            0
        } else {
            x[((y as usize * g.in_w + xx as usize) * nb + n) * cin + ci]
        }
    }
}

/// The executor's batch-major approx driver (DESIGN.md S24): a two-pass
/// sweep over each output pixel's `[nb][cout]` slab. Pass 1 hashes
/// every (codebook, image) code into the caller-owned `codes` arena
/// (`Scratch::codes`, `[nb * n_codebooks]`); pass 2 walks codebooks
/// outer / images inner so each codebook's `n_protos x rows` table slab
/// stays cache-resident across the whole tile while the axpys read
/// codes straight out of the arena. Bit-identical to the inline
/// [`conv_batch_rows`] arm (same codebook-ascending accumulation
/// order); zero allocation. Panics unless the plan's multiplier array
/// is [`Multipliers::LutApprox`].
pub fn conv_batch_approx_into(
    plan: &ConvPlan,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    codes: &mut [u16],
) {
    let Multipliers::LutApprox { layer } = &plan.mults else {
        panic!("{}: conv_batch_approx_into on a non-approx plan", plan.name)
    };
    let g = plan.geom;
    assert!(nb >= 1, "{}: empty batch", plan.name);
    assert_eq!(
        x.len(),
        g.in_pixels() * g.cin * nb,
        "{}: batch input len disagrees with the compiled plan",
        plan.name
    );
    assert_eq!(
        out.len(),
        g.out_pixels() * g.cout * nb,
        "{}: batch output len disagrees with the compiled plan",
        plan.name
    );
    assert!(
        codes.len() >= nb * layer.n_codebooks,
        "{}: codes arena holds {} slots, needs {}",
        plan.name,
        codes.len(),
        nb * layer.n_codebooks
    );
    let (ho, wo) = (g.out_h(), g.out_w());
    let cout = g.cout;
    let ncb = layer.n_codebooks;
    let slot = nb * cout;
    for oy in 0..ho {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[(oy * wo + ox) * slot..][..slot];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base_px = if interior {
                (oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)
            } else {
                0
            };
            for cb in 0..ncb {
                for n in 0..nb {
                    codes[n * ncb + cb] = layer.code_with(cb, |col| {
                        batch_col_read(plan, x, nb, oy, ox, interior, base_px, n, col)
                    }) as u16;
                }
            }
            for cb in 0..ncb {
                for n in 0..nb {
                    let code = codes[n * ncb + cb] as usize;
                    axpy(&mut o[n * cout..][..cout], layer.table_col(cb, code));
                }
            }
            for n in 0..nb {
                let on = &mut o[n * cout..][..cout];
                for (co, s) in on.iter_mut().enumerate() {
                    *s = plan.threshold(*s, co);
                }
            }
        }
    }
}

/// Batch-major arithmetic conv body (`Multipliers::Weights`): same
/// loop nest as [`conv_batch_cols`] with the product-column lookup
/// replaced by a scaled axpy over the `wflat_t` weight column. Zero
/// activations skip the column outright (adding zeros is an exact i32
/// identity, so bit-exactness with the image-major body holds).
fn conv_batch_weights(
    plan: &ConvPlan,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    oy0: usize,
    oy1: usize,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let dw = plan.kind == ConvKind::Dw;
    let tile = plan.batch_tile.min(nb);
    let slot = nb * cout;
    for oy in oy0..oy1 {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base_px = if interior {
                (oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)
            } else {
                0
            };
            let mut n0 = 0usize;
            while n0 < nb {
                let n1 = (n0 + tile).min(nb);
                if interior {
                    for (tap, &off) in plan.tap_offsets.iter().enumerate() {
                        let px = (base_px + off / cin) * nb * cin;
                        if dw {
                            // depthwise weight column for this tap, one
                            // weight per channel: elementwise mul-add
                            let wcol = &plan.wflat_t[tap * cout..][..cout];
                            for n in n0..n1 {
                                let xs = &x[px + n * cin..][..cin];
                                let on = &mut o[n * cout..][..cout];
                                for ((s, &w), &a) in on.iter_mut().zip(wcol).zip(xs) {
                                    *s += w * a;
                                }
                            }
                        } else {
                            for ci in 0..cin {
                                let wcol = &plan.wflat_t[(tap * cin + ci) * cout..][..cout];
                                for n in n0..n1 {
                                    let a = x[px + n * cin + ci];
                                    if a != 0 {
                                        axpy_scaled(&mut o[n * cout..][..cout], wcol, a);
                                    }
                                }
                            }
                        }
                    }
                } else {
                    for i in 0..g.k {
                        for j in 0..g.k {
                            let y = (oy * g.stride + i) as isize - g.pad as isize;
                            let xx = (ox * g.stride + j) as isize - g.pad as isize;
                            if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                                continue; // zero activation: zero column
                            }
                            let px = (y as usize * g.in_w + xx as usize) * nb * cin;
                            let tap = i * g.k + j;
                            if dw {
                                let wcol = &plan.wflat_t[tap * cout..][..cout];
                                for n in n0..n1 {
                                    let xs = &x[px + n * cin..][..cin];
                                    let on = &mut o[n * cout..][..cout];
                                    for ((s, &w), &a) in on.iter_mut().zip(wcol).zip(xs) {
                                        *s += w * a;
                                    }
                                }
                            } else {
                                for ci in 0..cin {
                                    let wcol = &plan.wflat_t[(tap * cin + ci) * cout..][..cout];
                                    for n in n0..n1 {
                                        let a = x[px + n * cin + ci];
                                        if a != 0 {
                                            axpy_scaled(&mut o[n * cout..][..cout], wcol, a);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                n0 = n1;
            }
            for n in 0..nb {
                let on = &mut o[n * cout..][..cout];
                for (co, s) in on.iter_mut().enumerate() {
                    *s = plan.threshold(*s, co);
                }
            }
        }
    }
}

/// Scalar batch-major conv body, monomorphized per multiplier readout —
/// the `LutDirect` and `LutTablesMacMajor` witnesses run through it, so
/// the batch layout itself is cross-checked against the hardware-true
/// per-MAC readout, not just against the memoized tables.
#[allow(clippy::too_many_arguments)]
fn conv_batch_scalar(
    plan: &ConvPlan,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    oy0: usize,
    oy1: usize,
    mul: impl Fn(usize, usize, i32) -> i32,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let dw = plan.kind == ConvKind::Dw;
    let slot = nb * cout;
    // zero-padded read from the interleaved layout
    let atb = |y: isize, xx: isize, n: usize, ch: usize| -> i32 {
        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
            0
        } else {
            x[((y as usize * g.in_w + xx as usize) * nb + n) * cin + ch]
        }
    };
    for oy in oy0..oy1 {
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            for n in 0..nb {
                let on = &mut o[n * cout..][..cout];
                if dw {
                    for (c, s) in on.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for i in 0..g.k {
                            for j in 0..g.k {
                                let y = (oy * g.stride + i) as isize - g.pad as isize;
                                let xx = (ox * g.stride + j) as isize - g.pad as isize;
                                acc += mul(c, i * g.k + j, atb(y, xx, n, c));
                            }
                        }
                        *s = plan.threshold(acc, c);
                    }
                } else {
                    for (co, s) in on.iter_mut().enumerate() {
                        let mut acc = 0i32;
                        for i in 0..g.k {
                            for j in 0..g.k {
                                let y = (oy * g.stride + i) as isize - g.pad as isize;
                                let xx = (ox * g.stride + j) as isize - g.pad as isize;
                                for ci in 0..cin {
                                    acc += mul(co, (i * g.k + j) * cin + ci, atb(y, xx, n, ci));
                                }
                            }
                        }
                        *s = plan.threshold(acc, co);
                    }
                }
            }
        }
    }
}

/// Sparse batch-major LUT-GEMM conv body: the compacted product table
/// of each live (tap, ci) is gathered once per batch tile and its
/// activation-selected column axpy'd into the first-`live` lanes of
/// every image's `[cout]` slot — the `LANES`-blocked sweep touches only
/// live work across the whole tile, which is where structured pruning
/// multiplies with the S22 batch amortization.
#[allow(clippy::too_many_arguments)]
fn conv_batch_sparse_cols(
    plan: &ConvPlan,
    info: &PruneInfo,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    products: &[i32],
    acts: usize,
    oy0: usize,
    oy1: usize,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let live = info.live_rows.len();
    let dw = plan.kind == ConvKind::Dw;
    let tile = plan.batch_tile.min(nb);
    let slot = nb * cout;
    for oy in oy0..oy1 {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base_px = if interior {
                (oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)
            } else {
                0
            };
            let mut n0 = 0usize;
            while n0 < nb {
                let n1 = (n0 + tile).min(nb);
                for (c, &dcol) in info.live_cols.iter().enumerate() {
                    let tap = if dw { dcol } else { dcol / cin };
                    let px = if interior {
                        (base_px + plan.tap_offsets[tap] / cin) * nb * cin
                    } else {
                        let (i, j) = (tap / g.k, tap % g.k);
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                            continue; // zero activation: zero column
                        }
                        (y as usize * g.in_w + xx as usize) * nb * cin
                    };
                    let tbl = &products[c * acts * live..][..acts * live];
                    if dw {
                        for n in n0..n1 {
                            let xs = &x[px + n * cin..][..cin];
                            let on = &mut o[n * cout..][..live];
                            for (r, &ch) in info.live_rows.iter().enumerate() {
                                on[r] += tbl[xs[ch] as usize * live + r];
                            }
                        }
                    } else {
                        let ci = dcol % cin;
                        for n in n0..n1 {
                            let a = x[px + n * cin + ci] as usize;
                            axpy(&mut o[n * cout..][..live], &tbl[a * live..][..live]);
                        }
                    }
                }
                n0 = n1;
            }
            for n in 0..nb {
                scatter_sparse_out(plan, info, &mut o[n * cout..][..cout]);
            }
        }
    }
}

/// Sparse batch-major arithmetic conv body: scaled axpys over the
/// compacted `wflat_t` columns (`wflat_t[c * live..]`), live rows only.
fn conv_batch_sparse_weights(
    plan: &ConvPlan,
    info: &PruneInfo,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    oy0: usize,
    oy1: usize,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let live = info.live_rows.len();
    let dw = plan.kind == ConvKind::Dw;
    let tile = plan.batch_tile.min(nb);
    let slot = nb * cout;
    for oy in oy0..oy1 {
        let y_interior = oy >= plan.oy_interior.0 && oy < plan.oy_interior.1;
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            o.fill(0);
            let interior = y_interior && ox >= plan.ox_interior.0 && ox < plan.ox_interior.1;
            let base_px = if interior {
                (oy * g.stride - g.pad) * g.in_w + (ox * g.stride - g.pad)
            } else {
                0
            };
            let mut n0 = 0usize;
            while n0 < nb {
                let n1 = (n0 + tile).min(nb);
                for (c, &dcol) in info.live_cols.iter().enumerate() {
                    let tap = if dw { dcol } else { dcol / cin };
                    let px = if interior {
                        (base_px + plan.tap_offsets[tap] / cin) * nb * cin
                    } else {
                        let (i, j) = (tap / g.k, tap % g.k);
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
                            continue; // zero activation: zero column
                        }
                        (y as usize * g.in_w + xx as usize) * nb * cin
                    };
                    let wcol = &plan.wflat_t[c * live..][..live];
                    if dw {
                        for n in n0..n1 {
                            let xs = &x[px + n * cin..][..cin];
                            let on = &mut o[n * cout..][..live];
                            for (r, &ch) in info.live_rows.iter().enumerate() {
                                on[r] += wcol[r] * xs[ch];
                            }
                        }
                    } else {
                        let ci = dcol % cin;
                        for n in n0..n1 {
                            let a = x[px + n * cin + ci];
                            if a != 0 {
                                axpy_scaled(&mut o[n * cout..][..live], wcol, a);
                            }
                        }
                    }
                }
                n0 = n1;
            }
            for n in 0..nb {
                scatter_sparse_out(plan, info, &mut o[n * cout..][..cout]);
            }
        }
    }
}

/// Sparse scalar batch-major conv body — the `LutDirect` and
/// `LutTablesMacMajor` witnesses of the pruned compaction, so the
/// compacted index space itself is cross-checked against the
/// hardware-true per-MAC readout.
#[allow(clippy::too_many_arguments)]
fn conv_batch_sparse_scalar(
    plan: &ConvPlan,
    info: &PruneInfo,
    x: &[i32],
    nb: usize,
    out: &mut [i32],
    oy0: usize,
    oy1: usize,
    mul: impl Fn(usize, usize, i32) -> i32,
) {
    let g = plan.geom;
    let wo = g.out_w();
    let (cin, cout) = (g.cin, g.cout);
    let dw = plan.kind == ConvKind::Dw;
    let slot = nb * cout;
    // zero-padded read from the interleaved layout
    let atb = |y: isize, xx: isize, n: usize, ch: usize| -> i32 {
        if y < 0 || xx < 0 || y >= g.in_h as isize || xx >= g.in_w as isize {
            0
        } else {
            x[((y as usize * g.in_w + xx as usize) * nb + n) * cin + ch]
        }
    };
    for oy in oy0..oy1 {
        for ox in 0..wo {
            let o = &mut out[((oy - oy0) * wo + ox) * slot..][..slot];
            for n in 0..nb {
                let on = &mut o[n * cout..][..cout];
                for (r, &ch) in info.live_rows.iter().enumerate() {
                    let mut acc = 0i32;
                    for (c, &dcol) in info.live_cols.iter().enumerate() {
                        let (tap, ci) = if dw { (dcol, ch) } else { (dcol / cin, dcol % cin) };
                        let (i, j) = (tap / g.k, tap % g.k);
                        let y = (oy * g.stride + i) as isize - g.pad as isize;
                        let xx = (ox * g.stride + j) as isize - g.pad as isize;
                        acc += mul(r, c, atb(y, xx, n, ci));
                    }
                    on[ch] = plan.threshold(acc, ch);
                }
                for &(ch, code) in &info.pruned_rows {
                    on[ch] = code;
                }
            }
        }
    }
}

/// Global sum-pool over the interleaved batch layout: `[pixel][nb][c]`
/// in, `[nb][c]` out. Every pixel slab has exactly the output's shape,
/// so the pool is a straight slab-wise add — per (image, channel) the
/// pixels accumulate in ascending order, identical to the image-major
/// [`pool_sum_into`].
pub fn pool_sum_batch_into(x: &[i32], nb: usize, out: &mut [i32]) {
    assert!(nb >= 1 && out.len() % nb == 0, "pooled buffer is [nb][c]");
    assert_eq!(x.len() % out.len(), 0, "pool input is whole pixel slabs");
    out.fill(0);
    for px in x.chunks_exact(out.len()) {
        for (a, &v) in out.iter_mut().zip(px) {
            *a += v;
        }
    }
}

/// Batch-major dense head: `pooled` is `[nb][cin]`, `acc` the
/// `[nb][cout]` i64 accumulator, `out` one logits vector per image.
/// Blocked over input channels like [`dense_into`] — per image every
/// logit still sums its channels in ascending-`ci` order, and the
/// epilogue is the identical `mul_add`, so logits are bit-exact with
/// the image-major head.
pub fn dense_batch_into(
    plan: &DensePlan,
    pooled: &[i32],
    nb: usize,
    acc: &mut [i64],
    out: &mut [Vec<f32>],
) {
    assert_eq!(
        pooled.len(),
        nb * plan.cin,
        "{}: batch pooled width disagrees with the dense plan",
        plan.name
    );
    assert_eq!(acc.len(), nb * plan.cout, "{}: batch dense accumulator len", plan.name);
    assert_eq!(out.len(), nb, "{}: one logits slot per image", plan.name);
    acc.fill(0);
    for ci in 0..plan.cin {
        let row = &plan.wflat[ci * plan.cout..(ci + 1) * plan.cout];
        for n in 0..nb {
            let a = pooled[n * plan.cin + ci] as i64;
            let an = &mut acc[n * plan.cout..][..plan.cout];
            for (s, &w) in an.iter_mut().zip(row) {
                *s += a * w as i64;
            }
        }
    }
    for (n, o) in out.iter_mut().enumerate() {
        assert_eq!(o.len(), plan.cout, "{}: logits len for image {n}", plan.name);
        let an = &acc[n * plan.cout..][..plan.cout];
        for (co, (slot, &s)) in o.iter_mut().zip(an.iter()).enumerate() {
            *slot = (s as f32).mul_add(plan.scale[co], plan.bias[co]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::network::{Network, Op};
    use crate::graph::plan::{Datapath, NetworkPlan, PlanOp};
    use crate::util::prop::Rng;

    /// One-conv network over an `hw x hw x cin` input.
    #[allow(clippy::too_many_arguments)]
    fn conv_net(
        rng: &mut Rng,
        kind: ConvKind,
        hw: usize,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
    ) -> Network {
        use crate::graph::network::Meta;
        let cols = if kind == ConvKind::Dw { k * k } else { k * k * cin };
        let thresholds: Vec<Vec<i32>> = (0..cout)
            .map(|_| {
                let base = rng.range_i32(-10, 10);
                (0..15).map(|i| base + i).collect()
            })
            .collect();
        Network {
            meta: Meta {
                image_size: hw,
                in_ch: cin,
                num_classes: 2,
                in_scale: 1.0,
                w_bits: 4,
                a_bits: 4,
                acc_int: 0.0,
                n_test: 0,
                golden_logits: vec![],
            },
            ops: vec![
                Op::Input { bits: 4, scale: 1.0 },
                Op::Conv {
                    name: "c".into(),
                    kind,
                    cin,
                    cout,
                    k,
                    stride,
                    pad: (k - 1) / 2,
                    w_bits: 4,
                    in_bits: 4,
                    out_bits: 4,
                    w_codes: (0..cout).map(|_| rng.vec_i32(cols, -8, 7)).collect(),
                    thresholds,
                    signs: vec![1; cout],
                    consts: vec![0; cout],
                    out_scale: 1.0,
                },
                Op::PoolSum {},
                Op::Dense {
                    name: "fc".into(),
                    cin: cout,
                    cout: 2,
                    w_bits: 8,
                    w_codes: vec![vec![1, -1]; cout],
                    scale: vec![1.0, 1.0],
                    bias: vec![0.0, 0.0],
                },
            ],
        }
    }

    /// Naive direct convolution — the spec the kernels must match.
    fn naive_conv(net: &Network, x: &Tensor) -> Tensor {
        let Op::Conv { kind, cout, k, stride, pad, w_codes, thresholds, .. } = &net.ops[1] else {
            panic!("conv_net has a conv at 1")
        };
        let ho = (x.h + 2 * pad - k) / stride + 1;
        let wo = (x.w + 2 * pad - k) / stride + 1;
        let mut out = Tensor::zeros(ho, wo, *cout);
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..*cout {
                    let mut acc = 0i32;
                    for i in 0..*k {
                        for j in 0..*k {
                            let y = (oy * stride + i) as isize - *pad as isize;
                            let xx = (ox * stride + j) as isize - *pad as isize;
                            if *kind == ConvKind::Dw {
                                acc += w_codes[co][i * k + j] * x.get(y, xx, co);
                            } else {
                                for ci in 0..x.c {
                                    acc += w_codes[co][(i * k + j) * x.c + ci] * x.get(y, xx, ci);
                                }
                            }
                        }
                    }
                    let code = thresholds[co].iter().filter(|&&t| acc >= t).count() as i32;
                    out.set(oy, ox, co, code);
                }
            }
        }
        out
    }

    fn first_conv_of(plan: &NetworkPlan) -> crate::graph::plan::ConvPlan {
        plan.ops
            .iter()
            .find_map(|op| match op {
                PlanOp::Conv(c) => Some(c.clone()),
                _ => None,
            })
            .expect("conv plan")
    }

    #[test]
    fn kernels_match_naive_conv_all_kinds_layouts_and_datapaths() {
        let mut rng = Rng::new(99);
        for (kind, hw, cin, cout, k, stride) in [
            (ConvKind::Pw, 6, 3, 5, 1, 1),
            (ConvKind::Std, 7, 2, 4, 3, 1), // odd width: border split exercised
            (ConvKind::Std, 8, 3, 3, 3, 2),
            (ConvKind::Dw, 7, 4, 4, 3, 2),
            (ConvKind::Dw, 5, 2, 2, 3, 1),
        ] {
            let net = conv_net(&mut rng, kind, hw, cin, cout, k, stride);
            let x = Tensor::from_hwc(hw, hw, cin, rng.vec_i32(hw * hw * cin, 0, 15));
            let want = naive_conv(&net, &x);
            for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
                for (label, plan) in [
                    ("act-major", NetworkPlan::compile(&net, dp)),
                    ("direct", NetworkPlan::compile_direct(&net, dp)),
                    ("mac-major", NetworkPlan::compile_mac_major(&net, dp)),
                ] {
                    let cp = first_conv_of(&plan);
                    assert_eq!(
                        conv(&cp, &x),
                        want,
                        "{kind:?} hw={hw} k={k} s={stride} {dp:?} {label}"
                    );
                }
            }
        }
    }

    #[test]
    fn conv_into_writes_over_dirty_output() {
        // the _into kernels must not depend on the output buffer's prior
        // contents (the arena hands them poisoned buffers)
        let mut rng = Rng::new(17);
        let net = conv_net(&mut rng, ConvKind::Std, 6, 2, 3, 3, 1);
        let x = Tensor::from_hwc(6, 6, 2, rng.vec_i32(6 * 6 * 2, 0, 15));
        for plan in [
            NetworkPlan::compile(&net, Datapath::LutFabric),
            NetworkPlan::compile_mac_major(&net, Datapath::LutFabric),
        ] {
            let cp = first_conv_of(&plan);
            let want = conv(&cp, &x);
            let mut out = vec![-999i32; 6 * 6 * 3];
            conv_into(&cp, &x.data, &mut out);
            assert_eq!(out, want.data);
        }
    }

    #[test]
    fn patch_out_matches_conv_on_pointwise() {
        // for a 1x1 conv the im2col patch IS the pixel, so patch_out and
        // the tensor kernel must agree pixel by pixel
        let mut rng = Rng::new(5);
        let net = conv_net(&mut rng, ConvKind::Pw, 4, 3, 4, 1, 1);
        let x = Tensor::from_hwc(4, 4, 3, rng.vec_i32(4 * 4 * 3, 0, 15));
        for plan in [
            NetworkPlan::compile(&net, Datapath::LutFabric),
            NetworkPlan::compile_direct(&net, Datapath::LutFabric),
            NetworkPlan::compile_mac_major(&net, Datapath::LutFabric),
        ] {
            let cp = first_conv_of(&plan);
            let whole = conv(&cp, &x);
            for px in 0..16 {
                let patch = &x.data[px * 3..(px + 1) * 3];
                assert_eq!(patch_out(&cp, patch), whole.data[px * 4..(px + 1) * 4].to_vec());
            }
        }
    }

    #[test]
    fn patch_out_matches_conv_on_depthwise_tables() {
        // depthwise goes through the per-channel gather arm of the
        // activation-major patch body; cross-check it against the tensor
        // kernel via an interior pixel's im2col patch
        let mut rng = Rng::new(23);
        let net = conv_net(&mut rng, ConvKind::Dw, 5, 3, 3, 3, 1);
        let x = Tensor::from_hwc(5, 5, 3, rng.vec_i32(5 * 5 * 3, 0, 15));
        let plan = NetworkPlan::compile(&net, Datapath::LutFabric);
        let cp = first_conv_of(&plan);
        let whole = conv(&cp, &x);
        // interior output (2,2): window origin (1,1)
        let mut patch = Vec::new();
        for i in 0..3isize {
            for j in 0..3isize {
                for c in 0..3usize {
                    patch.push(x.get(1 + i, 1 + j, c));
                }
            }
        }
        let got = patch_out(&cp, &patch);
        assert_eq!(got, whole.data[(2 * 5 + 2) * 3..(2 * 5 + 2 + 1) * 3].to_vec());
    }

    #[test]
    fn pool_and_res_add_bit_exact() {
        let x = Tensor::from_hwc(2, 2, 3, vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]);
        assert_eq!(pool_sum(&x), vec![1 + 4 + 7 + 10, 2 + 5 + 8 + 11, 3 + 6 + 9 + 12]);
        let mut a = Tensor::from_hwc(1, 1, 2, vec![9, 3]);
        let b = Tensor::from_hwc(1, 1, 2, vec![9, 3]);
        res_add(&mut a, &b, 4);
        assert_eq!(a.data, vec![15, 6]); // 18 saturates to 15

        // _into variants over dirty buffers
        let mut pooled = vec![-5i32; 3];
        pool_sum_into(&x.data, &mut pooled);
        assert_eq!(pooled, vec![22, 26, 30]);
    }

    #[test]
    fn batch_kernels_match_image_major_all_kinds_and_datapaths() {
        // the batch-major S22 contract at kernel level: for every conv
        // kind, datapath and multiplier layout, interleave -> batch conv
        // -> deinterleave equals the per-image conv bit for bit, across
        // ragged batch sizes, forced sub-nb tiles, and row fan-out
        let mut rng = Rng::new(4242);
        for (kind, hw, cin, cout, k, stride) in [
            (ConvKind::Pw, 6, 3, 5, 1, 1),
            (ConvKind::Std, 7, 2, 4, 3, 1), // odd width: border split
            (ConvKind::Std, 8, 3, 3, 3, 2),
            (ConvKind::Dw, 7, 4, 4, 3, 2),
        ] {
            let net = conv_net(&mut rng, kind, hw, cin, cout, k, stride);
            for dp in [Datapath::Arithmetic, Datapath::LutFabric] {
                for (label, plan) in [
                    ("act-major", NetworkPlan::compile(&net, dp)),
                    ("direct", NetworkPlan::compile_direct(&net, dp)),
                    ("mac-major", NetworkPlan::compile_mac_major(&net, dp)),
                ] {
                    let mut cp = first_conv_of(&plan);
                    // force tiles narrower than the batch so the tile
                    // loop and its ragged tail are exercised
                    cp.batch_tile = 2;
                    let g = cp.geom;
                    for nb in [1usize, 3, 5, 8] {
                        let imgs: Vec<Tensor> = (0..nb)
                            .map(|_| {
                                Tensor::from_hwc(hw, hw, cin, rng.vec_i32(hw * hw * cin, 0, 15))
                            })
                            .collect();
                        let mut x = vec![0i32; hw * hw * cin * nb];
                        for (n, img) in imgs.iter().enumerate() {
                            interleave_image(&img.data, n, nb, cin, &mut x);
                        }
                        for row_threads in [1usize, 3] {
                            let mut out = vec![-7i32; g.out_pixels() * g.cout * nb];
                            conv_batch_into(&cp, &x, nb, &mut out, row_threads);
                            for (n, img) in imgs.iter().enumerate() {
                                let want = conv(&cp, img);
                                let mut got = vec![0i32; g.out_pixels() * g.cout];
                                deinterleave_image(&out, n, nb, g.cout, &mut got);
                                assert_eq!(
                                    got, want.data,
                                    "{kind:?} {dp:?} {label} nb={nb} n={n} rt={row_threads}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn batch_pool_and_dense_match_image_major() {
        let mut rng = Rng::new(77);
        let (h, w, c, nb) = (3usize, 3usize, 5usize, 4usize);
        let imgs: Vec<Tensor> = (0..nb)
            .map(|_| Tensor::from_hwc(h, w, c, rng.vec_i32(h * w * c, 0, 15)))
            .collect();
        let mut x = vec![0i32; h * w * c * nb];
        for (n, img) in imgs.iter().enumerate() {
            interleave_image(&img.data, n, nb, c, &mut x);
        }
        // interleave/deinterleave round-trip
        for (n, img) in imgs.iter().enumerate() {
            let mut back = vec![0i32; h * w * c];
            deinterleave_image(&x, n, nb, c, &mut back);
            assert_eq!(back, img.data, "round-trip image {n}");
        }
        let mut pooled = vec![-3i32; nb * c]; // dirty
        pool_sum_batch_into(&x, nb, &mut pooled);
        for (n, img) in imgs.iter().enumerate() {
            assert_eq!(&pooled[n * c..][..c], pool_sum(img).as_slice(), "pool image {n}");
        }
        let plan = DensePlan {
            name: "fc".into(),
            cin: c,
            cout: 3,
            wflat: rng.vec_i32(c * 3, -128, 127),
            scale: (0..3).map(|i| 0.01 + i as f32 * 0.004).collect(),
            bias: (0..3).map(|i| i as f32 * 0.5 - 0.2).collect(),
        };
        let mut acc = vec![11i64; nb * 3]; // dirty
        let mut out = vec![vec![9.9f32; 3]; nb];
        dense_batch_into(&plan, &pooled, nb, &mut acc, &mut out);
        for (n, o) in out.iter().enumerate() {
            assert_eq!(o, &dense(&plan, &pooled[n * c..][..c]), "dense image {n}");
        }
    }

    #[test]
    fn dense_into_matches_nested_reference() {
        let mut rng = Rng::new(31);
        let (cin, cout) = (7, 4);
        let w_codes: Vec<Vec<i32>> = (0..cin).map(|_| rng.vec_i32(cout, -128, 127)).collect();
        let plan = DensePlan {
            name: "fc".into(),
            cin,
            cout,
            wflat: w_codes.iter().flatten().copied().collect(),
            scale: (0..cout).map(|i| 0.01 + i as f32 * 0.003).collect(),
            bias: (0..cout).map(|i| i as f32 - 1.5).collect(),
        };
        let pooled = rng.vec_i32(cin, 0, 400);
        // the pre-flattening reference loop, verbatim
        let want: Vec<f32> = (0..cout)
            .map(|co| {
                let acc: i64 = pooled
                    .iter()
                    .enumerate()
                    .map(|(ci, &a)| a as i64 * w_codes[ci][co] as i64)
                    .sum();
                (acc as f32).mul_add(plan.scale[co], plan.bias[co])
            })
            .collect();
        assert_eq!(dense(&plan, &pooled), want);
        let mut acc = vec![7i64; cout]; // dirty
        let mut out = vec![9.9f32; cout];
        dense_into(&plan, &pooled, &mut acc, &mut out);
        assert_eq!(out, want);
    }
}
