//! Model graph substrate (DESIGN.md S5/S17/S20): the streamlined
//! integer network IR (`network`), shape-level architecture specs
//! (`arch`), the compiled layer plans + kernel engine (`plan`,
//! `kernels`), structured-pruning specs (`prune`), the per-worker
//! tensor arenas the zero-allocation kernels run in (`scratch`) and the
//! reference integer executor (`executor`).

pub mod approx;
pub mod arch;
pub mod executor;
pub mod kernels;
pub mod network;
pub mod plan;
pub mod prune;
pub mod scratch;

pub use approx::{ApproxLayer, ApproxSpec};
pub use arch::{mobilenet_v2_full, mobilenet_v2_small, ArchSpec, LayerSpec};
pub use executor::{decode_test_images, Datapath, Executor, Tensor};
pub use network::{ConvKind, Network, Op};
pub use plan::{ConvGeom, ConvPlan, IoGeom, Multipliers, NetworkPlan, PlanOp, PlanShard, PruneInfo};
pub use prune::PruneSpec;
pub use scratch::{Scratch, ScratchPool};
