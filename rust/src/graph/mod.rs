//! Model graph substrate (DESIGN.md S5): the streamlined integer network
//! IR (`network`), shape-level architecture specs (`arch`) and the
//! reference integer executor (`executor`).

pub mod arch;
pub mod executor;
pub mod network;

pub use arch::{mobilenet_v2_full, mobilenet_v2_small, ArchSpec, LayerSpec};
pub use executor::{decode_test_images, Datapath, Executor, Tensor};
pub use network::{ConvKind, Network, Op};
