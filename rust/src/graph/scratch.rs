//! Per-worker tensor arenas (DESIGN.md S20): caller-owned buffers the
//! zero-allocation kernel engine runs in.
//!
//! A [`Scratch`] holds everything one in-flight image needs — a
//! ping-pong pair of activation buffers sized from the plan's largest
//! layer footprint, pre-sized residual-bypass slots, the pooled channel
//! vector and the dense head's `i64` accumulator — so steady-state
//! inference (`Executor::run_batch_into`) performs **zero heap
//! allocation per image**: every buffer is reused across images and
//! across batches, and `ensure` only grows capacity when the plan
//! (or a bigger plan) demands it.
//!
//! A [`ScratchPool`] is the batch-level counterpart: one `Scratch` per
//! worker thread of `Executor::run_batch`, owned by the persistent
//! serving backend (`engine::ExecutorBackend`) so the arena survives
//! across batches. Correctness does not depend on buffer contents:
//! `tests/kernels_arena.rs` deliberately poisons arenas with
//! [`Scratch::dirty`] and asserts bit-exactness against the
//! fresh-allocation path.
//!
//! Footprints are sized from the plan's **full-width** geometry
//! (`ConvGeom::cout`), never from a pruned plan's compacted row count:
//! a structurally pruned plan (DESIGN.md S23) still produces full-width
//! activation tensors (pruned channels hold their constant code), so
//! the same arena serves a plan and its pruned variants interchangeably.

use super::plan::{Multipliers, NetworkPlan, PlanOp};

/// Working buffers for one in-flight image. All fields are sized by
/// [`ensure`](Self::ensure) before a run; kernels slice them to the
/// current layer's exact footprint.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Current activation tensor (flat HWC codes). Kernels read `ping`,
    /// write `pong`, then the executor swaps the pair.
    pub(crate) ping: Vec<i32>,
    pub(crate) pong: Vec<i32>,
    /// Residual-bypass slots, one per nesting depth, each with capacity
    /// for the largest feature map (pushes `clear` + `extend` within
    /// capacity — no allocation).
    pub(crate) res: Vec<Vec<i32>>,
    /// Global sum-pool output (one lane per channel).
    pub(crate) pooled: Vec<i32>,
    /// Dense-head accumulator (`i64` blocked accumulation).
    pub(crate) acc64: Vec<i64>,
    /// Maddness codebook codes of one output pixel's batch tile
    /// (DESIGN.md S24): `[nb][n_codebooks]` for the widest approx layer
    /// of the plan. Empty on plans without `Multipliers::LutApprox`
    /// layers, so exact plans pay nothing.
    pub(crate) codes: Vec<u16>,
}

impl Scratch {
    /// An empty arena; [`ensure`](Self::ensure) sizes it on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// An arena pre-sized for `plan` (no growth on the first image).
    pub fn for_plan(plan: &NetworkPlan) -> Self {
        let mut s = Self::new();
        s.ensure(plan);
        s
    }

    /// Grow every buffer to fit `plan`. Idempotent and grow-only — and
    /// itself **allocation-free when already sized** (the boundary walk
    /// below is a plain fold over the ops, no intermediate `Vec`s), so
    /// it is safe to call on every image of the zero-allocation path.
    pub fn ensure(&mut self, plan: &NetworkPlan) {
        self.ensure_batch(plan, 1);
    }

    /// Like [`ensure`](Self::ensure) but for a **batch-major sweep**
    /// over `nb` interleaved images (DESIGN.md S22): every footprint is
    /// `nb`-strided (`[pixel][nb][c]` activations, `[nb][ch]` pooled,
    /// `[nb][cout]` dense accumulator). Same grow-only, allocation-free-
    /// when-sized contract, so the batch-major steady state stays
    /// zero-allocation too (`tests/zero_alloc.rs`).
    pub fn ensure_batch(&mut self, plan: &NetworkPlan, nb: usize) {
        let nb = nb.max(1);
        let (mut hw, mut ch) = (plan.io.image_size, plan.io.in_ch);
        let mut max_elems = hw * hw * ch;
        let mut max_ch = ch;
        let (mut depth, mut res_depth) = (0usize, 0usize);
        let mut dense_cout = 0usize;
        let mut max_codebooks = 0usize;
        for op in &plan.ops {
            match op {
                PlanOp::Input => {}
                PlanOp::ResAdd { .. } => depth = depth.saturating_sub(1),
                PlanOp::Conv(c) => {
                    hw = c.geom.out_h();
                    ch = c.geom.cout;
                    if let Multipliers::LutApprox { layer } = &c.mults {
                        max_codebooks = max_codebooks.max(layer.n_codebooks);
                    }
                }
                PlanOp::ResPush { .. } => {
                    depth += 1;
                    res_depth = res_depth.max(depth);
                }
                PlanOp::PoolSum { .. } => hw = 1,
                PlanOp::Dense(d) => {
                    hw = 1;
                    ch = d.cout;
                    dense_cout = dense_cout.max(d.cout);
                }
            }
            max_elems = max_elems.max(hw * hw * ch);
            max_ch = max_ch.max(ch);
        }
        let max_elems = max_elems * nb;
        let max_ch = max_ch * nb;
        let dense_cout = dense_cout * nb;
        if self.ping.len() < max_elems {
            self.ping.resize(max_elems, 0);
        }
        if self.pong.len() < max_elems {
            self.pong.resize(max_elems, 0);
        }
        while self.res.len() < res_depth {
            self.res.push(Vec::new());
        }
        for slot in &mut self.res {
            if slot.capacity() < max_elems {
                slot.reserve(max_elems - slot.len());
            }
        }
        if self.pooled.len() < max_ch {
            self.pooled.resize(max_ch, 0);
        }
        if self.acc64.len() < dense_cout {
            self.acc64.resize(dense_cout, 0);
        }
        let codes = max_codebooks * nb;
        if self.codes.len() < codes {
            self.codes.resize(codes, 0);
        }
    }

    /// Poison every buffer with `fill` — tests drive deliberately
    /// dirtied arenas through the kernels to prove no result depends on
    /// leftover scratch state.
    pub fn dirty(&mut self, fill: i32) {
        self.ping.fill(fill);
        self.pong.fill(fill);
        self.pooled.fill(fill);
        self.acc64.fill(fill as i64);
        self.codes.fill(fill as u16);
        for slot in &mut self.res {
            slot.clear();
            let cap = slot.capacity();
            slot.resize(cap, fill);
            slot.clear();
        }
    }
}

/// One [`Scratch`] per concurrent worker of a batch — the arena a
/// persistent backend keeps across batches so steady-state serving
/// never re-allocates working memory.
#[derive(Debug, Default)]
pub struct ScratchPool {
    pub(crate) slots: Vec<Scratch>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Make sure `n` arenas exist, each sized for `plan`.
    pub fn ensure(&mut self, n: usize, plan: &NetworkPlan) {
        while self.slots.len() < n {
            self.slots.push(Scratch::new());
        }
        for s in self.slots.iter_mut().take(n) {
            s.ensure(plan);
        }
    }

    /// Poison every arena (see [`Scratch::dirty`]).
    pub fn dirty(&mut self, fill: i32) {
        for s in &mut self.slots {
            s.dirty(fill);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mobilenet_v2_small;
    use crate::graph::network::Network;
    use crate::graph::plan::Datapath;

    #[test]
    fn ensure_sizes_from_plan_and_is_grow_only() {
        let net = Network::synthetic(&mobilenet_v2_small(), 1);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let mut s = Scratch::for_plan(&plan);
        let max = plan
            .boundary_geoms()
            .iter()
            .map(|&(hw, ch)| hw * hw * ch)
            .max()
            .unwrap();
        assert_eq!(s.ping.len(), max);
        assert_eq!(s.pong.len(), max);
        assert_eq!(s.acc64.len(), plan.dense_cout().unwrap());
        let (p0, q0) = (s.ping.capacity(), s.pong.capacity());
        s.ensure(&plan); // idempotent: no growth
        assert_eq!(s.ping.capacity(), p0);
        assert_eq!(s.pong.capacity(), q0);
        s.dirty(-7);
        assert!(s.ping.iter().all(|&v| v == -7));
    }

    #[test]
    fn ensure_batch_strides_footprints_and_stays_grow_only() {
        let net = Network::synthetic(&mobilenet_v2_small(), 4);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let max = plan
            .boundary_geoms()
            .iter()
            .map(|&(hw, ch)| hw * hw * ch)
            .max()
            .unwrap();
        let mut s = Scratch::new();
        s.ensure_batch(&plan, 6);
        assert_eq!(s.ping.len(), 6 * max);
        assert_eq!(s.pong.len(), 6 * max);
        assert_eq!(s.acc64.len(), 6 * plan.dense_cout().unwrap());
        let (p0, q0) = (s.ping.capacity(), s.pong.capacity());
        s.ensure_batch(&plan, 6); // idempotent at the same width
        s.ensure_batch(&plan, 2); // narrower batches never shrink
        s.ensure(&plan);
        assert_eq!(s.ping.capacity(), p0);
        assert_eq!(s.pong.capacity(), q0);
        assert_eq!(s.ping.len(), 6 * max);
    }

    #[test]
    fn pool_holds_one_arena_per_worker() {
        let net = Network::synthetic(&mobilenet_v2_small(), 2);
        let plan = NetworkPlan::compile(&net, Datapath::Arithmetic);
        let mut pool = ScratchPool::new();
        pool.ensure(3, &plan);
        assert_eq!(pool.slots.len(), 3);
        pool.ensure(2, &plan); // never shrinks
        assert_eq!(pool.slots.len(), 3);
        pool.dirty(5);
        assert!(pool.slots.iter().all(|s| s.ping.iter().all(|&v| v == 5)));
    }
}
