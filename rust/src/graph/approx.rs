//! Maddness-style approximate LUT datapath (DESIGN.md S24): product
//! quantization of the im2row column space, trained at plan-compile
//! time.
//!
//! The exact LUT-GEMM datapaths (DESIGN.md S20) spend one table lookup
//! and one `cout`-wide axpy per weight *column* — `cols` of them per
//! output pixel. Maddness (Stella Nera / halutmatmul) replaces that
//! with hashing: the column space is cut into `n_codebooks` contiguous
//! chunks, each chunk's activation sub-patch is hashed by a balanced
//! decision tree to one of `2^depth` learned prototypes, and the
//! precomputed dot product of every weight row with every prototype is
//! accumulated straight out of a codebook ROM. Per output pixel the
//! datapath does `depth` compares and ONE axpy per *codebook* instead
//! of one per column — `cols_per_codebook`x fewer accumulations, paid
//! for with quantization error.
//!
//! Training is deterministic and self-contained: prototypes are learned
//! from seeded synthetic activation patches (uniform over the layer's
//! `in_bits` code range) against the plan's (synthetic or artifact)
//! weights, so two compiles of the same network and [`ApproxSpec`]
//! produce bit-identical tables. The **saturated** configuration
//! (`cols_per_codebook == 1`, `depth >= in_bits`) degenerates to an
//! exact datapath: each single-column tree's thresholds are the binary
//! midpoints, so the leaf code *is* the activation code and every table
//! entry is the exact product `w * act` — bit-exact with
//! [`Multipliers::LutTables`](super::plan::Multipliers) by
//! construction. That anchor is what `tests/eval.rs` and `make
//! eval-smoke` gate on; the learned (wider-chunk) configurations trade
//! accuracy for the LUT-area and accumulation savings that
//! `lutmul report approx` and `lutmul eval --pareto` quantify.

use crate::fabric::cost;
use crate::util::prop::Rng;

/// Compile-time configuration of the approximate datapath
/// ([`NetworkPlan::compile_approx`](super::plan::NetworkPlan::compile_approx)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ApproxSpec {
    /// Weight columns per codebook (the chunk width `C` of the product
    /// quantization). `1` with `depth >= in_bits` is the saturated
    /// exact configuration.
    pub cols_per_codebook: usize,
    /// Decision-tree depth: every codebook hashes its sub-patch to one
    /// of `2^depth` prototypes.
    pub depth: usize,
    /// Synthetic activation patches sampled per layer when training the
    /// tree splits and prototypes (ignored by the saturated path).
    pub samples: usize,
    /// Seed of the per-layer training sample stream.
    pub seed: u64,
}

impl Default for ApproxSpec {
    fn default() -> Self {
        Self { cols_per_codebook: 4, depth: 4, samples: 256, seed: 0xADD5 }
    }
}

impl ApproxSpec {
    /// The saturated exact configuration: one column per codebook and a
    /// tree deep enough to enumerate every activation code, so the
    /// datapath reproduces the exact LUT-GEMM sums bit-for-bit.
    pub fn saturated() -> Self {
        Self { cols_per_codebook: 1, depth: 4, ..Self::default() }
    }
}

/// One conv layer's trained Maddness state — carried by
/// [`Multipliers::LutApprox`](super::plan::Multipliers) and read by the
/// approx kernel bodies in `graph::kernels`.
#[derive(Debug, Clone)]
pub struct ApproxLayer {
    /// Codebook count (`cols.div_ceil(cols_per_codebook)`).
    pub n_codebooks: usize,
    /// Tree depth actually compiled (clamped to `in_bits` on the
    /// saturated path — deeper levels cannot split integer codes
    /// further).
    pub depth: usize,
    /// Prototypes per codebook (`1 << depth`).
    pub n_protos: usize,
    /// Weight-row count the accumulation tables were built for.
    pub rows: usize,
    /// Codebook column ranges: codebook `c` covers weight columns
    /// `starts[c]..starts[c + 1]` (length `n_codebooks + 1`).
    pub starts: Vec<usize>,
    /// Per-level split dimension, relative to the codebook's first
    /// column: `split_dims[cb * depth + level]`.
    pub split_dims: Vec<usize>,
    /// Per-node split thresholds in heap order:
    /// `thresholds[cb * (2^depth - 1) + (2^level - 1) + code]`; the
    /// comparison is `value >= threshold` ⇒ right child.
    pub thresholds: Vec<i32>,
    /// Codebook accumulation tables, row-contiguous so one (codebook,
    /// code) pair yields an axpy-able column:
    /// `table[(cb * n_protos + code) * rows + row]` = dot(weight row
    /// chunk, prototype `code`).
    pub table: Vec<i32>,
    /// Physical LUT6 estimate of the codebook ROMs + hash comparators +
    /// shortened adder trees (`fabric::cost::approx_layer_lut_area`).
    pub lut6: usize,
    /// True for the saturated configuration — the datapath is bit-exact
    /// with the exact LUT tables by construction.
    pub exact: bool,
}

impl ApproxLayer {
    /// Train a layer's hash trees and codebook tables against its
    /// (possibly synthetic) weight matrix. `wmat` is `[rows][cols]`
    /// weight codes, activations are `in_bits`-bit unsigned codes.
    /// Deterministic in (`wmat`, `w_bits`, `in_bits`, `spec`, `seed`).
    pub fn train(wmat: &[Vec<i32>], w_bits: u32, in_bits: u32, spec: &ApproxSpec, seed: u64) -> Self {
        let rows = wmat.len();
        let cols = wmat[0].len();
        let amax = (1i32 << in_bits) - 1;
        let cw = spec.cols_per_codebook.max(1);
        let n_codebooks = cols.div_ceil(cw);
        let exact = cw == 1 && spec.depth >= in_bits as usize;
        let depth = if exact { in_bits as usize } else { spec.depth.clamp(1, 8) };
        let n_protos = 1usize << depth;
        let nodes = n_protos - 1;
        let starts: Vec<usize> =
            (0..=n_codebooks).map(|c| (c * cw).min(cols)).collect();

        let mut split_dims = vec![0usize; n_codebooks * depth];
        let mut thresholds = vec![0i32; n_codebooks * nodes];
        let mut table = vec![0i32; n_codebooks * n_protos * rows];

        if exact {
            // Saturated path: binary-midpoint thresholds make the leaf
            // code equal the activation code, so table entries are the
            // exact products and the whole datapath is bit-exact.
            for cb in 0..n_codebooks {
                for l in 0..depth {
                    for p in 0..1usize << l {
                        thresholds[cb * nodes + (1 << l) - 1 + p] =
                            (2 * p as i32 + 1) << (depth - 1 - l);
                    }
                }
                for code in 0..n_protos {
                    let t = &mut table[(cb * n_protos + code) * rows..][..rows];
                    for (r, slot) in t.iter_mut().enumerate() {
                        *slot = wmat[r][cb] * code as i32;
                    }
                }
            }
        } else {
            let mut rng = Rng::new(seed ^ 0x6d61_6464_6e65_7373);
            let n_samples = spec.samples.max(4 * n_protos);
            for cb in 0..n_codebooks {
                let cwc = starts[cb + 1] - starts[cb];
                // [sample][dim] synthetic activation sub-patches,
                // uniform over the layer's code range.
                let samples = rng.vec_i32(n_samples * cwc, 0, amax);
                let mut buckets = vec![0usize; n_samples];
                for l in 0..depth {
                    let dim = split_dim(&samples, &buckets, n_samples, cwc, 1 << l);
                    split_dims[cb * depth + l] = dim;
                    let mut vals: Vec<i32> = Vec::with_capacity(n_samples);
                    for b in 0..1usize << l {
                        vals.clear();
                        vals.extend(
                            (0..n_samples)
                                .filter(|&s| buckets[s] == b)
                                .map(|s| samples[s * cwc + dim]),
                        );
                        vals.sort_unstable();
                        let t = if vals.is_empty() {
                            (amax + 1) / 2
                        } else {
                            vals[vals.len() / 2]
                        };
                        thresholds[cb * nodes + (1 << l) - 1 + b] = t;
                    }
                    for s in 0..n_samples {
                        let t = thresholds[cb * nodes + (1 << l) - 1 + buckets[s]];
                        buckets[s] = (buckets[s] << 1) | (samples[s * cwc + dim] >= t) as usize;
                    }
                }
                // Prototypes: per-leaf mean sub-patch (midpoint for an
                // empty leaf), folded straight into the weight tables.
                let mut proto = vec![0f64; cwc];
                for code in 0..n_protos {
                    let members: Vec<usize> =
                        (0..n_samples).filter(|&s| buckets[s] == code).collect();
                    for (d, p) in proto.iter_mut().enumerate() {
                        *p = if members.is_empty() {
                            amax as f64 / 2.0
                        } else {
                            members.iter().map(|&s| samples[s * cwc + d] as f64).sum::<f64>()
                                / members.len() as f64
                        };
                    }
                    let t = &mut table[(cb * n_protos + code) * rows..][..rows];
                    for (r, slot) in t.iter_mut().enumerate() {
                        let dot: f64 = proto
                            .iter()
                            .enumerate()
                            .map(|(d, &p)| wmat[r][starts[cb] + d] as f64 * p)
                            .sum();
                        *slot = dot.round() as i32;
                    }
                }
            }
        }

        let lut6 = cost::approx_layer_lut_area(w_bits, rows, cols, n_codebooks, depth as u32)
            .round() as usize;
        Self {
            n_codebooks,
            depth,
            n_protos,
            rows,
            starts,
            split_dims,
            thresholds,
            table,
            lut6,
            exact,
        }
    }

    /// Hash one codebook's sub-patch to its prototype code. `col_val`
    /// reads the activation at an absolute weight-column index (the
    /// caller supplies direct, interleaved or zero-padded access); only
    /// the `depth` split dimensions are ever read.
    #[inline]
    pub fn code_with(&self, cb: usize, mut col_val: impl FnMut(usize) -> i32) -> usize {
        let nodes = self.n_protos - 1;
        let base = cb * nodes;
        let start = self.starts[cb];
        let dims = &self.split_dims[cb * self.depth..(cb + 1) * self.depth];
        let mut code = 0usize;
        for (l, &dim) in dims.iter().enumerate() {
            let t = self.thresholds[base + (1 << l) - 1 + code];
            code = (code << 1) | (col_val(start + dim) >= t) as usize;
        }
        code
    }

    /// The contiguous `rows`-wide accumulation column of one (codebook,
    /// code) pair — the axpy operand of the approx kernels.
    #[inline]
    pub fn table_col(&self, cb: usize, code: usize) -> &[i32] {
        &self.table[(cb * self.n_protos + code) * self.rows..][..self.rows]
    }

    /// Approximate inner product of weight row `row` with a full im2col
    /// patch (`[cols]`, column order) — the scalar-path analogue of
    /// `ConvPlan::dot`.
    #[inline]
    pub fn dot(&self, row: usize, patch: &[i32]) -> i32 {
        (0..self.n_codebooks)
            .map(|cb| {
                let code = self.code_with(cb, |c| patch[c]);
                self.table[(cb * self.n_protos + code) * self.rows + row]
            })
            .sum()
    }
}

/// Deterministic per-layer training seed: the spec's seed folded with
/// an FNV-1a hash of the layer name, so every layer trains on its own
/// sample stream yet two compiles of the same network agree bit-for-bit.
pub fn layer_seed(seed: u64, name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    seed ^ h
}

/// The split dimension for one tree level: the chunk dimension with the
/// largest summed within-bucket variance — splitting where the buckets
/// are still widest buys the most, the same greedy criterion Maddness
/// uses (its per-level "heuristic select").
fn split_dim(
    samples: &[i32],
    buckets: &[usize],
    n_samples: usize,
    cwc: usize,
    n_buckets: usize,
) -> usize {
    let mut best = (0usize, f64::MIN);
    for d in 0..cwc {
        let mut score = 0.0;
        for b in 0..n_buckets {
            let (mut n, mut sum, mut sq) = (0.0f64, 0.0f64, 0.0f64);
            for s in 0..n_samples {
                if buckets[s] == b {
                    let v = samples[s * cwc + d] as f64;
                    n += 1.0;
                    sum += v;
                    sq += v * v;
                }
            }
            if n > 0.0 {
                score += sq - sum * sum / n;
            }
        }
        if score > best.1 {
            best = (d, score);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wmat(rows: usize, cols: usize) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(7);
        (0..rows).map(|_| rng.vec_i32(cols, -7, 7)).collect()
    }

    #[test]
    fn saturated_layer_is_exact() {
        let w = wmat(5, 9);
        let layer = ApproxLayer::train(&w, 4, 4, &ApproxSpec::saturated(), 42);
        assert!(layer.exact);
        assert_eq!(layer.n_codebooks, 9);
        assert_eq!(layer.n_protos, 16);
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let patch = rng.vec_i32(9, 0, 15);
            for row in 0..5 {
                let exact: i32 =
                    w[row].iter().zip(&patch).map(|(&wv, &a)| wv * a).sum();
                assert_eq!(layer.dot(row, &patch), exact, "row {row} patch {patch:?}");
            }
        }
    }

    #[test]
    fn saturated_code_is_the_activation() {
        let w = wmat(2, 4);
        let layer = ApproxLayer::train(&w, 4, 4, &ApproxSpec::saturated(), 1);
        for a in 0..16 {
            assert_eq!(layer.code_with(2, |_| a), a as usize);
        }
    }

    #[test]
    fn training_is_deterministic() {
        let w = wmat(6, 16);
        let spec = ApproxSpec::default();
        let a = ApproxLayer::train(&w, 4, 4, &spec, 0xFEED);
        let b = ApproxLayer::train(&w, 4, 4, &spec, 0xFEED);
        assert_eq!(a.table, b.table);
        assert_eq!(a.thresholds, b.thresholds);
        assert_eq!(a.split_dims, b.split_dims);
    }

    #[test]
    fn chunking_covers_ragged_tails() {
        let w = wmat(3, 10);
        let layer = ApproxLayer::train(&w, 4, 4, &ApproxSpec::default(), 5);
        // 10 columns at width 4 -> 3 codebooks, last covering 2 columns
        assert_eq!(layer.n_codebooks, 3);
        assert_eq!(layer.starts, vec![0, 4, 8, 10]);
        assert_eq!(layer.table.len(), 3 * 16 * 3);
        assert!(!layer.exact);
    }

    #[test]
    fn learned_dot_tracks_exact_dot() {
        // The approximation must land in the right ballpark: over many
        // random patches the mean absolute error stays well under the
        // exact dot's own scale.
        let w = wmat(4, 16);
        let layer = ApproxLayer::train(&w, 4, 4, &ApproxSpec::default(), 11);
        let mut rng = Rng::new(3);
        let (mut err, mut mag) = (0f64, 0f64);
        for _ in 0..200 {
            let patch = rng.vec_i32(16, 0, 15);
            for row in 0..4 {
                let exact: i32 =
                    w[row].iter().zip(&patch).map(|(&wv, &a)| wv * a).sum();
                err += (layer.dot(row, &patch) - exact).abs() as f64;
                mag += (exact.abs() as f64).max(1.0);
            }
        }
        assert!(err / mag < 0.5, "relative error {}", err / mag);
    }

    #[test]
    fn lut6_estimate_beats_exact_tables() {
        // The area headline: at the default chunk width the codebook
        // ROMs + hash logic undercut the exact per-column ROM array.
        let layer = ApproxLayer::train(&wmat(32, 288), 4, 4, &ApproxSpec::default(), 2);
        let exact = cost::layer_lut_area(4, 32, 288);
        assert!(
            (layer.lut6 as f64) < exact,
            "approx {} LUT6 vs exact {exact}",
            layer.lut6
        );
    }
}
