//! FPGA LUT primitives: bit-exact models of Xilinx `LUT6` and `LUT6_2`.
//!
//! A LUT6 is a 64x1 ROM addressed by six inputs `{I5..I0}`; its contents
//! are the 64-bit INIT vector. `LUT6_2` exposes two outputs from the same
//! 64-bit INIT: `O6` reads the full table (6 inputs) and `O5` reads the
//! lower 32 bits (5 inputs, `I5` excluded). These are the exact primitive
//! semantics from the Xilinx UltraScale CLB user guide and are what the
//! paper's Figure 5 configures.


/// A single 6-input, 1-output look-up table (64-bit INIT ROM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lut6 {
    /// INIT vector: output bit for each of the 64 input combinations.
    pub init: u64,
}

impl Lut6 {
    /// Create from an INIT vector (the `64'h...` constant of an HDL netlist).
    pub fn new(init: u64) -> Self {
        Self { init }
    }

    /// Evaluate the LUT at a 6-bit address `{I5,I4,I3,I2,I1,I0}`.
    #[inline]
    pub fn eval(&self, addr: u8) -> bool {
        debug_assert!(addr < 64, "LUT6 address must be 6 bits");
        (self.init >> (addr & 0x3f)) & 1 == 1
    }
}

/// A dual-output LUT: one physical 64-bit LUT split into `O6` (6-input)
/// and `O5` (5-input, lower half) outputs. Requires `I5 = 1` when both
/// outputs are used — exactly how Figure 5 wires it ("The MSB of LUT6_2
/// input is configured as '1' to enable two output ports").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lut6_2 {
    pub init: u64,
}

impl Lut6_2 {
    pub fn new(init: u64) -> Self {
        Self { init }
    }

    /// `O6`: reads the full 64-bit table with all six inputs.
    #[inline]
    pub fn o6(&self, addr6: u8) -> bool {
        (self.init >> (addr6 & 0x3f)) & 1 == 1
    }

    /// `O5`: reads the lower 32 bits with the five inputs `{I4..I0}`.
    #[inline]
    pub fn o5(&self, addr5: u8) -> bool {
        (self.init >> (addr5 & 0x1f)) & 1 == 1
    }

    /// Evaluate both outputs with `I5` tied high (the Figure 5 wiring):
    /// `O6` sees address `32 + addr5`, `O5` sees `addr5`.
    #[inline]
    pub fn eval_dual(&self, addr5: u8) -> (bool, bool) {
        (self.o6(0x20 | (addr5 & 0x1f)), self.o5(addr5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut6_reads_init_bits() {
        let l = Lut6::new(0b1010);
        assert!(!l.eval(0));
        assert!(l.eval(1));
        assert!(!l.eval(2));
        assert!(l.eval(3));
        assert!(!l.eval(63));
    }

    #[test]
    fn lut6_all_ones() {
        let l = Lut6::new(u64::MAX);
        for a in 0..64u8 {
            assert!(l.eval(a));
        }
    }

    #[test]
    fn lut6_2_o5_only_lower_half() {
        // upper 32 bits set, lower clear: O5 must never read upper bits.
        let l = Lut6_2::new(0xffff_ffff_0000_0000);
        for a in 0..32u8 {
            assert!(!l.o5(a));
            assert!(l.o6(0x20 | a));
        }
    }

    #[test]
    fn lut6_2_dual_addresses() {
        // INIT with bit 5 (lower half) and bit 37 (= 32+5, upper half) set.
        let l = Lut6_2::new((1u64 << 5) | (1u64 << 37));
        let (o6, o5) = l.eval_dual(5);
        assert!(o6 && o5);
        let (o6, o5) = l.eval_dual(6);
        assert!(!o6 && !o5);
    }
}
