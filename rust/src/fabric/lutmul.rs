//! LUT-embedded constant multipliers — the paper's core contribution
//! (section 3.5, Figure 5).
//!
//! Two signed `n`-bit weights are embedded into `n` physical `LUT6_2`
//! primitives (2n output bits for each of the two weights' products,
//! two bits per LUT). The LUT inputs are `{I5=1, I4=WS, I3..I0=activation}`:
//! `WS` selects which of the two weights multiplies the (unsigned)
//! activation, and the 2n-bit two's-complement product is read out across
//! the LUT outputs. For 4-bit weights this is 4 LUT6 per 2 multipliers =
//! **2 LUT6 per multiplication**, vs 13-28 LUT6 for a general 4x4
//! multiplier — the resource advantage the whole paper builds on.
//!
//! `lutmul_init` reproduces the INIT generation of Figure 5 bit-for-bit
//! (the figure's example constants for weights `1` and `-3` are unit
//! tests below).

use super::lut::Lut6_2;

/// Generate the INIT vectors embedding two signed 4-bit weights.
///
/// Returns 4 INIT values; LUT `L` (0..4) outputs product bits `7 - 2L`
/// (on `O6`) and `6 - 2L` (on `O5`). Address layout (Figure 5):
/// `O5` plane in the lower 32 bits (`16*WS + act`), `O6` plane in the
/// upper 32 bits (`32 + 16*WS + act`).
pub fn lutmul_init(w0: i8, w1: i8) -> [u64; 4] {
    lutmul_init_generic(w0 as i32, w1 as i32, 4)
        .try_into()
        .expect("4-bit weights need exactly 4 LUTs")
}

/// Generalized INIT generation for `n`-bit weights, `n`-bit unsigned
/// activations, `2n`-bit two's-complement products. Needs `2^n <= 16`
/// activation codes to fit the LUT6_2 addressing of Figure 5 (larger
/// bit-widths cascade multiple LUTs; see [`super::cost::luts_per_mult`]).
pub fn lutmul_init_generic(w0: i32, w1: i32, n_bits: u32) -> Vec<u64> {
    assert!(n_bits >= 1 && n_bits <= 4, "Figure 5 packing addresses <= 4 activation bits");
    let prod_bits = 2 * n_bits;
    let n_luts = n_bits as usize; // 2 bits per LUT6_2
    let acts = 1u32 << n_bits;
    let mask = (1u32 << prod_bits) - 1; // two's complement truncation
    let mut inits = vec![0u64; n_luts];
    for (ws, &w) in [w0, w1].iter().enumerate() {
        for a in 0..acts {
            let p = ((w * a as i32) as u32) & mask;
            for l in 0..n_luts {
                let hi_bit = prod_bits - 1 - 2 * l as u32; // O6 plane
                let lo_bit = prod_bits - 2 - 2 * l as u32; // O5 plane
                let addr5 = (ws as u64) * 16 + a as u64;
                if (p >> hi_bit) & 1 == 1 {
                    inits[l] |= 1u64 << (32 + addr5);
                }
                if (p >> lo_bit) & 1 == 1 {
                    inits[l] |= 1u64 << addr5;
                }
            }
        }
    }
    inits
}

/// A hardware constant multiplier: two embedded weights, `n` LUT6_2s.
#[derive(Debug, Clone)]
pub struct ConstMultiplier {
    luts: Vec<Lut6_2>,
    n_bits: u32,
    /// The embedded weights (for inspection/debug only — the hardware
    /// truth is the INIT vectors).
    pub weights: [i32; 2],
}

impl ConstMultiplier {
    /// Embed two signed `n_bits` weights (n_bits <= 4).
    pub fn new(w0: i32, w1: i32, n_bits: u32) -> Self {
        let lim = 1i32 << (n_bits - 1);
        assert!((-lim..lim).contains(&w0) && (-lim..lim).contains(&w1));
        let luts = lutmul_init_generic(w0, w1, n_bits)
            .into_iter()
            .map(Lut6_2::new)
            .collect();
        Self { luts, n_bits, weights: [w0, w1] }
    }

    /// Number of physical LUT6 consumed.
    pub fn lut_count(&self) -> usize {
        self.luts.len()
    }

    /// Multiply the selected weight by an unsigned activation code by
    /// *reading the LUTs* (not by arithmetic) — this is the datapath the
    /// FPGA would execute.
    pub fn eval(&self, ws: bool, act: u32) -> i32 {
        assert!(act < (1 << self.n_bits));
        let addr5 = ((ws as u8) << 4) | (act as u8);
        let prod_bits = 2 * self.n_bits;
        let mut p: u32 = 0;
        for (l, lut) in self.luts.iter().enumerate() {
            let (o6, o5) = lut.eval_dual(addr5);
            let hi_bit = prod_bits - 1 - 2 * l as u32;
            let lo_bit = prod_bits - 2 - 2 * l as u32;
            if o6 {
                p |= 1 << hi_bit;
            }
            if o5 {
                p |= 1 << lo_bit;
            }
        }
        // sign-extend the 2n-bit two's-complement product
        let shift = 32 - prod_bits;
        ((p << shift) as i32) >> shift
    }

    /// INIT constants, formatted like an HDL netlist (`64'h...`).
    pub fn init_strings(&self) -> Vec<String> {
        self.luts
            .iter()
            .map(|l| {
                format!(
                    "64'h{:04x}_{:04x}_{:04x}_{:04x}",
                    (l.init >> 48) & 0xffff,
                    (l.init >> 32) & 0xffff,
                    (l.init >> 16) & 0xffff,
                    l.init & 0xffff
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 5's exact published INIT constants for weights 1 and -3.
    #[test]
    fn figure5_init_constants() {
        let inits = lutmul_init(1, -3);
        assert_eq!(inits[0], 0xfffe_0000_fffe_0000, "bits 7/6");
        assert_eq!(inits[1], 0x07fe_0000_f83e_0000, "bits 5/4");
        assert_eq!(inits[2], 0x39c6_ff00_5a5a_f0f0, "bits 3/2");
        assert_eq!(inits[3], 0xcccc_cccc_aaaa_aaaa, "bits 1/0");
    }

    #[test]
    fn figure5_multiplication_table() {
        // The right-hand table of Figure 5: products of 1 and -3 with all
        // uint4 activations, int8 two's complement.
        let m = ConstMultiplier::new(1, -3, 4);
        for a in 0..16 {
            assert_eq!(m.eval(false, a), a as i32, "weight 1 x {a}");
            assert_eq!(m.eval(true, a), -3 * a as i32, "weight -3 x {a}");
        }
    }

    #[test]
    fn exhaustive_all_int4_weight_pairs() {
        // Every (w0, w1) in [-8, 7]^2, every uint4 activation: the LUT
        // readout must equal the integer product.
        for w0 in -8..8 {
            for w1 in -8..8 {
                let m = ConstMultiplier::new(w0, w1, 4);
                assert_eq!(m.lut_count(), 4);
                for a in 0..16u32 {
                    assert_eq!(m.eval(false, a), w0 * a as i32);
                    assert_eq!(m.eval(true, a), w1 * a as i32);
                }
            }
        }
    }

    #[test]
    fn lower_bitwidths() {
        for n in 1..=3u32 {
            let lim = 1i32 << (n - 1);
            for w0 in -lim..lim {
                for w1 in -lim..lim {
                    let m = ConstMultiplier::new(w0, w1, n);
                    assert_eq!(m.lut_count(), n as usize);
                    for a in 0..(1u32 << n) {
                        assert_eq!(m.eval(false, a), w0 * a as i32, "n={n} w0={w0} a={a}");
                        assert_eq!(m.eval(true, a), w1 * a as i32, "n={n} w1={w1} a={a}");
                    }
                }
            }
        }
    }

    #[test]
    fn init_strings_format() {
        let m = ConstMultiplier::new(1, -3, 4);
        assert_eq!(m.init_strings()[0], "64'hfffe_0000_fffe_0000");
        assert_eq!(m.init_strings()[3], "64'hcccc_cccc_aaaa_aaaa");
    }
}
