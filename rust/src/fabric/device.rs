//! FPGA / GPU device inventories (Table 1 of the paper).
//!
//! All numbers are from the public datasheets the paper cites: the Alveo
//! product selection guide (U280), Zynq UltraScale+ and 7-series tables,
//! and the NVIDIA V100 whitepaper.


/// FPGA device resource inventory + memory system.
#[derive(Debug, Clone, PartialEq)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub technology_nm: u32,
    pub luts: u64,
    pub ffs: u64,
    pub bram36: u64,
    pub uram: u64,
    pub dsps: u64,
    /// Super Logic Regions (dies); resources are split ~evenly across them.
    pub slrs: u32,
    /// Achievable clock for a well-pipelined dataflow design (MHz).
    pub max_freq_mhz: f64,
    /// DDR bandwidth (GB/s); 0 if none.
    pub ddr_gbps: f64,
    /// HBM bandwidth (GB/s); 0 if none.
    pub hbm_gbps: f64,
    /// Max / typical board power (W).
    pub power_max_w: f64,
    pub power_typ_w: f64,
}

impl FpgaDevice {
    /// Total off-chip bandwidth (GB/s).
    pub fn total_bw_gbps(&self) -> f64 {
        self.ddr_gbps + self.hbm_gbps
    }

    /// A fractional slice of the device (e.g. the paper's 1/64 of U280
    /// for the Figure 1 roofline).
    pub fn fraction(&self, denom: u64) -> FpgaSlice {
        FpgaSlice {
            device: self.clone(),
            luts: self.luts / denom,
            dsps: self.dsps / denom,
            bram36: self.bram36 / denom,
            bw_gbps: self.hbm_gbps.max(self.ddr_gbps) / denom as f64,
        }
    }
}

/// A resource slice of a device (roofline analysis granularity).
#[derive(Debug, Clone)]
pub struct FpgaSlice {
    pub device: FpgaDevice,
    pub luts: u64,
    pub dsps: u64,
    pub bram36: u64,
    pub bw_gbps: f64,
}

/// GPU datasheet entry (Table 1 comparison column).
#[derive(Debug, Clone)]
pub struct GpuDevice {
    pub name: &'static str,
    pub technology_nm: u32,
    pub clock_mhz: f64,
    pub cuda_cores: u32,
    pub tensor_cores: u32,
    pub fp32_tflops: f64,
    pub fp16_tensor_tflops: f64,
    pub mem_gb: f64,
    pub bw_gbps: f64,
    pub power_w: f64,
    pub price_usd: f64,
}

/// AMD Xilinx Alveo U280 (the paper's platform).
pub const U280: FpgaDevice = FpgaDevice {
    name: "Alveo U280",
    technology_nm: 16,
    luts: 1_304_000,
    ffs: 2_607_000,
    bram36: 2_016,
    uram: 960,
    dsps: 9_024,
    slrs: 3,
    max_freq_mhz: 333.0,
    ddr_gbps: 38.0,
    hbm_gbps: 460.0,
    power_max_w: 225.0,
    power_typ_w: 100.0,
};

/// Zynq UltraScale+ ZU9EG (FPL'19, FILM-QNN platform).
pub const ZU9EG: FpgaDevice = FpgaDevice {
    name: "ZU9EG",
    technology_nm: 16,
    luts: 274_080,
    ffs: 548_160,
    bram36: 912,
    uram: 0,
    dsps: 2_520,
    slrs: 1,
    max_freq_mhz: 333.0,
    ddr_gbps: 19.2,
    hbm_gbps: 0.0,
    power_max_w: 60.0,
    power_typ_w: 20.0,
};

/// Kintex-7 XC7K325T (Light-OPU platform).
pub const XC7K325T: FpgaDevice = FpgaDevice {
    name: "XC7K325T",
    technology_nm: 28,
    luts: 203_800,
    ffs: 407_600,
    bram36: 445,
    uram: 0,
    dsps: 840,
    slrs: 1,
    max_freq_mhz: 200.0,
    ddr_gbps: 12.8,
    hbm_gbps: 0.0,
    power_max_w: 25.0,
    power_typ_w: 10.0,
};

/// Virtex-7 XC7V690T (FPL'21 platform).
pub const XC7V690T: FpgaDevice = FpgaDevice {
    name: "XC7V690T",
    technology_nm: 28,
    luts: 433_200,
    ffs: 866_400,
    bram36: 1_470,
    uram: 0,
    dsps: 3_600,
    slrs: 1,
    max_freq_mhz: 200.0,
    ddr_gbps: 12.8,
    hbm_gbps: 0.0,
    power_max_w: 40.0,
    power_typ_w: 15.0,
};

/// Zynq-7000 XC7Z045 (Mix & Match platform).
pub const XC7Z045: FpgaDevice = FpgaDevice {
    name: "XC7Z045",
    technology_nm: 28,
    luts: 218_600,
    ffs: 437_200,
    bram36: 545,
    uram: 0,
    dsps: 900,
    slrs: 1,
    max_freq_mhz: 150.0,
    ddr_gbps: 12.8,
    hbm_gbps: 0.0,
    power_max_w: 30.0,
    power_typ_w: 12.0,
};

/// NVIDIA Tesla V100 PCIe (Table 1 comparison).
pub const V100: GpuDevice = GpuDevice {
    name: "V100 GPU",
    technology_nm: 12,
    clock_mhz: 1530.0,
    cuda_cores: 5120,
    tensor_cores: 640,
    fp32_tflops: 14.0,
    fp16_tensor_tflops: 112.0,
    mem_gb: 32.0,
    bw_gbps: 900.0,
    power_w: 250.0,
    price_usd: 11_458.0,
};

/// All FPGA devices appearing in Table 2.
pub fn all_fpgas() -> Vec<&'static FpgaDevice> {
    vec![&U280, &ZU9EG, &XC7K325T, &XC7V690T, &XC7Z045]
}

/// U280 INT8 DSP peak (Table 1: 24.5 TOPs) — Eq. (1) with p=2, f=680MHz
/// DSP fabric limit per the Alveo datasheet's peak-performance method.
pub fn u280_datasheet_int8_tops() -> f64 {
    // 9024 DSPs * 2 ops (MAC) * 2 (8-bit packing) * 680 MHz
    9024.0 * 2.0 * 2.0 * 680e6 / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_matches_table1() {
        assert_eq!(U280.dsps, 9024);
        assert_eq!(U280.hbm_gbps, 460.0);
        assert_eq!(U280.ddr_gbps, 38.0);
        assert_eq!(U280.power_max_w, 225.0);
        assert_eq!(U280.slrs, 3);
    }

    #[test]
    fn v100_matches_table1() {
        assert_eq!(V100.cuda_cores, 5120);
        assert_eq!(V100.tensor_cores, 640);
        assert_eq!(V100.fp32_tflops, 14.0);
        assert_eq!(V100.bw_gbps, 900.0);
    }

    #[test]
    fn u280_int8_peak_near_datasheet() {
        let tops = u280_datasheet_int8_tops();
        assert!((tops - 24.5).abs() < 0.3, "got {tops} TOPs, datasheet says 24.5");
    }

    #[test]
    fn fraction_slices_resources() {
        let s = U280.fraction(64);
        assert_eq!(s.luts, U280.luts / 64);
        assert_eq!(s.dsps, 141);
        assert!((s.bw_gbps - 460.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn lut_to_dsp_ratio_is_about_100x() {
        // The paper's motivating observation: LUTs outnumber DSPs ~100x.
        for d in all_fpgas() {
            let ratio = d.luts as f64 / d.dsps as f64;
            assert!(ratio > 55.0 && ratio < 260.0, "{}: {ratio}", d.name);
        }
    }
}
