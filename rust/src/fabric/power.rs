//! Board power model, calibrated to the paper's Table 2 measurements.
//!
//! A linear activity model: `P = P_static + (a*LUT + b*BRAM + c*DSP) * f`.
//! Coefficients are calibrated so the two designs the paper measured on
//! the same U280 board land on their published numbers:
//!   FINN   (501k LUT, 898 BRAM, 106 DSP @ 333 MHz)  -> 41.69 W
//!   LUTMUL (529k LUT, 1119 BRAM, 106 DSP @ 333 MHz) -> 42.12 W
//! This is the usual Vivado report_power-style abstraction: static plus
//! toggling-proportional dynamic power.

use super::device::FpgaDevice;

/// Per-resource dynamic power coefficients (W per unit per MHz), solved
/// from the FINN/LUTMUL calibration pair above.
pub const LUT_W_PER_MHZ: f64 = 5.85e-8;
pub const BRAM_W_PER_MHZ: f64 = 5.0e-6;
pub const DSP_W_PER_MHZ: f64 = 1.2e-5;

/// Static (idle) board power for data-center cards vs edge parts, as a
/// fraction of typical power.
fn static_power_w(device: &FpgaDevice) -> f64 {
    // U280 idles around 30 W (shell + HBM + fans); edge parts far lower.
    if device.hbm_gbps > 0.0 {
        30.0
    } else {
        0.15 * device.power_typ_w
    }
}

/// Estimate board power for a design's resource usage at `freq_mhz`.
pub fn estimate_power_w(
    device: &FpgaDevice,
    luts: u64,
    bram36: u64,
    dsps: u64,
    freq_mhz: f64,
) -> f64 {
    static_power_w(device)
        + (luts as f64 * LUT_W_PER_MHZ
            + bram36 as f64 * BRAM_W_PER_MHZ
            + dsps as f64 * DSP_W_PER_MHZ)
            * freq_mhz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::device::U280;

    #[test]
    fn finn_calibration_point() {
        let p = estimate_power_w(&U280, 501_363, 898, 106, 333.0);
        assert!((p - 41.69).abs() < 1.5, "FINN power {p} vs paper 41.69 W");
    }

    #[test]
    fn lutmul_calibration_point() {
        let p = estimate_power_w(&U280, 529_242, 1119, 106, 333.0);
        assert!((p - 42.12).abs() < 1.5, "LUTMUL power {p} vs paper 42.12 W");
    }

    #[test]
    fn power_monotonic_in_resources() {
        let lo = estimate_power_w(&U280, 100_000, 100, 0, 333.0);
        let hi = estimate_power_w(&U280, 500_000, 1000, 0, 333.0);
        assert!(hi > lo);
    }

    #[test]
    fn power_scales_with_frequency() {
        let a = estimate_power_w(&U280, 500_000, 1000, 100, 100.0);
        let b = estimate_power_w(&U280, 500_000, 1000, 100, 300.0);
        assert!(b > a);
    }

    #[test]
    fn stays_below_board_max() {
        // A full-device design at max frequency must stay within the
        // board's power envelope (sanity of the coefficients).
        let p = estimate_power_w(&U280, U280.luts, U280.bram36, U280.dsps, 333.0);
        assert!(p < U280.power_max_w, "{p} W exceeds board max");
    }
}
