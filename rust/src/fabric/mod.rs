//! FPGA fabric simulator substrate (DESIGN.md S1-S3).
//!
//! Bit-exact LUT primitive models (`lut`), the paper's LUT-embedded
//! constant multipliers with Figure 5 INIT generation (`lutmul`), LUT
//! cost models including Eq. (3) (`cost`), device resource inventories
//! from Table 1 (`device`), and the calibrated board power model
//! (`power`).

pub mod cost;
pub mod device;
pub mod fp4;
pub mod lut;
pub mod lutmul;
pub mod netlist;
pub mod power;

pub use cost::{adder_tree_luts, layer_lut_area, luts_per_general_mult, luts_per_mult};
pub use device::{FpgaDevice, FpgaSlice, GpuDevice, U280, V100};
pub use lut::{Lut6, Lut6_2};
pub use lutmul::{lutmul_init, lutmul_init_generic, ConstMultiplier};
pub use power::estimate_power_w;
