//! LUT cost models: Eq. (3) of the paper plus literature-calibrated costs
//! for general multipliers and adder logic.

/// LUT6 count for one n-bit multiplication via LUTMUL embedding — Eq. (3):
/// `#LUTs = (2n * 2^n) / (1 * 2^6)`.
///
/// For n >= 3 this is exact ROM sizing (a `2n`-bit-wide, `2^n`-deep table
/// sliced into 6-input LUTs). Small n floors at 1 physical LUT per *pair*
/// of output bits because a LUT6_2 cannot emit fewer than its two ports —
/// the same floor the paper's Figure 2 plots for 1-2 bit weights.
pub fn luts_per_mult(n_bits: u32) -> f64 {
    let raw = (2.0 * n_bits as f64) * (1u64 << n_bits) as f64 / 64.0;
    raw.max(1.0)
}

/// LUT6 count for a general (non-constant) n x n-bit multiplier on soft
/// logic. The paper cites 13-28 LUT6 for 4-bit; the model below is the
/// standard partial-product estimate `~1.1 n^2` that lands in that range
/// and scales sensibly (Vivado synthesis of `a*b` multipliers).
pub fn luts_per_general_mult(n_bits: u32) -> f64 {
    (1.1 * (n_bits * n_bits) as f64).max(13.0_f64.min((n_bits * n_bits) as f64))
}

/// LUT6 count for a `width`-bit 2-input adder: one LUT per result bit
/// (carry chains ride the dedicated CARRY8 logic, not LUTs, but each bit
/// consumes the LUT in front of it).
pub fn luts_per_adder(width: u32) -> f64 {
    width as f64
}

/// Accumulator width needed to sum `n_terms` products of `prod_bits`-bit
/// values without overflow.
pub fn accumulator_width(prod_bits: u32, n_terms: u32) -> u32 {
    prod_bits + (32 - (n_terms.max(1)).leading_zeros())
}

/// LUT cost of a balanced adder *tree* reducing `n_terms` values of
/// `prod_bits` bits down to one accumulator. Widths grow one bit per
/// level. An HLS `II=1` pipeline instantiates every adder (paper
/// section 4.3: "HLS instantiates an adder for each addition operation").
pub fn adder_tree_luts(prod_bits: u32, n_terms: u32) -> f64 {
    if n_terms <= 1 {
        return 0.0;
    }
    let mut total = 0.0;
    let mut terms = n_terms;
    let mut width = prod_bits;
    while terms > 1 {
        let adders = terms / 2;
        width += 1;
        total += adders as f64 * luts_per_adder(width);
        terms = adders + (terms % 2);
    }
    total
}

/// Post-implementation (Vivado `opt_design`) shrink factor for adder
/// trees: Vivado merges chains into ternary adders and packs carry logic.
/// Calibrated against Figure 6: the second conv layer's 992 additions
/// (32 output channels x 31 adds over int8+ products) synthesize to
/// ~11.9k LUTs at HLS and implement at 2645 LUTs -> factor ~0.22.
pub const VIVADO_ADDER_SHRINK: f64 = 0.22;

/// Post-implementation overhead factor on ROM LUTs: Vivado re-packs the
/// HLS-estimated `Eq.(3)` ROMs together with address decode and weight-
/// select fabric. Calibrated against Figure 6 (1024 weights: 1829 LUT at
/// HLS -> 3277 LUT as ROM after implementation: x1.6 on Eq. 3's 2048).
pub const VIVADO_ROM_FACTOR: f64 = 1.6;

/// HLS-reported multiplier LUTs relative to Eq. (3) (logic optimization
/// trims constant product bits; Figure 6 reports 1829/2048 = 0.893).
pub const HLS_MULT_FACTOR: f64 = 0.893;

/// Post-implementation LUT area of one conv layer's multiply-accumulate
/// array: `rows x cols` constant multipliers as Eq. (3) ROMs (Vivado
/// re-pack factor applied) plus one per-row adder tree reducing the
/// `cols` products (Vivado ternary-merge shrink applied). This is the
/// area a structured pruning pass reclaims (DESIGN.md S23): a pruned
/// layer is costed with its *live* row/column counts, a dense layer
/// with its full `cout x cols` — same formula, so the per-layer saving
/// in `lutmul report prune` is exactly the dropped rows' and columns'
/// share.
pub fn layer_lut_area(w_bits: u32, rows: usize, cols: usize) -> f64 {
    if rows == 0 || cols == 0 {
        return 0.0;
    }
    rows as f64 * cols as f64 * luts_per_mult(w_bits) * VIVADO_ROM_FACTOR
        + rows as f64 * adder_tree_luts(2 * w_bits, cols as u32) * VIVADO_ADDER_SHRINK
}

/// LUT6 count of one codebook ROM: `n_protos` entries of `table_bits`
/// bits sliced into 6-input LUTs (Eq. (3)'s sizing applied to the
/// Maddness accumulation table), floored at one physical LUT.
pub fn approx_rom_luts(table_bits: u32, n_protos: u32) -> f64 {
    (table_bits as f64 * n_protos as f64 / 64.0).max(1.0)
}

/// Post-implementation LUT area of one conv layer's Maddness-style
/// approximate datapath (DESIGN.md S24): per (codebook, row) one
/// accumulator-width ROM of `2^depth` prototype dot products (Vivado
/// re-pack factor applied), per codebook a `depth`-level comparator
/// tree (one LUT6 per compare of <=6-bit activation codes), and per row
/// an adder tree over `n_codebooks` terms instead of `cols` — the
/// structural saving the approximate datapath buys: the wider the
/// chunk, the fewer ROM columns and adder-tree terms per output.
pub fn approx_layer_lut_area(
    w_bits: u32,
    rows: usize,
    cols: usize,
    n_codebooks: usize,
    depth: u32,
) -> f64 {
    if rows == 0 || cols == 0 || n_codebooks == 0 {
        return 0.0;
    }
    // Table entries are chunk-wide partial dots, so they carry the same
    // accumulator width a `cols`-term exact sum needs.
    let width = accumulator_width(2 * w_bits, cols as u32);
    let roms = (rows * n_codebooks) as f64
        * approx_rom_luts(width, 1u32 << depth.min(31))
        * VIVADO_ROM_FACTOR;
    let hash = (n_codebooks * depth as usize) as f64;
    let adders =
        rows as f64 * adder_tree_luts(width, n_codebooks as u32) * VIVADO_ADDER_SHRINK;
    roms + hash + adders
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_paper_values() {
        // 4-bit: 8 * 16 / 64 = 2 LUTs per multiplication (paper headline)
        assert_eq!(luts_per_mult(4), 2.0);
        // 8-bit: 16 * 256 / 64 = 64
        assert_eq!(luts_per_mult(8), 64.0);
        // 3-bit: 6 * 8 / 64 = 0.75 -> floors at 1 physical LUT
        assert_eq!(luts_per_mult(3), 1.0);
        assert_eq!(luts_per_mult(1), 1.0);
        assert_eq!(luts_per_mult(2), 1.0);
    }

    #[test]
    fn general_mult_matches_cited_range() {
        let g = luts_per_general_mult(4);
        assert!(g >= 13.0 && g <= 28.0, "paper cites 13-28 LUTs, got {g}");
        // LUTMUL advantage: 6.5-14x fewer (paper: "6-14x more LUT6")
        let ratio = g / luts_per_mult(4);
        assert!(ratio >= 6.0 && ratio <= 14.0, "ratio {ratio}");
    }

    #[test]
    fn accumulator_widths() {
        assert_eq!(accumulator_width(8, 1), 9);
        // 288 int8 products (3x3x32 conv): 8 + ceil(log2(288)) ~ 17 bits
        assert_eq!(accumulator_width(8, 288), 17);
    }

    #[test]
    fn adder_tree_grows_with_terms() {
        let a = adder_tree_luts(8, 16);
        let b = adder_tree_luts(8, 32);
        assert!(b > a && a > 0.0);
    }

    #[test]
    fn adder_tree_single_term_free() {
        assert_eq!(adder_tree_luts(8, 1), 0.0);
        assert_eq!(adder_tree_luts(8, 0), 0.0);
    }

    #[test]
    fn layer_lut_area_scales_with_live_work() {
        let dense = layer_lut_area(4, 32, 288);
        let pruned = layer_lut_area(4, 16, 288);
        assert!(dense > 0.0);
        // halving the rows halves the whole array (ROMs and trees alike)
        assert!((pruned - dense / 2.0).abs() < 1e-9, "{pruned} vs {}", dense / 2.0);
        // dropping columns removes ROMs and shrinks every row's tree
        assert!(layer_lut_area(4, 32, 144) < dense);
        assert_eq!(layer_lut_area(4, 0, 288), 0.0);
        assert_eq!(layer_lut_area(4, 32, 0), 0.0);
    }

    #[test]
    fn approx_area_beats_exact_at_default_chunking() {
        // 32x288 4-bit layer, 72 codebooks of 4 columns, 16 prototypes:
        // the codebook ROMs + hash + shortened trees must undercut the
        // exact per-column ROM array (the S24 headline), and widening
        // the chunks must keep shrinking the area.
        let exact = layer_lut_area(4, 32, 288);
        let c4 = approx_layer_lut_area(4, 32, 288, 72, 4);
        let c8 = approx_layer_lut_area(4, 32, 288, 36, 4);
        assert!(c4 < exact, "approx {c4} vs exact {exact}");
        assert!(c8 < c4, "wider chunks must cost less: {c8} vs {c4}");
        assert_eq!(approx_layer_lut_area(4, 0, 288, 72, 4), 0.0);
        assert_eq!(approx_layer_lut_area(4, 32, 0, 0, 4), 0.0);
    }

    #[test]
    fn fig6_calibration_sanity() {
        // conv2: 32x32 1x1 conv = 1024 mults, 32 channels x 31 adds.
        let mult_hls = 1024.0 * luts_per_mult(4) * HLS_MULT_FACTOR;
        assert!((mult_hls - 1829.0).abs() < 6.0, "HLS mult LUTs {mult_hls} vs 1829");
        let rom_impl = 1024.0 * luts_per_mult(4) * VIVADO_ROM_FACTOR;
        assert!((rom_impl - 3277.0).abs() < 60.0, "impl ROM {rom_impl} vs 3277");
        let adders_impl = 32.0 * adder_tree_luts(8, 32) * VIVADO_ADDER_SHRINK;
        let err = (adders_impl - 2645.0).abs() / 2645.0;
        assert!(err < 0.2, "impl adders {adders_impl} vs 2645");
    }
}
