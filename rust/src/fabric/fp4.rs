//! FP4 / MXFP4 extension (paper section 4.4): "Our method is not only
//! limited to integer multiplication, but can also be extended to
//! customized data formats, such as FP4 and MXFP4, while DSP packing is
//! designed efficiently for integer formats."
//!
//! This module implements that extension:
//!  * an E2M1 FP4 codec (the OCP Microscaling spec's 4-bit float:
//!    1 sign, 2 exponent, 1 mantissa bit; values ±{0, .5, 1, 1.5, 2, 3,
//!    4, 6});
//!  * a LUT-embedded FP4 constant multiplier: the product of a constant
//!    FP4 weight with an FP4 activation is, like the integer case, a
//!    16-entry table — but the *output* needs more bits (products span
//!    0.25..36), so each multiplier emits a fixed-point `Q9.2` code
//!    (11 bits + sign -> 6 LUT6_2 per weight pair, vs 4 for int4);
//!  * MXFP4 blocks: 32 FP4 elements sharing one power-of-two scale
//!    (E8M0), dot products accumulating in fixed point.
//!
//! The key claim carries over: the FP4 multiplier is still a small
//! constant ROM (3 LUT6/mult amortized) — DSP packing has no good FP4
//! story at all.

use super::lut::Lut6_2;

/// All 16 E2M1 FP4 values, indexed by code. Codes 0..7 positive
/// (0, 0.5, 1, 1.5, 2, 3, 4, 6), codes 8..15 the negated values.
pub const FP4_VALUES: [f32; 16] = [
    0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0,
];

/// Decode an FP4 (E2M1) code to f32.
pub fn fp4_decode(code: u8) -> f32 {
    FP4_VALUES[(code & 0xf) as usize]
}

/// Encode an f32 to the nearest FP4 code (first/lowest code wins ties,
/// so 0.0 encodes positively and exact grid values round-trip).
pub fn fp4_encode(x: f32) -> u8 {
    let mut best = 0u8;
    let mut best_err = f32::INFINITY;
    for (i, &v) in FP4_VALUES.iter().enumerate() {
        let err = (x - v).abs();
        if err < best_err {
            best_err = err;
            best = i as u8;
        }
    }
    best
}

/// Fixed-point scale of the product table: products are multiples of
/// 0.25 (m1 x m1 granularity), so `Q.2` fraction bits are exact.
pub const FP4_PROD_FRAC_BITS: u32 = 2;

/// Exact integer code of an FP4 x FP4 product: `round(p * 4)`. The
/// product magnitude is at most 36, so the code fits in 9 integer bits;
/// with sign that is 12 output bits total.
pub fn fp4_product_code(w_code: u8, a_code: u8) -> i32 {
    let p = fp4_decode(w_code) * fp4_decode(a_code);
    (p * (1 << FP4_PROD_FRAC_BITS) as f32) as i32
}

/// Output bits of the FP4 product table (two's complement Q9.2).
pub const FP4_PROD_BITS: u32 = 12;

/// A LUT-embedded FP4 constant multiplier: two FP4 weights packed per
/// primitive group (Figure 5's WS trick), `FP4_PROD_BITS` output bits ->
/// 6 physical LUT6_2 per pair (2 bits per LUT, as in the int4 case).
#[derive(Debug, Clone)]
pub struct Fp4Multiplier {
    luts: Vec<Lut6_2>,
    pub weights: [u8; 2],
}

impl Fp4Multiplier {
    pub fn new(w0: u8, w1: u8) -> Self {
        let n_luts = (FP4_PROD_BITS / 2) as usize;
        let mut inits = vec![0u64; n_luts];
        let mask = (1u32 << FP4_PROD_BITS) - 1;
        for (ws, &w) in [w0, w1].iter().enumerate() {
            for a in 0..16u8 {
                let p = (fp4_product_code(w, a) as u32) & mask;
                for (l, init) in inits.iter_mut().enumerate() {
                    let hi_bit = FP4_PROD_BITS - 1 - 2 * l as u32;
                    let lo_bit = FP4_PROD_BITS - 2 - 2 * l as u32;
                    let addr5 = (ws as u64) * 16 + a as u64;
                    if (p >> hi_bit) & 1 == 1 {
                        *init |= 1u64 << (32 + addr5);
                    }
                    if (p >> lo_bit) & 1 == 1 {
                        *init |= 1u64 << addr5;
                    }
                }
            }
        }
        Self { luts: inits.into_iter().map(Lut6_2::new).collect(), weights: [w0, w1] }
    }

    /// Physical LUT6 consumed (6 per pair -> 3 per weight).
    pub fn lut_count(&self) -> usize {
        self.luts.len()
    }

    /// Multiply by LUT readout; returns the Q9.2 fixed-point product code.
    pub fn eval(&self, ws: bool, a_code: u8) -> i32 {
        let addr5 = ((ws as u8) << 4) | (a_code & 0xf);
        let mut p: u32 = 0;
        for (l, lut) in self.luts.iter().enumerate() {
            let (o6, o5) = lut.eval_dual(addr5);
            let hi_bit = FP4_PROD_BITS - 1 - 2 * l as u32;
            let lo_bit = FP4_PROD_BITS - 2 - 2 * l as u32;
            if o6 {
                p |= 1 << hi_bit;
            }
            if o5 {
                p |= 1 << lo_bit;
            }
        }
        let shift = 32 - FP4_PROD_BITS;
        ((p << shift) as i32) >> shift
    }

    /// Decode a product code back to f32.
    pub fn decode_product(code: i32) -> f32 {
        code as f32 / (1 << FP4_PROD_FRAC_BITS) as f32
    }
}

/// An MXFP4 block (OCP Microscaling): `BLOCK` FP4 elements sharing one
/// power-of-two scale exponent (E8M0, bias 127).
#[derive(Debug, Clone)]
pub struct MxFp4Block {
    /// Shared scale exponent, biased by 127 (value = 2^(exp - 127)).
    pub scale_exp: u8,
    pub codes: Vec<u8>,
}

pub const MXFP4_BLOCK: usize = 32;

impl MxFp4Block {
    /// Quantize a slice of f32 to one MXFP4 block (absmax scaling onto
    /// the FP4 range's max magnitude of 6).
    pub fn quantize(xs: &[f32]) -> Self {
        assert!(!xs.is_empty() && xs.len() <= MXFP4_BLOCK);
        let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        // scale = 2^e such that amax / 2^e <= 6 (largest FP4 magnitude)
        let e = if amax == 0.0 { 0i32 } else { ((amax / 6.0).log2().ceil() as i32).max(-127) };
        let scale = (e as f32).exp2();
        let codes = xs.iter().map(|&x| fp4_encode(x / scale)).collect();
        Self { scale_exp: (e + 127).clamp(0, 255) as u8, codes }
    }

    pub fn scale(&self) -> f32 {
        ((self.scale_exp as i32 - 127) as f32).exp2()
    }

    /// Dequantize the block.
    pub fn dequantize(&self) -> Vec<f32> {
        let s = self.scale();
        self.codes.iter().map(|&c| fp4_decode(c) * s).collect()
    }

    /// Exact dot product of two blocks via the LUT product codes:
    /// fixed-point accumulation, one float multiply at the end
    /// (scale_a * scale_b / 16) — the LUTMUL execution model for MXFP4.
    pub fn dot(&self, other: &MxFp4Block) -> f32 {
        assert_eq!(self.codes.len(), other.codes.len());
        let acc: i32 = self
            .codes
            .iter()
            .zip(&other.codes)
            .map(|(&w, &a)| fp4_product_code(w, a))
            .sum();
        // product codes are Q.2 (each is the exact product x4)
        acc as f32 / (1 << FP4_PROD_FRAC_BITS) as f32 * self.scale() * other.scale()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_roundtrip_exact_values() {
        for code in 0..16u8 {
            let v = fp4_decode(code);
            let back = fp4_encode(v);
            // -0.0 encodes to +0.0's code; everything else is exact
            if code == 8 {
                assert_eq!(fp4_decode(back), 0.0);
            } else {
                assert_eq!(back, code, "value {v}");
            }
        }
    }

    #[test]
    fn encode_saturates() {
        assert_eq!(fp4_decode(fp4_encode(100.0)), 6.0);
        assert_eq!(fp4_decode(fp4_encode(-100.0)), -6.0);
    }

    #[test]
    fn product_codes_are_exact() {
        // every FP4 x FP4 product is a multiple of 0.25 and <= 36
        for w in 0..16u8 {
            for a in 0..16u8 {
                let p = fp4_decode(w) * fp4_decode(a);
                let code = fp4_product_code(w, a);
                assert_eq!(code as f32 / 4.0, p, "w={w} a={a}");
                assert!(code.abs() <= 36 * 4);
            }
        }
    }

    #[test]
    fn lut_multiplier_exhaustive() {
        // LUT readout == real FP4 product for every weight pair sample
        for w0 in 0..16u8 {
            let w1 = (w0 + 7) % 16;
            let m = Fp4Multiplier::new(w0, w1);
            assert_eq!(m.lut_count(), 6);
            for a in 0..16u8 {
                assert_eq!(
                    Fp4Multiplier::decode_product(m.eval(false, a)),
                    fp4_decode(w0) * fp4_decode(a),
                    "w0={w0} a={a}"
                );
                assert_eq!(
                    Fp4Multiplier::decode_product(m.eval(true, a)),
                    fp4_decode(w1) * fp4_decode(a),
                    "w1={w1} a={a}"
                );
            }
        }
    }

    #[test]
    fn fp4_still_beats_general_float_mult() {
        // 3 LUT6 per FP4 mult (6 per pair); a soft-logic FP4 multiplier
        // via int mantissa mult + exponent add is ~10+, an fp16 one ~100s.
        let m = Fp4Multiplier::new(3, 9);
        assert!(m.lut_count() as f64 / 2.0 <= 3.0);
    }

    #[test]
    fn mxfp4_quantize_dequantize_error_bound() {
        let xs: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.37).collect();
        let b = MxFp4Block::quantize(&xs);
        let back = b.dequantize();
        let s = b.scale();
        for (x, y) in xs.iter().zip(&back) {
            // FP4 relative grid at scale s: max abs error 0.25 * s near 0,
            // relative ~1/8 at the top of a binade; bound by 1*s overall
            assert!((x - y).abs() <= s, "{x} -> {y} (scale {s})");
        }
    }

    #[test]
    fn mxfp4_dot_matches_float_of_dequantized() {
        let a: Vec<f32> = (0..32).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.21).collect();
        let w: Vec<f32> = (0..32).map(|i| ((i * 5 % 11) as f32 - 5.0) * 0.33).collect();
        let ba = MxFp4Block::quantize(&a);
        let bw = MxFp4Block::quantize(&w);
        let want: f32 = ba
            .dequantize()
            .iter()
            .zip(bw.dequantize().iter())
            .map(|(x, y)| x * y)
            .sum();
        let got = ba.dot(&bw);
        // fixed-point accumulation is exact; only the final two float
        // multiplies differ in rounding order
        assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "{got} vs {want}");
    }

    #[test]
    fn mxfp4_zero_block() {
        let b = MxFp4Block::quantize(&[0.0; 32]);
        assert!(b.dequantize().iter().all(|&v| v == 0.0));
        assert_eq!(b.dot(&b), 0.0);
    }
}
