//! Accuracy harness (DESIGN.md S24 / EXPERIMENTS.md E17): score every
//! datapath of one network on a labeled test set and chart the
//! accuracy–speed–area Pareto front.
//!
//! The approximate datapath (`graph::approx`) deliberately trades
//! accuracy for LUT area and accumulation count; this module is the
//! other half of that trade — without measured top-1/top-5 next to the
//! throughput and `lut6` columns, "faster and smaller" is
//! unfalsifiable. `lutmul eval` drives it from the CLI: the trained
//! artifact test set when built, a **labeled synthetic set** otherwise
//! ([`Network::synthetic_labeled`] — seeded images labeled by the exact
//! arithmetic datapath's own argmax, so the exact rows score 100% by
//! construction and every other datapath's score reads directly as
//! agreement with the exact model).
//!
//! The Pareto table is emitted with the same JSON schema as `lutmul
//! bench --json` (`{backend, datapath, images_per_s, ns_per_image,
//! ...}` rows under `"rows"`), so `scripts/bench_regress.py` compares
//! eval snapshots with the same keying it uses for bench snapshots
//! (approx rows carry `"approx": true`, pruned rows a `"sparsity"`
//! field).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::argmax;
use crate::engine::{ExecutorBackend, InferenceBackend};
use crate::graph::approx::ApproxSpec;
use crate::graph::executor::{Executor, Tensor};
use crate::graph::network::Network;
use crate::graph::plan::{Datapath, NetworkPlan};
use crate::graph::prune::PruneSpec;

/// Top-1 / top-5 accuracy of one scored batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalScore {
    /// Images scored.
    pub n: usize,
    /// Fraction whose argmax equals the label.
    pub top1: f64,
    /// Fraction whose label ranks in the 5 largest logits.
    pub top5: f64,
}

/// Deterministic rank of `label` among the logits: the number of
/// classes strictly greater, with index order breaking exact ties (so a
/// flat logit vector still yields one well-defined rank).
fn label_rank(logits: &[f32], label: usize) -> usize {
    let lv = logits[label];
    logits
        .iter()
        .enumerate()
        .filter(|&(j, &v)| v > lv || (v == lv && j < label))
        .count()
}

/// Score per-image logits against labels.
pub fn score(logits: &[Vec<f32>], labels: &[u8]) -> EvalScore {
    let n = logits.len().min(labels.len());
    if n == 0 {
        return EvalScore { n: 0, top1: 0.0, top5: 0.0 };
    }
    let mut hit1 = 0usize;
    let mut hit5 = 0usize;
    for (l, &y) in logits.iter().zip(labels).take(n) {
        let y = y as usize;
        if argmax(l) == y {
            hit1 += 1;
        }
        if y < l.len() && label_rank(l, y) < 5 {
            hit5 += 1;
        }
    }
    EvalScore { n, top1: hit1 as f64 / n as f64, top5: hit5 as f64 / n as f64 }
}

impl Network {
    /// A labeled synthetic test set: `n` seeded uniform code images,
    /// each labeled by the **exact arithmetic datapath's argmax** on
    /// this network. Deterministic in (`self`, `n`, `seed`). Because
    /// the labels are the exact model's own answers, any exact compile
    /// of this network scores top-1 = 1.0 on the set by construction —
    /// the accuracy axis of `lutmul eval` then measures how often an
    /// approximate/pruned datapath *agrees with the exact model*, which
    /// is the quantity the Maddness trade-off spends.
    pub fn synthetic_labeled(&self, n: usize, seed: u64) -> (Vec<Vec<i32>>, Vec<u8>) {
        let io = self.io();
        let px = io.image_size * io.image_size * io.in_ch;
        let amax = (1i32 << self.meta.a_bits.clamp(1, 8)) - 1;
        let mut rng = crate::util::prop::Rng::new(seed ^ 0x1abe_1ed5_e7da_7a5e);
        let images: Vec<Vec<i32>> = (0..n.max(1)).map(|_| rng.vec_i32(px, 0, amax)).collect();
        let ex = Executor::from_plan(NetworkPlan::compile(self, Datapath::Arithmetic));
        let labels = images
            .iter()
            .map(|img| {
                let t = Tensor::from_hwc(io.image_size, io.image_size, io.in_ch, img.clone());
                argmax(&ex.execute(&t)) as u8
            })
            .collect();
        (images, labels)
    }
}

/// One datapath's point on the accuracy–speed–area front.
#[derive(Debug, Clone)]
pub struct ParetoRow {
    /// Backend label (`executor/lut-exact`, `executor/lut-approx`, ...).
    pub backend: String,
    /// Datapath label, same vocabulary as `lutmul bench --json`.
    pub datapath: String,
    pub images_per_s: f64,
    pub score: EvalScore,
    /// Plan-wide LUT6 estimate (`NetworkPlan::lut_count`) — the area
    /// axis of the front.
    pub lut6: usize,
    /// Approximate (Maddness) datapath row.
    pub approx: bool,
    /// Channel sparsity of a pruned row (0.0 on dense rows).
    pub sparsity: f64,
}

/// Which rows [`pareto`] builds.
#[derive(Debug, Clone)]
pub struct ParetoConfig {
    /// Structured channel sparsity of the pruned row; `0.0` skips it.
    pub sparsity: f64,
    /// Configuration of the approximate row.
    pub spec: ApproxSpec,
    /// Full front (`--pareto`): adds the mac-major exact witness and
    /// the saturated-approx anchor next to the default rows.
    pub full: bool,
    /// Executor thread fan-out per row.
    pub threads: usize,
}

impl Default for ParetoConfig {
    fn default() -> Self {
        Self { sparsity: 0.0, spec: ApproxSpec::default(), full: false, threads: 1 }
    }
}

/// Time one compiled plan over the batch and score it.
fn run_row(
    plan: NetworkPlan,
    backend: &str,
    datapath: &str,
    approx: bool,
    sparsity: f64,
    images: &[Vec<i32>],
    labels: &[u8],
    threads: usize,
) -> Result<ParetoRow> {
    let lut6 = plan.lut_count();
    let mut b = ExecutorBackend::new(Arc::new(plan), threads);
    let t0 = Instant::now();
    let out = b.infer_batch(images)?;
    let images_per_s = images.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    Ok(ParetoRow {
        backend: backend.to_string(),
        datapath: datapath.to_string(),
        images_per_s,
        score: score(&out.logits, labels),
        lut6,
        approx,
        sparsity,
    })
}

/// Build the accuracy–speed–area front of one network on one labeled
/// batch: the exact act-major LUT compile, the approximate compile, and
/// (per [`ParetoConfig`]) the mac-major witness, the pruned compile and
/// the saturated-approx anchor. Every row runs through the same
/// batch-major executor backend, so the throughput column is
/// apples-to-apples.
pub fn pareto(
    net: &Network,
    images: &[Vec<i32>],
    labels: &[u8],
    cfg: &ParetoConfig,
) -> Result<Vec<ParetoRow>> {
    anyhow::ensure!(!images.is_empty(), "eval needs at least one image");
    anyhow::ensure!(
        images.len() == labels.len(),
        "{} images but {} labels",
        images.len(),
        labels.len()
    );
    anyhow::ensure!(
        (0.0..1.0).contains(&cfg.sparsity),
        "sparsity must be in [0, 1), got {}",
        cfg.sparsity
    );
    let t = cfg.threads.max(1);
    let mut rows = Vec::new();
    rows.push(run_row(
        NetworkPlan::compile(net, Datapath::LutFabric),
        "executor/lut-exact",
        "lut-fabric",
        false,
        0.0,
        images,
        labels,
        t,
    )?);
    if cfg.full {
        rows.push(run_row(
            NetworkPlan::compile_mac_major(net, Datapath::LutFabric),
            "executor/lut-mac-major",
            "lut-fabric/mac-major",
            false,
            0.0,
            images,
            labels,
            t,
        )?);
    }
    if cfg.sparsity > 0.0 {
        let spec = PruneSpec::channels(cfg.sparsity);
        rows.push(run_row(
            NetworkPlan::compile_pruned(net, Datapath::LutFabric, &spec),
            "executor/lut-sparse",
            "lut-fabric",
            false,
            cfg.sparsity,
            images,
            labels,
            t,
        )?);
    }
    rows.push(run_row(
        NetworkPlan::compile_approx(net, Datapath::LutFabric, &cfg.spec),
        "executor/lut-approx",
        "lut-fabric/approx",
        true,
        0.0,
        images,
        labels,
        t,
    )?);
    if cfg.full && cfg.spec != ApproxSpec::saturated() {
        rows.push(run_row(
            NetworkPlan::compile_approx(net, Datapath::LutFabric, &ApproxSpec::saturated()),
            "executor/lut-approx-sat",
            "lut-fabric/approx",
            true,
            0.0,
            images,
            labels,
            t,
        )?);
    }
    Ok(rows)
}

/// Human-readable front, one line per row.
pub fn table(rows: &[ParetoRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "  {:<24} {:>9}  {:>6}  {:>6}  {:>9}\n",
        "datapath", "img/s", "top-1", "top-5", "LUT6"
    ));
    for r in rows {
        let mut tag = String::new();
        if r.approx {
            tag.push_str(" [approx]");
        }
        if r.sparsity > 0.0 {
            tag.push_str(&format!(" [sparsity {:.2}]", r.sparsity));
        }
        s.push_str(&format!(
            "  {:<24} {:>9.0}  {:>5.1}%  {:>5.1}%  {:>9}{tag}\n",
            r.backend,
            r.images_per_s,
            100.0 * r.score.top1,
            100.0 * r.score.top5,
            r.lut6,
        ));
    }
    s
}

/// Machine-readable front: the same document shape as `lutmul bench
/// --json` (top-level `bench`/`source`/`n_images`/`rows`), so
/// `scripts/bench_regress.py` keys eval snapshots exactly like bench
/// snapshots. Dense exact rows omit `sparsity` and `approx`, matching
/// the bench emitter's omit-when-default convention.
pub fn json(rows: &[ParetoRow], invocation: &str, source: &str, n: usize) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            let mut extra = String::new();
            if r.sparsity > 0.0 {
                extra.push_str(&format!(", \"sparsity\": {:.2}", r.sparsity));
            }
            if r.approx {
                extra.push_str(", \"approx\": true");
            }
            format!(
                "    {{\"backend\": {:?}, \"datapath\": {:?}, \"images_per_s\": {:.1}, \
                 \"ns_per_image\": {:.0}, \"top1\": {:.4}, \"top5\": {:.4}, \
                 \"lut6\": {}{extra}}}",
                r.backend,
                r.datapath,
                r.images_per_s,
                1e9 / r.images_per_s.max(1e-9),
                r.score.top1,
                r.score.top5,
                r.lut6,
            )
        })
        .collect();
    format!(
        "{{\n  \"bench\": {invocation:?},\n  \"source\": {source:?},\n  \"n_images\": {n},\n  \
         \"rows\": [\n{}\n  ]\n}}",
        body.join(",\n")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::mobilenet_v2_small;

    #[test]
    fn score_counts_top1_and_top5() {
        let logits = vec![
            vec![0.1, 0.9, 0.0, 0.0, 0.0, 0.0], // label 1: top-1 hit
            vec![0.5, 0.4, 0.3, 0.2, 0.1, 0.0], // label 4: top-5 only
            vec![0.5, 0.4, 0.3, 0.2, 0.1, 0.0], // label 5: miss
        ];
        let s = score(&logits, &[1, 4, 5]);
        assert_eq!(s.n, 3);
        assert!((s.top1 - 1.0 / 3.0).abs() < 1e-12);
        assert!((s.top5 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn label_rank_breaks_ties_by_index() {
        // flat logits: rank equals the label index
        let flat = vec![1.0f32; 8];
        assert_eq!(label_rank(&flat, 0), 0);
        assert_eq!(label_rank(&flat, 7), 7);
    }

    #[test]
    fn synthetic_labels_are_deterministic_and_exact_scores_full() {
        let net = Network::synthetic(&mobilenet_v2_small(), 0x5EED);
        let (ia, la) = net.synthetic_labeled(6, 9);
        let (ib, lb) = net.synthetic_labeled(6, 9);
        assert_eq!(ia, ib);
        assert_eq!(la, lb);
        // the exact LUT datapath reproduces the labeling datapath
        let rows = pareto(&net, &ia, &la, &ParetoConfig::default()).unwrap();
        let exact = rows.iter().find(|r| r.backend == "executor/lut-exact").unwrap();
        assert_eq!(exact.score.top1, 1.0);
        assert_eq!(exact.score.top5, 1.0);
    }

    #[test]
    fn json_rows_tag_approx_and_sparsity() {
        let mk = |backend: &str, approx: bool, sp: f64| ParetoRow {
            backend: backend.into(),
            datapath: "lut-fabric".into(),
            images_per_s: 100.0,
            score: EvalScore { n: 4, top1: 0.75, top5: 1.0 },
            lut6: 42,
            approx,
            sparsity: sp,
        };
        let doc = json(
            &[mk("executor/lut-exact", false, 0.0), mk("executor/lut-approx", true, 0.0)],
            "lutmul eval --pareto",
            "synthetic",
            4,
        );
        assert!(doc.contains("\"rows\""));
        assert!(doc.contains("\"approx\": true"));
        assert!(!doc.contains("\"sparsity\""));
        assert!(doc.contains("\"top1\": 0.7500"));
        let sparse = json(&[mk("executor/lut-sparse", false, 0.5)], "x", "s", 1);
        assert!(sparse.contains("\"sparsity\": 0.50"));
    }
}
