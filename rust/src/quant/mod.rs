//! Quantization library (DESIGN.md S4): Eq. (4)/(5) affine quantizers and
//! the FINN-style multi-threshold activation unit produced by streamlining.
//!
//! Mirrors `python/compile/quantize.py`; the integer semantics here must
//! match the JAX golden model bit-for-bit.


/// Signed two's-complement quantization range, e.g. 4 bits -> [-8, 7].
pub fn weight_qrange(bits: u32) -> (i32, i32) {
    (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)
}

/// Unsigned activation range, e.g. 4 bits -> [0, 15].
pub fn act_qrange(bits: u32) -> (i32, i32) {
    (0, (1 << bits) - 1)
}

/// Eq. (4): `quantize(x) = clamp(round(x/s + z), ymin, ymax)`.
pub fn quantize(x: f64, scale: f64, zero_point: i32, ymin: i32, ymax: i32) -> i32 {
    let q = (x / scale).round() as i64 + zero_point as i64;
    q.clamp(ymin as i64, ymax as i64) as i32
}

/// Eq. (5): `dequantize(y) = s * (y - z)`.
pub fn dequantize(y: i32, scale: f64, zero_point: i32) -> f64 {
    scale * (y - zero_point) as f64
}

/// A per-channel multi-threshold activation unit.
///
/// `apply(acc, ch)` returns the output code: the number of thresholds the
/// integer accumulator crosses (`>=` for positive batch-norm gain, `<=`
/// for negative, constant for zero gain). This is the streamlined form of
/// `clamp(round(BN(s_w*s_in*acc)/s_out))` — see
/// `python/compile/quantize.py::streamline_thresholds`.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiThreshold {
    /// `[channels][levels]` ascending integer thresholds.
    pub thresholds: Vec<Vec<i32>>,
    /// +1 (count `acc >= t`), -1 (count `acc <= t`), 0 (constant channel).
    pub signs: Vec<i32>,
    /// Constant output code for channels with `signs == 0`.
    pub consts: Vec<i32>,
}

impl MultiThreshold {
    pub fn channels(&self) -> usize {
        self.thresholds.len()
    }

    pub fn levels(&self) -> usize {
        self.thresholds.first().map_or(0, Vec::len)
    }

    /// Output code for an integer accumulator on channel `ch`.
    #[inline]
    pub fn apply(&self, acc: i32, ch: usize) -> i32 {
        match self.signs[ch] {
            s if s > 0 => self.thresholds[ch].iter().filter(|&&t| acc >= t).count() as i32,
            s if s < 0 => self.thresholds[ch].iter().filter(|&&t| acc <= t).count() as i32,
            _ => self.consts[ch],
        }
    }

    /// Validate internal consistency (shapes, codes in range).
    pub fn validate(&self) -> Result<(), String> {
        let c = self.thresholds.len();
        if self.signs.len() != c || self.consts.len() != c {
            return Err(format!(
                "shape mismatch: {} thresholds vs {} signs vs {} consts",
                c,
                self.signs.len(),
                self.consts.len()
            ));
        }
        let l = self.levels();
        for (ch, t) in self.thresholds.iter().enumerate() {
            if t.len() != l {
                return Err(format!("channel {ch}: ragged thresholds"));
            }
            if self.signs[ch] == 0 && !(0..=l as i32).contains(&self.consts[ch]) {
                return Err(format!("channel {ch}: const code out of range"));
            }
        }
        Ok(())
    }
}

/// Saturating residual-join add: `clamp(a + b, 0, 2^bits - 1)` on codes.
/// Exact because both branches share one activation scale (DESIGN.md).
#[inline]
pub fn saturating_res_add(a: i32, b: i32, bits: u32) -> i32 {
    (a + b).clamp(0, (1 << bits) - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qranges() {
        assert_eq!(weight_qrange(4), (-8, 7));
        assert_eq!(weight_qrange(8), (-128, 127));
        assert_eq!(act_qrange(4), (0, 15));
        assert_eq!(act_qrange(1), (0, 1));
    }

    #[test]
    fn quantize_eq4() {
        // paper Eq. 4 with s=0.5, z=0, 4-bit unsigned
        assert_eq!(quantize(3.2, 0.5, 0, 0, 15), 6);
        assert_eq!(quantize(-1.0, 0.5, 0, 0, 15), 0); // clamps
        assert_eq!(quantize(100.0, 0.5, 0, 0, 15), 15);
    }

    #[test]
    fn dequantize_eq5_roundtrip() {
        let s = 0.13;
        for code in 0..16 {
            let x = dequantize(code, s, 0);
            assert_eq!(quantize(x, s, 0, 0, 15), code);
        }
    }

    #[test]
    fn multithreshold_positive() {
        let mt = MultiThreshold {
            thresholds: vec![vec![0, 2, 50]],
            signs: vec![1],
            consts: vec![0],
        };
        assert_eq!(mt.apply(-5, 0), 0);
        assert_eq!(mt.apply(0, 0), 1);
        assert_eq!(mt.apply(3, 0), 2);
        assert_eq!(mt.apply(100, 0), 3);
    }

    #[test]
    fn multithreshold_negative() {
        let mt = MultiThreshold {
            thresholds: vec![vec![-1, 1, 50]],
            signs: vec![-1],
            consts: vec![0],
        };
        assert_eq!(mt.apply(-5, 0), 3);
        assert_eq!(mt.apply(0, 0), 2);
        assert_eq!(mt.apply(3, 0), 1);
        assert_eq!(mt.apply(100, 0), 0);
    }

    #[test]
    fn multithreshold_const() {
        let mt = MultiThreshold {
            thresholds: vec![vec![0; 15]],
            signs: vec![0],
            consts: vec![7],
        };
        assert_eq!(mt.apply(-1000, 0), 7);
        assert_eq!(mt.apply(1000, 0), 7);
    }

    #[test]
    fn validate_catches_ragged() {
        let mt = MultiThreshold {
            thresholds: vec![vec![1, 2], vec![1]],
            signs: vec![1, 1],
            consts: vec![0, 0],
        };
        assert!(mt.validate().is_err());
    }

    #[test]
    fn res_add_saturates() {
        assert_eq!(saturating_res_add(10, 10, 4), 15);
        assert_eq!(saturating_res_add(3, 4, 4), 7);
        assert_eq!(saturating_res_add(0, 0, 4), 0);
        assert_eq!(saturating_res_add(1, 1, 1), 1);
    }
}
