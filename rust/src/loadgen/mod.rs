//! Open-loop, bursty, multi-tenant load generator for the serving tier
//! (DESIGN.md S21, EXPERIMENTS.md E14).
//!
//! Each tenant is one TCP connection speaking the binary protocol
//! ([`serve::proto`](crate::serve::proto)) with a writer thread that
//! sends on a precomputed *open-loop* arrival schedule — arrivals do
//! not wait for responses, so an overloaded server sees real queue
//! pressure instead of the closed-loop self-throttling that hides tail
//! latency — and a reader thread that matches responses against the
//! send log. The server guarantees in-order responses per connection,
//! so any id mismatch is a reorder/cross-wire violation and is counted,
//! not ignored.
//!
//! Traffic is bursty by construction: inside every `burst_every` cycle
//! the first `burst_len` runs at `burst_mult ×` the steady per-tenant
//! rate (multi-tenant bursts align, which is the worst case for the
//! batching window). Inter-arrival gaps are exponential via a seeded
//! [`Rng`], so a run is reproducible from its config.
//!
//! All latencies are *client-observed* (send to response on the
//! socket), which is the number a deployment actually experiences —
//! the coordinator's queue-wait/compute split tells the rest of the
//! story server-side.
//!
//! Connections are *retried*, not fatal: a refused or reset connect
//! backs off exponentially with seeded jitter (and a write failure
//! mid-phase reconnects the same way), with every attempt counted in
//! the phase table's `retry` column — so a fleet draining a killed
//! worker or a briefly-unreachable server shows up as retries and
//! latency, never as an aborted phase (DESIGN.md S25). `class_mix`
//! splits the offered traffic between the fleet's latency and
//! throughput pools per request.

use std::collections::VecDeque;
use std::io::BufWriter;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::RequestClass;
use crate::serve::proto::{self, RequestFrame, Status};
use crate::util::prop::Rng;

/// Shape of one load phase.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Concurrent open-loop clients, one connection each.
    pub tenants: usize,
    /// Aggregate steady-state offered rate across all tenants
    /// (requests/s).
    pub rate_rps: f64,
    /// Burst-window rate multiplier (1.0 = flat traffic).
    pub burst_mult: f64,
    /// Burst cycle period.
    pub burst_every: Duration,
    /// Burst window length at the start of each cycle.
    pub burst_len: Duration,
    /// How long to offer load.
    pub duration: Duration,
    /// Per-request relative deadline carried on the wire; `None` sends 0
    /// (no deadline).
    pub deadline: Option<Duration>,
    /// Fraction of requests sent as [`RequestClass::Throughput`]
    /// (0.0 = all latency-class, the single-pool default).
    pub class_mix: f64,
    /// Seed for arrival gaps and image codes.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            tenants: 4,
            rate_rps: 400.0,
            burst_mult: 4.0,
            burst_every: Duration::from_millis(200),
            burst_len: Duration::from_millis(50),
            duration: Duration::from_millis(1000),
            deadline: None,
            class_mix: 0.0,
            seed: 0x10AD,
        }
    }
}

/// Client-observed outcome of one load phase.
#[derive(Debug, Default, Clone)]
pub struct LoadReport {
    /// Requests put on the wire.
    pub offered: u64,
    pub ok: u64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
    pub failed: u64,
    pub malformed: u64,
    /// Responses whose id did not match the oldest in-flight request on
    /// that connection — must be 0 (the server promises per-connection
    /// ordering).
    pub order_violations: u64,
    /// Requests that got no response before the connection closed.
    pub lost: u64,
    /// Connect attempts that had to be retried (initial connect and
    /// mid-phase reconnects, exponential backoff + jitter each).
    pub retries: u64,
    /// `Ok` responses per request class, indexed by
    /// [`RequestClass::index`].
    pub class_ok: [u64; 2],
    pub elapsed: Duration,
    /// Send-to-response latency of every `Ok` reply, microseconds.
    pub latencies_us: Vec<u64>,
}

impl LoadReport {
    /// Every offered request resolved to exactly one outcome.
    pub fn accounted(&self) -> bool {
        self.ok
            + self.rejected
            + self.deadline_exceeded
            + self.failed
            + self.malformed
            + self.lost
            == self.offered
    }

    /// Completed (`Ok`) requests per second of wall clock.
    pub fn goodput_rps(&self) -> f64 {
        self.ok as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    pub fn latency_p50_us(&self) -> u64 {
        percentile_us(&self.latencies_us, 50.0)
    }

    pub fn latency_p99_us(&self) -> u64 {
        percentile_us(&self.latencies_us, 99.0)
    }

    pub fn latency_max_us(&self) -> u64 {
        self.latencies_us.iter().copied().max().unwrap_or(0)
    }

    fn merge(&mut self, other: LoadReport) {
        self.offered += other.offered;
        self.ok += other.ok;
        self.rejected += other.rejected;
        self.deadline_exceeded += other.deadline_exceeded;
        self.failed += other.failed;
        self.malformed += other.malformed;
        self.order_violations += other.order_violations;
        self.lost += other.lost;
        self.retries += other.retries;
        for (a, b) in self.class_ok.iter_mut().zip(other.class_ok) {
            *a += b;
        }
        self.elapsed = self.elapsed.max(other.elapsed);
        self.latencies_us.extend(other.latencies_us);
    }
}

/// Nearest-rank percentile over an unsorted sample set.
pub fn percentile_us(samples: &[u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Throughput / tail-latency table over named phases, one row each.
pub fn table(phases: &[(&str, &LoadReport)]) -> String {
    let mut out = String::from(
        "phase      offered      ok     rej    shed    fail    lost   retry |     ok/s   p50(us)   p99(us)   max(us)\n",
    );
    for (name, r) in phases {
        out.push_str(&format!(
            "{name:<10} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} | {:>8.1} {:>9} {:>9} {:>9}\n",
            r.offered,
            r.ok,
            r.rejected,
            r.deadline_exceeded,
            r.failed + r.malformed,
            r.lost,
            r.retries,
            r.goodput_rps(),
            r.latency_p50_us(),
            r.latency_p99_us(),
            r.latency_max_us(),
        ));
    }
    out
}

/// Offer one phase of load against a running server and collect the
/// client-observed report. Blocks for roughly `cfg.duration` plus
/// response drain.
pub fn run(addr: SocketAddr, image_px: usize, cfg: &LoadgenConfig) -> Result<LoadReport> {
    anyhow::ensure!(cfg.tenants >= 1, "loadgen needs at least one tenant");
    anyhow::ensure!(cfg.rate_rps > 0.0, "loadgen needs a positive rate");
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(cfg.tenants);
    for tenant in 0..cfg.tenants {
        let cfg = cfg.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("loadgen-t{tenant}"))
                .spawn(move || tenant_run(addr, image_px, tenant, &cfg))
                .context("spawning loadgen tenant")?,
        );
    }
    let mut total = LoadReport::default();
    for h in handles {
        match h.join() {
            Ok(Ok(r)) => total.merge(r),
            Ok(Err(e)) => return Err(e),
            Err(_) => anyhow::bail!("loadgen tenant panicked"),
        }
    }
    total.elapsed = t0.elapsed();
    Ok(total)
}

/// FIFO send log shared between a connection's writer and reader:
/// `(id, send instant, class)` per in-flight request.
type Inflight = Arc<Mutex<VecDeque<(u64, Instant, RequestClass)>>>;

/// One live connection: the buffered writer half, the shared send log,
/// and the reader thread matching responses against it.
struct Conn {
    stream: TcpStream,
    w: BufWriter<TcpStream>,
    inflight: Inflight,
    reader: std::thread::JoinHandle<LoadReport>,
}

/// Connect with exponential backoff + seeded jitter on refusal/reset.
/// Every extra attempt counts into `retries`; only exhausting the
/// budget surfaces the error.
fn connect_with_retry(
    addr: SocketAddr,
    tenant: usize,
    rng: &mut Rng,
    retries: &mut u64,
) -> Result<TcpStream> {
    const ATTEMPTS: u32 = 6;
    let mut delay = Duration::from_millis(10);
    let mut attempt = 0;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                attempt += 1;
                if attempt >= ATTEMPTS {
                    return Err(e).with_context(|| {
                        format!(
                            "loadgen tenant {tenant} connecting to {addr} \
                             ({ATTEMPTS} attempts, backoff exhausted)"
                        )
                    });
                }
                *retries += 1;
                // full backoff plus up to 50% seeded jitter, so aligned
                // tenants don't re-stampede a recovering server
                std::thread::sleep(delay + delay.mul_f64(rng.f64() * 0.5));
                delay = (delay * 2).min(Duration::from_millis(640));
            }
        }
    }
}

/// Open one connection (with retry) and start its reader.
fn open_conn(
    addr: SocketAddr,
    tenant: usize,
    rng: &mut Rng,
    retries: &mut u64,
) -> Result<Conn> {
    let stream = connect_with_retry(addr, tenant, rng, retries)?;
    stream.set_nodelay(true).ok();
    let reader_stream = stream.try_clone().context("cloning loadgen stream")?;
    let writer_stream = stream.try_clone().context("cloning loadgen stream")?;
    let inflight: Inflight = Arc::new(Mutex::new(VecDeque::new()));
    let reader = {
        let inflight = inflight.clone();
        std::thread::Builder::new()
            .name(format!("loadgen-t{tenant}-rx"))
            .spawn(move || read_responses(reader_stream, &inflight))
            .context("spawning loadgen reader")?
    };
    Ok(Conn { stream, w: BufWriter::new(writer_stream), inflight, reader })
}

/// Finish one connection: half-close the write side so the server
/// drains and answers what was sent, join the reader, merge its
/// classifications, and count whatever never got a response as lost.
fn close_conn(conn: Conn, report: &mut LoadReport) -> Result<()> {
    drop(conn.w); // flush what buffers; a dead socket just drops it
    let _ = conn.stream.shutdown(Shutdown::Write);
    match conn.reader.join() {
        Ok(r) => report.merge(r),
        Err(_) => anyhow::bail!("loadgen reader panicked"),
    }
    report.lost += conn.inflight.lock().unwrap_or_else(|e| e.into_inner()).len() as u64;
    Ok(())
}

/// One tenant: paced writer on this thread, response reader on a helper
/// thread per connection, reconnecting (with backoff) if the connection
/// dies mid-phase.
fn tenant_run(
    addr: SocketAddr,
    image_px: usize,
    tenant: usize,
    cfg: &LoadgenConfig,
) -> Result<LoadReport> {
    let mut rng = Rng::new(cfg.seed ^ (tenant as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let mut report = LoadReport::default();
    let mut retries = 0u64;
    let mut conn = open_conn(addr, tenant, &mut rng, &mut retries)?;

    // open-loop writer: arrivals follow the schedule, never the server
    let per_tenant_rps = cfg.rate_rps / cfg.tenants as f64;
    let deadline_us: u32 = cfg
        .deadline
        .map(|d| d.as_micros().min(u32::MAX as u128) as u32)
        .unwrap_or(0);
    let start = Instant::now();
    let mut next_at = Duration::ZERO;
    let mut offered = 0u64;
    while next_at < cfg.duration {
        let now = start.elapsed();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        let id = ((tenant as u64) << 48) | offered;
        let class = if rng.f64() < cfg.class_mix {
            RequestClass::Throughput
        } else {
            RequestClass::Latency
        };
        let codes: Vec<u8> = (0..image_px).map(|_| rng.below(16) as u8).collect();
        let frame = proto::encode_request(&RequestFrame { id, deadline_us, class, codes });
        {
            // log before writing so a fast response can never race ahead
            // of its own send record
            let mut q = conn.inflight.lock().unwrap_or_else(|e| e.into_inner());
            q.push_back((id, Instant::now(), class));
        }
        if proto::write_frame(&mut conn.w, &frame).is_err() || conn.w.flush().is_err() {
            // connection died mid-phase (server restart, worker drain):
            // this request was never sent — retract its log entry,
            // settle the old connection (unanswered sends count as
            // lost), and reconnect with backoff instead of aborting
            conn.inflight.lock().unwrap_or_else(|e| e.into_inner()).pop_back();
            close_conn(conn, &mut report)?;
            match open_conn(addr, tenant, &mut rng, &mut retries) {
                Ok(c) => {
                    conn = c;
                    // same id resends on the fresh connection next pass
                    continue;
                }
                Err(_) => {
                    // backoff exhausted mid-phase: end the phase with
                    // what resolved instead of failing the whole run
                    report.offered = offered;
                    report.retries = retries;
                    report.elapsed = start.elapsed();
                    return Ok(report);
                }
            }
        }
        offered += 1;
        // burst windows multiply the rate; gaps are exponential so the
        // schedule has realistic clumping on top of the bursts
        let in_burst = is_burst(next_at, cfg);
        let rate = per_tenant_rps * if in_burst { cfg.burst_mult.max(1.0) } else { 1.0 };
        let u = rng.f64().clamp(1e-12, 1.0 - 1e-12);
        let gap_s = -(1.0 - u).ln() / rate.max(1e-9);
        next_at += Duration::from_secs_f64(gap_s.min(5.0));
    }
    close_conn(conn, &mut report)?;
    report.offered = offered;
    report.retries = retries;
    report.elapsed = start.elapsed();
    Ok(report)
}

/// Is `t` (offset into the phase) inside a burst window?
fn is_burst(t: Duration, cfg: &LoadgenConfig) -> bool {
    if cfg.burst_mult <= 1.0 || cfg.burst_every.is_zero() {
        return false;
    }
    let cycle = t.as_nanos() % cfg.burst_every.as_nanos().max(1);
    cycle < cfg.burst_len.as_nanos()
}

/// Reader half: match every response against the FIFO send log and
/// classify it. Returns a partial report (offered/lost/elapsed are
/// filled in by the writer side).
fn read_responses(
    stream: TcpStream,
    inflight: &Mutex<VecDeque<(u64, Instant, RequestClass)>>,
) -> LoadReport {
    let mut report = LoadReport::default();
    let mut r = std::io::BufReader::new(stream);
    loop {
        let payload = match proto::read_frame(&mut r, None) {
            Ok(Some(p)) => p,
            Ok(None) | Err(_) => break, // clean EOF or torn connection
        };
        let resp = match proto::decode_response(&payload) {
            Ok(resp) => resp,
            Err(_) => {
                report.malformed += 1;
                continue;
            }
        };
        let front = inflight.lock().unwrap_or_else(|e| e.into_inner()).pop_front();
        let sent = match front {
            Some((id, at, class)) if id == resp.id => Some((at, class)),
            Some(_) | None => {
                report.order_violations += 1;
                None
            }
        };
        match resp.status {
            Status::Ok => {
                report.ok += 1;
                if let Some((at, class)) = sent {
                    report.latencies_us.push(at.elapsed().as_micros() as u64);
                    report.class_ok[class.index()] += 1;
                }
            }
            Status::Rejected => report.rejected += 1,
            Status::DeadlineExceeded => report.deadline_exceeded += 1,
            Status::Malformed => report.malformed += 1,
            Status::Failed | Status::RetriesExhausted => report.failed += 1,
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&xs, 50.0), 50);
        assert_eq!(percentile_us(&xs, 99.0), 99);
        assert_eq!(percentile_us(&xs, 100.0), 100);
        assert_eq!(percentile_us(&[7], 99.0), 7);
        assert_eq!(percentile_us(&[], 99.0), 0);
    }

    #[test]
    fn burst_windows() {
        let cfg = LoadgenConfig {
            burst_every: Duration::from_millis(100),
            burst_len: Duration::from_millis(25),
            burst_mult: 4.0,
            ..Default::default()
        };
        assert!(is_burst(Duration::from_millis(10), &cfg));
        assert!(!is_burst(Duration::from_millis(60), &cfg));
        assert!(is_burst(Duration::from_millis(110), &cfg));
        let flat = LoadgenConfig { burst_mult: 1.0, ..cfg };
        assert!(!is_burst(Duration::from_millis(10), &flat));
    }

    #[test]
    fn report_accounting() {
        let mut r = LoadReport { offered: 5, ok: 3, rejected: 1, ..Default::default() };
        assert!(!r.accounted());
        r.lost = 1;
        assert!(r.accounted());
        // connection retries are attempts, not offered requests — they
        // must not unbalance the accounting identity
        r.retries = 4;
        assert!(r.accounted());
        r.latencies_us = vec![10, 20, 30];
        assert_eq!(r.latency_p50_us(), 20);
        assert_eq!(r.latency_max_us(), 30);
    }

    #[test]
    fn merge_sums_retries_and_class_counts() {
        let mut a = LoadReport {
            offered: 2,
            ok: 2,
            retries: 1,
            class_ok: [2, 0],
            ..Default::default()
        };
        let b = LoadReport {
            offered: 3,
            ok: 3,
            retries: 2,
            class_ok: [1, 2],
            ..Default::default()
        };
        a.merge(b);
        assert_eq!(a.offered, 5);
        assert_eq!(a.retries, 3);
        assert_eq!(a.class_ok, [3, 2]);
        let row = table(&[("mix", &a)]);
        assert!(row.contains("retry"), "{row}");
    }
}
